"""SLO-driven recomposition: the burn-rate alert closes the control loop.

``adapt_bench`` proved the controller recovers from drift when the COST
model notices (the ``drift`` trigger). This bench proves the other path:
the cost triggers are disabled outright (``every_n`` and ``drift_ratio``
effectively infinite) and the only thing watching the system is an
``obs.SloTracker`` — a user-facing latency objective with multi-window
burn-rate alerting. Three parts:

  - SIMULATED: the adapt-bench 3-step chain under the same 5x mid-run
    compute drift on pA. The drift pushes every request past the
    objective; the fast+slow burn rates breach within a couple of window
    widths; the ``slo`` trigger (and nothing else) forces the placement
    DP, which moves ``work`` to pB under observed costs; the windowed p95
    returns under objective while the STATIC run keeps burning. Asserts
    exactly that, plus that the swap decision's recorded trigger is
    ``slo`` and zero ``drift``/``boundary`` recomputes happened.

  - REAL: same loop on the actual dataflow engine via
    ``AdaptiveDeployment(slo=...)`` with a degrading pA handler — the
    wall-clock twin of the simulated half. Asserts the cutover audit log
    attributes the swap to the SLO by name and the post-swap tail is
    back under objective.

  - PROFILER: the §4.2 document workflow traced on the simulator,
    calibrated with ``obs.calibrate``, ranked by ``WhatIfProfiler``.
    Asserts the top recommendation predicts a p95 improvement and that
    every per-edge transfer speedup predicts a non-regression — the same
    improvement direction the PR-8 streaming bench measured on this
    workflow.

Output: CSV-ish ``name,value`` rows (-> ``BENCH_slo.json`` via run.py).
"""

from __future__ import annotations

import time

import numpy as np

from repro.adapt import AdaptiveDeployment, RecompositionController, TelemetryHub
from repro.core import simulator as sm
from repro.dag import DagDeployment, DagSpec, DagStep
from repro.obs import (
    SloSpec,
    SloTracker,
    Tracer,
    WhatIfProfiler,
    WindowedHistogram,
    calibrate,
)

from benchmarks.adapt_bench import (
    CANDIDATES,
    SIM_PLATFORMS,
    SIM_REGIONS,
    SPEC,
    _deploy,
    _registry,
    modeled_costs,
    real_fallback,
    steps_for,
)

NEVER = 10**9  # every_n / drift_ratio sentinel: only the slo trigger can fire

# objective for the simulated chain: healthy ~2.2 s and the pB fallback
# ~2.6 s sit under it, the 5x-drifted pA (~6.3 s) far over it
SIM_SLO = SloSpec(
    "chain-p95",
    objective_s=3.5,
    target=0.9,
    fast_window_s=12.0,
    slow_window_s=36.0,
    burn_threshold=4.0,
    min_count=6,
)


def run_sim_slo(n: int, drift, adaptive: bool, seed: int = 11):
    """The adapt_bench request loop, SLO-instrumented: cost triggers off,
    per-request latencies fed to the tracker on the sim clock (arrival
    spacing 1 s, so seconds == requests). Returns (totals, windowed
    histogram, tracker, controller or None, tracer, swaps)."""
    hub = TelemetryHub(alpha=0.4)
    tracer = Tracer()
    slo = SloTracker(SIM_SLO, tracer=tracer)
    sim = sm.WorkflowSimulator(
        SIM_PLATFORMS, seed=seed, telemetry=hub if adaptive else None, drift=drift
    )
    ctrl = None
    if adaptive:
        ctrl = RecompositionController(
            hub,
            modeled_costs(),
            CANDIDATES,
            regions=SIM_REGIONS,
            every_n=NEVER,
            drift_ratio=NEVER,
            min_samples=2,
            tracer=tracer,
            slo=slo,
        )
    spec = SPEC
    totals = np.empty(n)
    wh = WindowedHistogram(window_s=32.0, epochs=8)
    swaps = []
    for k in range(n):
        steps = steps_for({s.name: s.platform for s in spec.steps})
        totals[k] = sim.run_request(steps, k * 1.0, prefetch=True).total_s
        now = float(k)
        wh.observe(totals[k], now=now)
        slo.record(totals[k], now=now)
        if ctrl is not None:
            placement = ctrl.tick(spec)
            if placement is not None:
                spec = spec.apply_placement(placement)
                swaps.append((k, placement))
    return totals, wh, slo, ctrl, tracer, swaps


def run_real_slo(requests: int = 72):
    """The real-engine half: cost triggers disabled, the SLO drives."""
    slo_spec = SloSpec(
        "adapt-real-p95",
        objective_s=0.15,
        target=0.8,
        fast_window_s=1.5,
        slow_window_s=4.5,
        burn_threshold=2.0,
        min_count=4,
    )
    rows = {}
    slow = {"scale": 1.0}
    with _deploy(DagDeployment(_registry()), slow) as engine:
        tracer = Tracer()
        real_spec = DagSpec(  # the adapt-bench chain on real platform names
            (
                DagStep("ingest", "edge"),
                DagStep("work", "pA"),
                DagStep("deliver", "edge"),
            ),
            (("ingest", "work"), ("work", "deliver")),
            "slo-real",
        )
        adapt = AdaptiveDeployment(
            engine,
            real_spec,
            CANDIDATES,
            real_fallback(),
            every_n=NEVER,
            drift_ratio=NEVER,
            min_samples=2,
            tracer=tracer,
            slo=SloTracker(slo_spec),
        )
        lat = []
        for k in range(requests):
            if k == requests // 3:
                slow["scale"] = 8.0  # 0.03 s sleep -> 0.24 s, over objective
            lat.append(adapt.run(1.0).total_s)
        tail = lat[-(requests // 4) :]
        rows["real_slo_post_swap_p95_s"] = float(np.quantile(tail, 0.95))
        rows["real_slo_alerts"] = float(adapt.slo.alerts)
        rows["real_route_version"] = float(adapt.routes.version)
        swaps = list(adapt.swaps)
        assert swaps, "SLO breach never produced a cutover"
        assert swaps[0]["trigger"] == "slo", swaps
        assert swaps[0]["slo"] == slo_spec.name, swaps
        assert any(
            m == "work" and dst == "pB"
            for s in swaps
            for m, (_, dst) in s["moved"].items()
        ), swaps
        assert adapt.controller.stats["slo_triggers"] >= 1
        assert adapt.controller.stats["drift_triggers"] == 0
        burn_events = [e for e in tracer.events if e[1] == "slo.burn"]
        assert burn_events, "no slo.burn event reached the tracer ring"
        assert rows["real_slo_post_swap_p95_s"] < slo_spec.objective_s, rows
    return rows


def main(n: int = 240, runs_real: int = 72, quick: bool = False) -> dict:
    if quick:
        n, runs_real = 160, 60
    half = n // 2
    drift = sm.DriftSchedule([sm.DriftEvent(half, "pA", compute_scale=5.0)])

    t0 = time.perf_counter()
    static, wh_s, slo_s, _, _, _ = run_sim_slo(n, drift, adaptive=False)
    adaptive, wh_a, slo_a, ctrl, tracer, swaps = run_sim_slo(n, drift, adaptive=True)
    end = float(n)
    rows = {
        "sim_static_tail_p95_s": wh_s.window(end).quantile(0.95),
        "sim_adaptive_tail_p95_s": wh_a.window(end).quantile(0.95),
        "sim_slo_alerts": float(slo_a.alerts),
        "sim_slo_triggers": float(ctrl.stats["slo_triggers"]),
        "sim_swap_at_request": float(swaps[0][0]) if swaps else -1.0,
        "sim_wall_s": time.perf_counter() - t0,
    }

    # the loop, asserted end to end: burn-rate alert -> slo trigger (and
    # ONLY the slo trigger) -> swap -> windowed p95 back under objective
    assert any(e[1] == "slo.burn" for e in tracer.events), "no slo.burn event"
    assert swaps, "SLO breach never recomposed"
    decisions = [e for e in tracer.events if e[1] == "recompose.decision"]
    swap_decisions = [e for e in decisions if e[2]["outcome"] == "swap"]
    assert swap_decisions and all(
        e[2]["trigger"] == "slo" and e[2]["slo"] == SIM_SLO.name
        for e in swap_decisions
    ), decisions
    assert ctrl.stats["drift_triggers"] == 0, ctrl.stats
    assert ctrl.stats["slo_triggers"] >= 1, ctrl.stats
    assert rows["sim_adaptive_tail_p95_s"] < SIM_SLO.objective_s, rows
    assert rows["sim_static_tail_p95_s"] > SIM_SLO.objective_s, rows
    # the static run's tracker is still burning at the end; the adaptive
    # one recovered (its fast window cleared after the cutover)
    assert slo_s.burning, "static run should still be burning"
    assert not slo_a.burning, "adaptive run should have recovered"

    rows.update(run_real_slo(runs_real))

    # what-if profiler on the traced document workflow: the top ranked
    # intervention must predict a p95 win, and every per-edge transfer
    # speedup must predict a non-regression — the improvement direction
    # the PR-8 streaming bench measured on this same workflow
    doc_tracer = Tracer()
    doc_sim = sm.WorkflowSimulator(sm.paper_platforms(), seed=3)
    doc_edges = (
        ("check", "virus"),
        ("check", "ocr"),
        ("virus", "e_mail"),
        ("ocr", "e_mail"),
    )
    doc_spec = sm.ExperimentSpec(
        sm.document_workflow_fig4(),
        edges=doc_edges,
        n_requests=1,
        prefetch=True,
        tracer=doc_tracer,
    )
    doc_sim.simulate(doc_spec, backend="scalar")
    prof = WhatIfProfiler(calibrate(doc_tracer.last()), n_requests=80 if quick else 200)
    ranked = prof.rank(speedup=2.0)
    top = ranked[0]
    rows["prof_baseline_p95_s"] = top.baseline_s
    rows["prof_top_delta_pct"] = top.delta_pct
    transfers = [iv for iv in ranked if iv.kind == "transfer"]
    rows["prof_best_transfer_delta_pct"] = min(iv.delta_pct for iv in transfers)
    assert top.delta_s < 0, ranked
    assert transfers and all(iv.delta_s <= 1e-9 for iv in transfers), transfers
    print(f"profiler top: {top.label}")

    print("name,value")
    for name, value in rows.items():
        print(f"{name},{value:.4f}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="reduced sample counts")
    main(quick=ap.parse_args().quick)
