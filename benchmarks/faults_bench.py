"""Durability under injected faults: availability with and without the
outage trigger, on the simulator AND the real engine.

The robustness claim, measured end to end. Two parts:

  - SIMULATED: the adapt-bench 3-step chain (ingest on the edge, ``work``
    placeable on pA or pB, deliver on the edge) with a ``FaultSchedule``
    next to the drift schedule: a platform OUTAGE kills pA for the middle
    sixth of the stream, and pB carries a small transient error rate the
    retry budget absorbs (priced as backoff seconds, not failures). The
    STATIC run keeps ``work`` on pA and every outage-window request prices
    to ``inf`` — availability collapses to ~0.83. The ADAPTIVE run feeds
    the simulator's error telemetry into a ``RecompositionController``
    whose OUTAGE trigger prices the dead cell infinite and fails over to
    pB within ~2 requests, then fails BACK to the (strictly cheaper) home
    platform once the outage mark expires after recovery. Asserts adaptive
    availability >= 99% while static stays below the gate, and that both
    the fail-over and the fail-back are audited ``trigger="outage"``
    decisions.

  - REAL: the same chain on the actual dataflow engine with a
    ``FaultInjector`` raising ``InjectedFault`` inside ``_run_node``: an
    outage window on pA (every attempt dies, retries can't save it — the
    first hit exhausts its budget and DEAD-LETTERS through the
    ``JobManager``), plus a transient error rate on pB that the engine's
    retry/backoff loop absorbs (visible as ``retry`` span events). The
    adaptive deployment ticks its controller even on the request that
    raises, cuts ``work`` over to pB on the audited outage trigger, and
    fails back after the TTL. Asserts adaptive availability >= 95% while
    static drops to ~0.75, with the dead letters and retry events visible
    on the report surfaces.

Output: CSV-ish ``name,value`` rows.
"""

from __future__ import annotations

import math

import numpy as np

from repro.adapt import AdaptiveDeployment, RecompositionController, TelemetryHub
from repro.core import Platform, PlatformRegistry
from repro.core.shipping import PlacementCosts
from repro.core.simulator import (
    Dist,
    FaultEvent,
    FaultSchedule,
    OutageEvent,
    RetryPolicy,
    SimPlatform,
    SimStep,
    WorkflowSimulator,
)
from repro.dag import DagDeployment, DagSpec, DagStep
from repro.jobs import JobManager, availability
from repro.obs import Tracer

# ---------------------------------------------------------------------------
# simulated: outage injection + controller-in-the-loop failover
# ---------------------------------------------------------------------------
SIM_PLATFORMS = [
    SimPlatform(
        "client",
        "edge",
        native_prefetch=True,
        allows_sync=True,
        cold_start=Dist(0.2, 0.2),
    ),
    SimPlatform("pA", "region-a", cold_start=Dist(0.8, 0.3)),
    SimPlatform("pB", "region-b", cold_start=Dist(0.8, 0.3)),
]
SIM_REGIONS = {"client": "edge", "pA": "region-a", "pB": "region-b"}
WORK_COMPUTE = {"pA": Dist(1.0, 0.05), "pB": Dist(1.3, 0.05)}
SPEC = DagSpec(
    (
        DagStep("ingest", "client"),
        DagStep("work", "pA"),
        DagStep("deliver", "client"),
    ),
    (("ingest", "work"), ("work", "deliver")),
    "faults-bench",
)
CANDIDATES = {"work": ["pA", "pB"]}


def modeled_costs() -> PlacementCosts:
    """Home platform pA is STRICTLY cheaper than pB — required so the
    outage trigger's fail-back (after the mark expires) actually moves the
    step home instead of parking on the failover platform forever."""
    compute = {
        ("ingest", "client"): 0.04,
        ("deliver", "client"): 0.04,
        ("work", "pA"): 1.0,
        ("work", "pB"): 1.3,
    }
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.0,
        compute_s=lambda name, p: compute.get((name, p), 0.05),
        transfer_s=lambda a, b, size: 0.001 if a == b else 0.6,
        payload_size=1.5e6,
    )


def steps_for(placement: dict) -> list:
    wp = placement["work"]
    return [
        SimStep("ingest", "client", compute=Dist(0.04, 0.05)),
        SimStep("work", wp, compute=WORK_COMPUTE[wp]),
        SimStep("deliver", "client", compute=Dist(0.04, 0.05)),
    ]


def sim_schedule(n: int) -> FaultSchedule:
    """Outage on pA for the middle sixth; mild transients on pB the retry
    budget absorbs (they price as backoff, never as failures)."""
    start = n // 3
    return FaultSchedule(
        (
            OutageEvent(start, start + n // 6, platform="pA"),
            FaultEvent("pB", p_error=0.1, step="work"),
        ),
        seed=7,
    )


def run_sim(n: int, faults, adaptive: bool, seed: int = 11, tracer=None):
    """One simulated request stream with the outage trigger in the loop.
    Returns (totals, swaps, ticks-at-swap)."""
    hub = TelemetryHub(alpha=0.4)
    sim = WorkflowSimulator(
        SIM_PLATFORMS,
        seed=seed,
        telemetry=hub if adaptive else None,
        faults=faults,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.02, seed=3),
    )
    ctrl = RecompositionController(
        hub,
        modeled_costs(),
        CANDIDATES,
        regions=SIM_REGIONS,
        every_n=10**9,  # boundary trigger off: only the outage path fires
        drift_ratio=10.0,  # drift trigger off: pB's modeled gap stays quiet
        min_samples=2,
        outage_ttl=n // 6 + 16,  # expires AFTER the window: one probe, no flap
        tracer=tracer,
    )
    spec = SPEC
    totals = np.empty(n)
    swaps = []
    for k in range(n):
        steps = steps_for({s.name: s.platform for s in spec.steps})
        totals[k] = sim.run_request(steps, k * 1.0, prefetch=True).total_s
        if adaptive:
            placement = ctrl.tick(spec)
            if placement is not None:
                spec = spec.apply_placement(placement)
                swaps.append((k, dict(placement), ctrl.last_trigger))
    return totals, swaps, ctrl


# ---------------------------------------------------------------------------
# real engine: FaultInjector outage + JobManager dead letters
# ---------------------------------------------------------------------------
def _registry():
    reg = PlatformRegistry()
    reg.register(Platform("edge", "edge", kind="edge", native_prefetch=True))
    reg.register(Platform("pA", "region-a", kind="cloud"))
    reg.register(Platform("pB", "region-b", kind="cloud"))
    return reg


def _handlers():
    def ingest(p, d):
        return p

    def work(p, d):
        return p + 1.0

    def deliver(p, d):
        return p

    return ingest, work, deliver


def real_fallback() -> PlacementCosts:
    compute = {("work", "pA"): 0.03, ("work", "pB"): 0.045}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.0,
        compute_s=lambda name, p: compute.get((name, p), 0.001),
        transfer_s=lambda a, b, size: 0.0005 if a == b else 0.05,
        payload_size=1.5e6,
    )


def real_schedule(n: int) -> FaultSchedule:
    """Outage on pA for the second quarter of the request stream (request
    index = the engine's own submission counter), transients on pB."""
    start = n // 4
    return FaultSchedule(
        (
            OutageEvent(start, start + n // 4, platform="pA"),
            FaultEvent("pB", p_error=0.12, step="work"),
        ),
        seed=5,
    )


def _deploy(engine):
    ingest, work, deliver = _handlers()
    engine.deploy("ingest", ingest, ["edge"])
    engine.deploy("work", work, ["pA", "pB"])
    engine.deploy("deliver", deliver, ["edge"])
    return engine


def run_real(requests: int = 64):
    spec = DagSpec(
        (
            DagStep("ingest", "edge"),
            DagStep("work", "pA"),
            DagStep("deliver", "edge"),
        ),
        (("ingest", "work"), ("work", "deliver")),
        "faults-real",
    )
    retry = RetryPolicy(max_attempts=3, backoff_base_s=0.001, seed=9)
    rows = {}

    # adaptive: outage trigger cuts work over to pB, dead-letters only the
    # detection request, fails back home after the TTL
    tracer = Tracer(max_traces=requests + 8)
    engine = _deploy(
        DagDeployment(_registry(), faults=real_schedule(requests), retry=retry)
    )
    with AdaptiveDeployment(
        engine,
        spec,
        CANDIDATES,
        real_fallback(),
        every_n=10**9,
        drift_ratio=10.0,
        min_samples=2,
        outage_ttl=requests // 4 + 8,
        tracer=tracer,
    ) as adapt:
        jm = JobManager(adapt, tracer=tracer, timeout_s=30.0)
        for k in range(requests):
            jm.submit(float(k))
        snap = jm.snapshot()
        rows["real_adaptive_availability"] = snap["kept"] / snap["submitted"]
        rows["real_route_version"] = float(adapt.routes.version)
        rows["real_adaptive_dead_letters"] = float(len(snap["dead_letters"]))
        swaps = list(adapt.swaps)
        retries = sum(
            1
            for t in tracer.traces()
            for s in t.spans
            for e in s.events
            if e[1] == "retry"
        )
        rows["real_retry_span_events"] = float(retries)
        events = [e[1] for e in tracer.events]

    # the exact-ledger invariant holds on the bench too, not just in tests
    assert snap["kept"] + snap["dead_lettered"] == snap["submitted"], snap
    # audited failover: an outage-triggered cutover moved work pA -> pB,
    # and the expiry moved it home again
    assert any(
        s["trigger"] == "outage" and s["moved"].get("work") == ("pA", "pB")
        for s in swaps
    ), swaps
    assert any(s["moved"].get("work") == ("pB", "pA") for s in swaps), swaps
    # the durability surfaces are populated: dead letters recorded and
    # announced on the event ring, retries visible as span events
    assert rows["real_adaptive_dead_letters"] >= 1, snap
    assert "job.dead_letter" in events and "outage.detected" in events, events
    assert retries > 0, "transients on pB never exercised the retry loop"

    # static: same faults, no controller — the whole outage window is lost
    engine = _deploy(
        DagDeployment(_registry(), faults=real_schedule(requests), retry=retry)
    )
    with engine:
        jm = JobManager(engine, timeout_s=30.0)
        for k in range(requests):
            jm.submit(float(k), spec=spec)
        snap = jm.snapshot()
        rows["real_static_availability"] = snap["kept"] / snap["submitted"]
        rows["real_static_dead_letters"] = float(len(snap["dead_letters"]))
    return rows


def main(n: int = 400, runs_real: int = 64) -> dict:
    faults = sim_schedule(n)

    static, _, _ = run_sim(n, faults, adaptive=False)
    sim_tracer = Tracer()
    adaptive, swaps, ctrl = run_sim(n, faults, adaptive=True, tracer=sim_tracer)
    clean, clean_swaps, _ = run_sim(n, None, adaptive=True)

    rows = {
        "sim_static_availability": availability(static),
        "sim_adaptive_availability": availability(adaptive),
        "sim_adaptive_failed_requests": float(np.sum(~np.isfinite(adaptive))),
        "sim_outage_triggers": float(ctrl.stats["outage_triggers"]),
        "sim_post_failback_median_s": float(
            np.median(adaptive[np.isfinite(adaptive)][-(n // 8) :])
        ),
    }
    rows.update(run_real(runs_real))
    print("name,value")
    for name, value in rows.items():
        print(f"{name},{value:.4f}")

    # the headline: the outage trigger holds availability above 99% on the
    # simulator while the static placement loses the whole window
    assert rows["sim_adaptive_availability"] >= 0.99, rows
    assert rows["sim_static_availability"] < 0.99, rows
    assert math.isclose(
        rows["sim_static_availability"], 1.0 - (n // 6) / n, abs_tol=1e-9
    ), rows
    # both directions audited as outage decisions: fail over to pB, fail
    # back home once the mark expires
    assert any(p.get("work") == "pB" and t == "outage" for _, p, t in swaps), swaps
    assert any(p.get("work") == "pA" and t == "outage" for _, p, t in swaps), swaps
    sim_events = [e[1] for e in sim_tracer.events]
    assert "outage.detected" in sim_events and "outage.cleared" in sim_events, (
        sim_events
    )
    # no faults -> no trigger, and the stream is fully available
    assert not clean_swaps and availability(clean) == 1.0
    # the real engine held the gate too, while static collapsed
    assert rows["real_adaptive_availability"] >= 0.95, rows
    assert rows["real_static_availability"] < 0.95, rows
    failover_at = next(k for k, p, t in swaps if p.get("work") == "pB")
    print(f"derived,sim_failover_at_request,{failover_at}")
    print(f"derived,sim_lost_to_detection,{rows['sim_adaptive_failed_requests']:.0f}")
    return rows


if __name__ == "__main__":
    main()
