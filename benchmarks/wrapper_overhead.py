"""Paper §4.1: the platform wrapper adds < 1 ms per call (real wall-clock)."""
from __future__ import annotations

import time


from repro.core.platform import Platform, PlatformWrapper


def main(n_calls=2000):
    plat = Platform("edge", "eu", kind="edge")
    w = PlatformWrapper(plat, lambda payload, data: payload, "noop")
    # measure full-call overhead vs a direct call
    def direct(payload, data):
        return payload
    t0 = time.perf_counter()
    for _ in range(n_calls):
        direct(1, {})
    t_direct = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n_calls):
        w(1, {})
    t_wrapped = time.perf_counter() - t0
    per_call_us = (t_wrapped - t_direct) / n_calls * 1e6
    print("name,us_per_call,derived")
    print(f"wrapper_overhead,{per_call_us:.2f},"
          f"paper_target=<1000us pass={per_call_us < 1000}")
    return per_call_us


if __name__ == "__main__":
    main()
