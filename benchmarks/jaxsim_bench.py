"""jax backend vs numpy backend on the scorer-shaped sweep (jaxsim bench).

The workload that motivates the jax backend is not one experiment but the
candidate-set sweep the ``PlacementScorer`` runs inside the recomposition
controller: (seeds x placements x requests) totals for a whole candidate
placement set under common random numbers. The numpy backend pays one
vectorized experiment per (seed, placement) cell; the jax backend compiles
the entire sweep into ONE jitted program (``simulate_placements``) and
amortizes sampling across it — pre-tabulated lognormal factors per
distinct sigma, static poke depths, an early-out parallel cold scan.

  - SPEED: the full sweep (8 seeds x 32 placements x 512 requests)
    through ``simulate_placements`` (f32) must be >= 5x faster than the
    numpy backend on the same sweep, compile time excluded (measured:
    ~8x on CI-class CPUs). ``--quick`` shrinks the sweep and only gates
    jax >= numpy (tiny sweeps under-fill the compiled program).
  - AGREEMENT: per-placement medians and the pooled p99 of the two
    backends land within 1% (different rngs, same distributions; pinned
    seeds make the gap deterministic).

Output: CSV-ish ``name,value`` rows; ``run.py`` writes them to
``experiments/bench/BENCH_jaxsim.json`` so the speedup is tracked across
commits.
"""

from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from repro.core import simulator as S


def _placements(count: int) -> list:
    """``count`` distinct placements of the document workflow: rotate the
    platform of one middle step through the paper's platform set."""
    base = S.document_workflow_fig4()
    plats = [p.name for p in S.paper_platforms()]
    out = []
    for i in range(count):
        steps = list(base)
        j = 1 + i % (len(steps) - 2)
        steps[j] = replace(steps[j], platform=plats[i % len(plats)])
        out.append(steps)
    return out


def main(
    n: int = 512, n_placements: int = 32, seeds=tuple(range(8)), quick: bool = False
) -> dict:
    if quick:
        n, n_placements, seeds = 128, 8, (0, 1, 2, 3)
    placements = _placements(n_placements)
    spec = S.ExperimentSpec(placements[0], n_requests=n, seeds=tuple(seeds))
    rows = {
        "n_requests": float(n),
        "n_placements": float(n_placements),
        "n_seeds": float(len(seeds)),
    }

    # -- numpy backend: one vectorized experiment per (seed, placement) --------
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    t0 = time.perf_counter()
    np_tot = np.stack(
        [
            sim.simulate(replace(spec, steps=tuple(steps)), backend="numpy")
            for steps in placements
        ],
        axis=1,
    )  # (S, P, n)
    rows["numpy_sweep_s"] = time.perf_counter() - t0

    # -- jax backend: the whole sweep is one jitted call ------------------------
    t0 = time.perf_counter()
    jx_tot = sim.simulate_placements(spec, placements, dtype=np.float32)
    rows["jax_first_call_s"] = time.perf_counter() - t0  # includes compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jx_tot = sim.simulate_placements(spec, placements, dtype=np.float32)
        best = min(best, time.perf_counter() - t0)
    rows["jax_sweep_s"] = best
    rows["speedup_x"] = rows["numpy_sweep_s"] / rows["jax_sweep_s"]

    # -- agreement (pinned seeds -> deterministic, not flaky) -------------------
    med_np = np.median(np_tot, axis=(0, 2))  # per-placement medians
    med_jx = np.median(jx_tot, axis=(0, 2))
    rows["median_gap_pct"] = float(np.abs(med_jx - med_np).max() / med_np.min()) * 100
    p99_np, p99_jx = np.percentile(np_tot, 99), np.percentile(jx_tot, 99)
    rows["p99_gap_pct"] = abs(p99_jx - p99_np) / p99_np * 100

    print("name,value")
    for name, value in rows.items():
        print(f"{name},{value:.6f}")
    cells = len(seeds) * n_placements * n
    print(f"derived,requests_per_second_jax,{cells / rows['jax_sweep_s']:.0f}")

    assert rows["speedup_x"] >= (1.0 if quick else 5.0), rows
    # quick pools ~4k samples, too few to pin the 99th percentile tighter;
    # the 1% gates on the full sweep are the real agreement ratchet
    assert rows["median_gap_pct"] <= (3.0 if quick else 1.0), rows
    assert rows["p99_gap_pct"] <= (6.0 if quick else 1.0), rows
    return rows


if __name__ == "__main__":
    main()
