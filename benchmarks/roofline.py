"""Roofline report: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and prints the per-(arch x shape x mesh) three-term table:

  compute    = FLOPs/dev / 197 TFLOP/s          (bf16 peak, TPU v5e)
  memory     = bytes/dev / 819 GB/s             (HBM)
  collective = ICI bytes / 50 GB/s + DCN bytes / 25 GB/s

plus the dominant bottleneck, the useful-FLOPs ratio (6·N_active·D / total
HLO FLOPs — catches remat/replication waste), and the roofline fraction
(useful model-time / step lower bound).
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _augment(r):
    """Attach the analytic HBM memory term (XLA 'bytes accessed' is a
    pre-fusion UPPER BOUND — 10-100x the touched bytes on the CPU backend;
    see launch/analytic.py::hbm_bytes_dev). Recomputes the bottleneck and
    step lower bound with the analytic term; raw XLA stays as *_xla."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch.analytic import CellModel
    from repro.launch.dryrun import apply_overrides, cell_defaults

    shape = SHAPES[r["shape"]]
    cfg = apply_overrides(cell_defaults(get_config(r["arch"]), shape),
                          r.get("overrides"))
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if r["mesh"].startswith("multipod") else
                  {"data": 16, "model": 16})
    cm = CellModel(cfg, shape, mesh_shape, r.get("micro_global_batch", 0))
    hbm = cm.hbm_bytes_dev(r.get("n_micro", 1), r["params"])
    rl = r["roofline"]
    rl["memory_s_xla_upper"] = rl["memory_s"]
    rl["memory_s"] = hbm / HBM_BW
    rl["bottleneck"] = max(
        (("compute", rl["compute_s"]), ("memory", rl["memory_s"]),
         ("collective", rl["collective_s"])), key=lambda kv: kv[1])[0]
    rl["step_s_lower_bound"] = max(rl["compute_s"], rl["memory_s"],
                                   rl["collective_s"])
    return r


def load(pattern="*.json", d=DRYRUN_DIR):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, pattern))):
        rows.append(_augment(json.load(open(f))))
    return rows


def roofline_fraction(r):
    """useful model-FLOPs time / achievable step time (the score)."""
    ideal_s = r["model_flops"] / (r["n_devices"] * PEAK_FLOPS_BF16)
    lower = r["roofline"]["step_s_lower_bound"]
    return ideal_s / lower if lower > 0 else 0.0


def fmt_row(r):
    rl = r["roofline"]
    return (f"{r['arch']:22s},{r['shape']:12s},"
            f"{r['mesh'].split('_')[0]:8s},{r.get('tag','') or '-':16s},"
            f"{rl['compute_s']*1e3:10.2f},{rl['memory_s']*1e3:10.2f},"
            f"{rl['collective_s']*1e3:10.2f},{rl['bottleneck']:10s},"
            f"{r['useful_flops_ratio']*100:7.2f},"
            f"{roofline_fraction(r)*100:7.2f}")


def main(pattern="*.json"):
    rows = load(pattern)
    if not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all --both-meshes` first")
        return []
    print("arch,shape,mesh,tag,compute_ms,memory_ms,collective_ms,"
          "bottleneck,useful_flops_pct,roofline_frac_pct")
    for r in rows:
        print(fmt_row(r))
    # summary: worst cells by roofline fraction (hillclimb candidates)
    base = [r for r in rows if not r.get("tag")]
    worst = sorted(base, key=roofline_fraction)[:3]
    coll = sorted(base, key=lambda r: -r["roofline"]["collective_s"])[:3]
    print("\n# worst roofline fraction (hillclimb candidates):")
    for r in worst:
        print(f"#   {r['arch']} {r['shape']} {r['mesh']} "
              f"frac={roofline_fraction(r)*100:.2f}%")
    print("# most collective-bound:")
    for r in coll:
        print(f"#   {r['arch']} {r['shape']} {r['mesh']} "
              f"coll={r['roofline']['collective_s']*1e3:.1f}ms")
    return rows


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "*.json")
