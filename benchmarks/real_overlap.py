"""Real-JAX overlap measurement (not simulated): the choreography middleware
hiding (a) enforced object-store latency and (b) XLA compilation behind real
matmul compute on this host.

This is the CPU-scale ground truth that the simulator's protocol semantics
are implemented by the SAME code path a TPU deployment would use.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    DataRef,
    Deployment,
    Platform,
    PlatformRegistry,
    StepSpec,
    WorkflowSpec,
)


def build(compute_s=0.4, fetch_bytes=int(4e6), bw=10e6):
    reg = PlatformRegistry()
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us"))
    dep = Deployment(reg)
    dep.store.enforce_latency = True
    dep.store.network.set_link("eu", "us", 0.04, bw)
    rng = np.random.default_rng(0)
    dep.store.put("dep/big", rng.normal(size=fetch_bytes // 8), region="eu")

    def step_a(payload, data):
        t_end = time.perf_counter() + compute_s  # deterministic busy work
        x = payload
        while time.perf_counter() < t_end:
            x = np.tanh(x @ x.T)[: payload.shape[0], : payload.shape[1]]
        return x

    def step_b(payload, data):
        return float(np.sum(data["dep/big"])) + float(payload[0, 0])

    dep.deploy("a", step_a, ["edge-eu"])
    dep.deploy("b", step_b, ["cloud-us"])
    return dep


def run(dep, prefetch, n=5):
    wf = WorkflowSpec(
        (
            StepSpec("a", "edge-eu", prefetch=prefetch),
            StepSpec(
                "b",
                "cloud-us",
                data_deps=(DataRef("dep/big", "eu"),),
                prefetch=prefetch,
            ),
        )
    )
    x = np.random.default_rng(1).normal(size=(128, 128)).astype(np.float32)
    dep.run(wf, x)  # warm pools/compiles
    return [dep.run(wf, x).total_s for _ in range(n)]


def main():
    with build() as dep:
        geo = np.median(run(dep, True))
        base = np.median(run(dep, False))
        hidden = dep.prefetcher.stats["hidden_s"]
    print("name,us_per_call,derived")
    print(f"real_overlap_baseline,{base * 1e6:.0f},fetch_serial")
    print(
        f"real_overlap_geoff,{geo * 1e6:.0f},"
        f"improvement_pct={(base - geo) / base * 100:.1f} "
        f"hidden_fetch_s={hidden:.2f}"
    )
    return base, geo


if __name__ == "__main__":
    main()
