"""Data-pipeline pre-fetching (GeoFF overlap applied to training input):
DoubleBuffer vs synchronous loading around a real jit'd train step."""
from __future__ import annotations

import time

import jax

from repro.configs.registry import smoke_config
from repro.core.prefetch import DoubleBuffer
from repro.data.pipeline import ShardedLoader, SyntheticCorpus
from repro.models import model as M
from repro.optim import AdamW, AdamWConfig


def main(steps=8):
    cfg = smoke_config("qwen3-1.7b")
    opt = AdamW(AdamWConfig(warmup_steps=1))
    step_fn = jax.jit(M.make_train_step(cfg, opt), donate_argnums=(0, 1))

    def slow_transform(b):   # emulate host-side decode/transfer cost
        time.sleep(0.05)
        return {k: jax.numpy.asarray(v) for k, v in b.items()}

    def run(prefetch):
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        s = opt.init(p)
        corpus = SyntheticCorpus(cfg.vocab_size, 64, 0)
        loader = ShardedLoader(corpus, 4)
        it = DoubleBuffer(loader, 2, slow_transform) if prefetch else \
            map(slow_transform, loader)
        # warm compile
        b = next(it)
        p, s, m = step_fn(p, s, b, jax.numpy.zeros((), "int32"))
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(steps):
            b = next(it)
            p, s, m = step_fn(p, s, b, jax.numpy.asarray(i, "int32"))
            jax.block_until_ready(m["loss"])
        return (time.perf_counter() - t0) / steps

    t_sync = run(False)
    t_pf = run(True)
    print("name,us_per_call,derived")
    print(f"pipeline_sync,{t_sync*1e6:.0f},host_work_serial")
    print(f"pipeline_prefetch,{t_pf*1e6:.0f},"
          f"improvement_pct={(t_sync-t_pf)/t_sync*100:.1f}")
    return t_sync, t_pf


if __name__ == "__main__":
    main()
