"""Benchmark entry point: one bench per paper table/figure + system benches.

  paper_figs        Figs 4/6/8 medians + CDFs (vectorized simulator,
                    multi-seed error bars)
  vecsim            vectorized vs scalar simulation core (asserts >= 20x
                    speedup and <= 1% median/p99 gaps)
  jaxsim            jax backend vs numpy backend on the scorer-shaped
                    (seeds x placements x requests) sweep (asserts >= 5x
                    on the full sweep and <= 1% median/p99 gaps)
  dag_overlap       chain vs DAG medians, +-prefetch (sim + real engine)
  placement         exact place_dag DP vs greedy baseline (asserts DP wins)
  adapt             online recomposition vs static under 5x mid-run drift
                    (sim + real engine; asserts >= 25% recovery, <= 2%
                    no-drift overhead)
  slo               burn-rate alerting closes the loop: cost triggers off,
                    the obs SLO tracker alone forces the re-placement
                    (sim + real engine + what-if profiler direction check)
  faults            durability under injected outages: the outage trigger
                    holds availability >= 99% (sim) / >= 95% (real engine)
                    while static placements lose the whole window; dead
                    letters + retry span events on the report surfaces
  wrapper_overhead  §4.1 wrapper < 1 ms (real wall-clock)
  real_overlap      real-JAX latency hiding on this host (not simulated)
  pipeline_overlap  data-pipeline DoubleBuffer vs sync input
  streaming         chunked pipelined data plane vs whole-object transfers
                    (sim + real engine; asserts >= 20% p50 reduction on
                    both, plus the P2P bypass beating the buffered path)
  timing            §5.5 eager vs learned poke timing (beyond-paper)
  roofline          per-cell three-term table from the dry-run artifacts
  trace_diff        sim-vs-real critical-path diff on the traced document
                    workflow (repro.obs; writes a Perfetto JSON sample)

Output: CSV-ish ``name,us_per_call,derived`` blocks per bench, plus one
machine-readable ``experiments/bench/BENCH_<name>.json`` per bench (the
bench's returned rows + wall time) so the perf trajectory is tracked
across commits instead of scrolling away in CI logs.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback
from datetime import datetime, timezone

BENCH_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def _git_sha() -> str:
    """Current commit SHA, or "unknown" outside a repo / without git."""
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"],
                capture_output=True,
                text=True,
                timeout=5,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            )
            .stdout.strip()
            or "unknown"
        )
    except Exception:
        return "unknown"


def _jax_backend() -> str:
    try:
        import jax

        return jax.default_backend()
    except Exception:
        return "unknown"


def _write_bench_json(name: str, wall_s: float, rows, quick: bool = False) -> None:
    """One JSON artifact per bench: rows (when the bench returned a dict)
    + wall time, stamped with the commit SHA, UTC timestamp and run flags
    so ``scripts/bench_trend.py`` can line artifacts up across commits.
    Non-serializable values degrade to strings rather than failing the
    bench."""
    os.makedirs(BENCH_OUT, exist_ok=True)
    payload = {
        "bench": name,
        "wall_s": round(wall_s, 4),
        "rows": rows if isinstance(rows, dict) else None,
        "git_sha": _git_sha(),
        "utc": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "quick": quick,
        "jax_backend": _jax_backend(),
    }
    path = os.path.join(BENCH_OUT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True, default=str)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="reduced sample counts — the CI smoke gate that "
        "keeps the perf scripts importable and running",
    )
    args = ap.parse_args(argv)

    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)  # `benchmarks` as a package from anywhere
    from benchmarks import (
        adapt_bench,
        dag_overlap,
        faults_bench,
        jaxsim_bench,
        paper_figs,
        pipeline_overlap,
        placement_bench,
        real_overlap,
        roofline,
        slo_bench,
        streaming_bench,
        timing_bench,
        vecsim_bench,
        wrapper_overhead,
    )

    # the simulated benches ride the vectorized path now, so the full run
    # uses paper-scale x ~28 (50k requests) instead of the scalar 1800
    n_fig = 80 if args.quick else 50_000
    seeds_fig = (42, 43) if args.quick else (42, 43, 44, 45, 46)
    benches = [
        (
            "paper_figs",
            lambda: paper_figs.main(n=n_fig, write=not args.quick, seeds=seeds_fig),
        ),
        ("vecsim", vecsim_bench.main),
        ("jaxsim", lambda: jaxsim_bench.main(quick=args.quick)),
        (
            "dag_overlap",
            lambda: dag_overlap.main(
                n=max(n_fig, 1800), runs_real=3 if args.quick else 7
            ),
        ),
        ("placement", placement_bench.main),
        (
            "adapt",
            lambda: adapt_bench.main(
                n=160 if args.quick else 1200, runs_real=40 if args.quick else 64
            ),
        ),
        ("slo", lambda: slo_bench.main(quick=args.quick)),
        (
            "faults",
            lambda: faults_bench.main(
                n=240 if args.quick else 400, runs_real=48 if args.quick else 64
            ),
        ),
        (
            "wrapper_overhead",
            lambda: wrapper_overhead.main(n_calls=100 if args.quick else 2000),
        ),
        ("real_overlap", real_overlap.main),
        (
            "pipeline_overlap",
            lambda: pipeline_overlap.main(steps=4 if args.quick else 8),
        ),
        ("streaming", lambda: streaming_bench.main(quick=args.quick)),
        ("timing", timing_bench.main),
        ("roofline", roofline.main),
    ]

    # sim-vs-real critical-path diff (repro.obs): a script, not a package
    # module — import it off the scripts dir like a bench
    sys.path.insert(0, os.path.join(root, "scripts"))
    import trace_diff

    benches.append(("trace_diff", lambda: trace_diff.main(quick=args.quick)))
    failed = []
    for name, fn in benches:
        print(f"\n===== bench: {name} =====")
        try:
            t0 = time.perf_counter()
            rows = fn()
            _write_bench_json(name, time.perf_counter() - t0, rows, quick=args.quick)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED benches: {failed}")
        sys.exit(1)
    print("\nall benches OK")


if __name__ == "__main__":
    main()
