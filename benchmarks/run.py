"""Benchmark entry point: one bench per paper table/figure + system benches.

  paper_figs        Figs 4/6/8 medians + CDFs (calibrated simulator)
  wrapper_overhead  §4.1 wrapper < 1 ms (real wall-clock)
  real_overlap      real-JAX latency hiding on this host (not simulated)
  pipeline_overlap  data-pipeline DoubleBuffer vs sync input
  timing            §5.5 eager vs learned poke timing (beyond-paper)
  roofline          per-cell three-term table from the dry-run artifacts

Output: CSV-ish ``name,us_per_call,derived`` blocks per bench.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import (paper_figs, pipeline_overlap, real_overlap,
                            roofline, timing_bench, wrapper_overhead)

    benches = [
        ("paper_figs", lambda: paper_figs.main(n=1800)),
        ("wrapper_overhead", wrapper_overhead.main),
        ("real_overlap", real_overlap.main),
        ("pipeline_overlap", pipeline_overlap.main),
        ("timing", timing_bench.main),
        ("roofline", roofline.main),
    ]
    failed = []
    for name, fn in benches:
        print(f"\n===== bench: {name} =====")
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED benches: {failed}")
        sys.exit(1)
    print("\nall benches OK")


if __name__ == "__main__":
    main()
