"""Chain vs DAG medians with and without pre-fetching (dag_overlap bench).

Two parts:
  - SIMULATED: the Fig-4 document workflow restructured as a diamond
    (check -> virus || ocr -> e_mail) through the DAG recurrence
    (repro.dag.sim), against the chain serialization of the same calibrated
    steps — four medians: {chain, dag} x {baseline, prefetch}.
  - REAL: a small diamond with sleeping handlers on the actual dataflow
    engine (repro.dag.engine) vs the same steps serialized through the
    chain middleware, enforced store latencies — the wall-clock win is real
    branch parallelism plus pre-fetch overlap, not a model.

Output: CSV-ish ``name,median_s`` rows; asserts the DAG schedule beats the
chain serialization so CI catches a scheduling regression.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    DataRef,
    Deployment,
    Platform,
    PlatformRegistry,
    StepSpec,
    WorkflowSpec,
)
from repro.core.simulator import ExperimentSpec, median, paper_platforms
from repro.dag import (
    DagDeployment,
    DagSpec,
    DagStep,
    DagWorkflowSimulator,
    document_dag_fig4,
    serialize_chain,
)


def run_sim(n: int = 1800) -> dict:
    """Four medians through the vectorized fast path (50k-request streams
    cost milliseconds; the scalar loop is gated by the vecsim bench)."""
    steps, edges = document_dag_fig4()
    chain = serialize_chain(steps, edges)
    rows = {}
    for label, prefetch in [("baseline", False), ("prefetch", True)]:
        sim = DagWorkflowSimulator(paper_platforms(), seed=42)
        rows[f"sim_chain_{label}"] = median(
            sim.simulate(
                ExperimentSpec(chain, n_requests=n, prefetch=prefetch),
                backend="numpy",
            )
        )
        sim = DagWorkflowSimulator(paper_platforms(), seed=42)
        rows[f"sim_dag_{label}"] = median(
            sim.simulate(
                ExperimentSpec(steps, edges=edges, n_requests=n, prefetch=prefetch),
                backend="numpy",
            )
        )
    return rows


def _register(reg):
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us", kind="cloud"))
    return reg


def _handlers():
    def head(p, d):
        time.sleep(0.04)
        return p

    def branch(p, d):
        assert "ref" in d
        time.sleep(0.12)
        return p

    def join(p, d):
        return p if not isinstance(p, dict) else sum(p.values())

    return head, branch, join


def run_real(runs: int = 5) -> dict:
    deps = (DataRef("ref", "eu"),)
    rows = {}

    def seed(dep):
        dep.store.enforce_latency = True
        dep.store.network.set_link("eu", "us", 0.04, 8e6)
        dep.store.put("ref", np.ones(int(1e6 // 8)), region="eu")
        return dep

    head, branch, join = _handlers()

    with seed(DagDeployment(_register(PlatformRegistry()))) as dag:
        dag.deploy("head", head, ["edge-eu"])
        dag.deploy("left", branch, ["cloud-us"])
        dag.deploy("right", branch, ["cloud-us"])
        dag.deploy("join", join, ["cloud-us"])
        spec = DagSpec(
            (
                DagStep("head", "edge-eu"),
                DagStep("left", "cloud-us", data_deps=deps),
                DagStep("right", "cloud-us", data_deps=deps),
                DagStep("join", "cloud-us"),
            ),
            (
                ("head", "left"),
                ("head", "right"),
                ("left", "join"),
                ("right", "join"),
            ),
            "diamond",
        )
        dag.run(spec, 1.0)  # warm pools
        ts = [dag.run(spec, 1.0).total_s for _ in range(runs)]
        rows["real_dag_prefetch"] = float(np.median(ts))

    with seed(Deployment(_register(PlatformRegistry()))) as chain:
        chain.deploy("head", head, ["edge-eu"])
        chain.deploy("left", branch, ["cloud-us"])
        chain.deploy("right", branch, ["cloud-us"])
        chain.deploy("join", join, ["cloud-us"])
        cspec = WorkflowSpec(
            (
                StepSpec("head", "edge-eu"),
                StepSpec("left", "cloud-us", data_deps=deps),
                StepSpec("right", "cloud-us", data_deps=deps),
                StepSpec("join", "cloud-us"),
            ),
            "diamond-chain",
        )
        chain.run(cspec, 1.0)
        ts = [chain.run(cspec, 1.0).total_s for _ in range(runs)]
        rows["real_chain_prefetch"] = float(np.median(ts))
    return rows


def main(n: int = 1800, runs_real: int = 5) -> dict:
    rows = run_sim(n)
    rows.update(run_real(runs_real))
    print("name,median_s")
    for name, value in rows.items():
        print(f"{name},{value:.4f}")
    assert rows["sim_dag_prefetch"] < rows["sim_chain_prefetch"], rows
    assert rows["sim_dag_baseline"] < rows["sim_chain_baseline"], rows
    assert rows["real_dag_prefetch"] < rows["real_chain_prefetch"], rows
    overlap = rows["sim_chain_prefetch"] - rows["sim_dag_prefetch"]
    print(f"derived,sim_branch_overlap_s,{overlap:.4f}")
    return rows


if __name__ == "__main__":
    main()
