"""Beyond-paper §5.5: eager vs learned poke timing — the duration /
double-billing trade-off, measured in the calibrated simulator.

The controller now plugs straight into the unified simulator (``timing=``):
each edge's poke is delayed by the learned per-(pred -> succ) slack, and the
controller is fed per-edge slack observations relative to the undelayed
poke, so the EWMA converges to the true idle gap instead of chasing its own
feedback."""

from __future__ import annotations

import numpy as np

from repro.core import simulator as S
from repro.core.timing import PokeTimingController


def run(mode: str, n=600, margin=0.2):
    plats = S.paper_platforms()
    steps = S.document_workflow_fig4()
    ctrl = PokeTimingController(mode, margin_s=margin)
    sim = S.WorkflowSimulator(plats, seed=5, timing=ctrl)
    totals, dbs = [], []
    for k in range(n):
        tr = sim.run_request(steps, k * 1.0, prefetch=True)
        totals.append(tr.total_s)
        dbs.append(tr.double_billed_s)
    return float(np.median(totals)), float(np.median(dbs))


def main():
    print("name,us_per_call,derived")
    t_e, d_e = run("eager")
    t_l, d_l = run("learned")
    print(f"poke_eager,{t_e * 1e6:.0f},double_billed_s={d_e:.2f}")
    print(
        f"poke_learned,{t_l * 1e6:.0f},double_billed_s={d_l:.2f} "
        f"duration_cost_pct={(t_l - t_e) / t_e * 100:.1f} "
        f"billing_saved_pct={(d_e - d_l) / max(d_e, 1e-9) * 100:.1f}"
    )
    return (t_e, d_e), (t_l, d_l)


if __name__ == "__main__":
    main()
