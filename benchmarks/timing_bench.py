"""Beyond-paper §5.5: eager vs learned poke timing — the duration /
double-billing trade-off, measured in the calibrated simulator."""
from __future__ import annotations

import numpy as np

from repro.core import simulator as S
from repro.core.timing import PokeTimingController


def run(mode: str, n=600, margin=0.2):
    plats = S.paper_platforms()
    steps = S.document_workflow_fig4()
    ctrl = PokeTimingController(mode, margin_s=margin)
    sim = S.WorkflowSimulator(plats, seed=5)
    totals, dbs = [], []
    for k in range(n):
        tr = sim.run_request(steps, k * 1.0, prefetch=True)
        # apply learned delays post-hoc per successor (the sim recurrence is
        # linear in the poke time, so shifting prepare[i] is exact as long
        # as downstream steps were payload-bound — asserted via start[i])
        total_shift = 0.0
        db = 0.0
        for i in range(1, len(steps)):
            delay = ctrl.poke_delay(steps[i - 1].name, steps[i].name)
            prep = tr.prepare[i] + delay
            start = max(tr.payload[i], prep)
            db += max(0.0, start - prep)
            total_shift = max(total_shift, start - tr.start[i])
            # absolute slack vs the UNDELAYED poke -> the EWMA converges to
            # the true idle gap and the delay tracks it
            ctrl.record_slack(steps[i].name, tr.payload[i] - tr.prepare[i])
        totals.append(tr.total_s + total_shift)
        dbs.append(db if mode == "learned" else tr.double_billed_s)
    return float(np.median(totals)), float(np.median(dbs))


def main():
    print("name,us_per_call,derived")
    t_e, d_e = run("eager")
    t_l, d_l = run("learned")
    print(f"poke_eager,{t_e*1e6:.0f},double_billed_s={d_e:.2f}")
    print(f"poke_learned,{t_l*1e6:.0f},double_billed_s={d_l:.2f} "
          f"duration_cost_pct={(t_l-t_e)/t_e*100:.1f} "
          f"billing_saved_pct={(d_e-d_l)/max(d_e,1e-9)*100:.1f}")
    return (t_e, d_e), (t_l, d_l)


if __name__ == "__main__":
    main()
