"""Benchmarks reproducing the paper's three experiments (Figs 4, 6, 8).

Each bench runs the calibrated simulator with the paper's protocol (1
request/second for 30 simulated minutes = 1800 requests), reports the median
total workflow duration for baseline and GeoFF, the improvement, and writes
the CDF data (to the 99th percentile, as in the paper's figures) to
experiments/paper_figs/.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import simulator as S

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "paper_figs")

PAPER = {
    "fig4_prefetch": {"baseline": 4.65, "geoff": 2.19, "improv": 53.02},
    "fig6_shipping": {"baseline": 10.47, "geoff": 7.65, "improv": 26.90},
    "fig8_native": {"baseline": 5.87, "geoff": 5.08, "improv": 12.08},
}


def cdf99(xs):
    xs = np.sort(np.asarray(xs))
    n = int(len(xs) * 0.99)
    return xs[:n]


def run_fig4(n=1800):
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=42)
    steps = S.document_workflow_fig4()
    base = sim.run_experiment(steps, n, prefetch=False)
    geo = sim.run_experiment(steps, n, prefetch=True)
    return base, geo


def run_fig6(n=1800):
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=42)
    far = sim.run_experiment(S.shipping_workflow_fig6("lambda-eu-central-1"),
                             n, prefetch=True)
    close = sim.run_experiment(S.shipping_workflow_fig6("lambda-us-east-1"),
                               n, prefetch=True)
    return far, close


def run_fig8(n=1800):
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=42)
    steps = S.native_prefetch_workflow_fig8()
    base = sim.run_experiment(steps, n, prefetch=False)
    geo = sim.run_experiment(steps, n, prefetch=True)
    return base, geo


def run_shipping_optimizer_check():
    """§5.3 automation: the placement DP must pick the paper's §4.3 winner."""
    from repro.core.shipping import PlacementCosts, place_chain
    from repro.core.workflow import DataRef, StepSpec, WorkflowSpec
    spec = WorkflowSpec((
        StepSpec("check", "tinyfaas-edge"), StepSpec("virus", "tinyfaas-edge"),
        StepSpec("ocr", "lambda-eu-central-1",
                 data_deps=(DataRef("scans", "us-east-1", int(30e6)),)),
        StepSpec("e_mail", "lambda-us-east-1")))
    fetch = {("ocr", "lambda-eu-central-1"): 3.6,
             ("ocr", "lambda-us-east-1"): 0.9}
    compute = {("ocr", p): 5.85 for p in
               ("lambda-eu-central-1", "lambda-us-east-1")}
    costs = PlacementCosts(
        fetch_s=lambda n, p, d: fetch.get((n, p), 0.0),
        compute_s=lambda n, p: compute.get((n, p), 0.3),
        transfer_s=lambda a, b, s: 0.05 if a == b else 0.8)
    placed = place_chain(spec, {"ocr": ["lambda-eu-central-1",
                                        "lambda-us-east-1"]}, costs)
    return placed.steps[2].platform


def main(n=1800, write=True):
    rows = []
    b4, g4 = run_fig4(n)
    rows.append(("fig4_prefetch", float(np.median(b4)), float(np.median(g4))))
    far, close = run_fig6(n)
    rows.append(("fig6_shipping", float(np.median(far)),
                 float(np.median(close))))
    b8, g8 = run_fig8(n)
    rows.append(("fig8_native", float(np.median(b8)), float(np.median(g8))))

    if write:
        os.makedirs(OUT, exist_ok=True)
        for (name, _, _), (b, g) in zip(rows, [(b4, g4), (far, close),
                                               (b8, g8)]):
            np.savez(os.path.join(OUT, name + "_cdf.npz"),
                     baseline=cdf99(b), geoff=cdf99(g))

    print("name,baseline_median_s,geoff_median_s,improvement_pct,"
          "paper_baseline,paper_geoff,paper_improvement_pct")
    results = {}
    for name, b, g in rows:
        imp = (b - g) / b * 100
        p = PAPER[name]
        print(f"{name},{b:.3f},{g:.3f},{imp:.2f},{p['baseline']},"
              f"{p['geoff']},{p['improv']}")
        results[name] = {"baseline": b, "geoff": g, "improv_pct": imp,
                         "paper": p}
    ship = run_shipping_optimizer_check()
    print(f"shipping_optimizer_choice,{ship},,,,,(paper ships OCR to"
          " us-east-1)")
    results["shipping_optimizer_choice"] = ship
    if write:
        with open(os.path.join(OUT, "summary.json"), "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
