"""Benchmarks reproducing the paper's three experiments (Figs 4, 6, 8).

Each bench runs the calibrated simulator with the paper's protocol — but
through the vectorized fast path, so instead of the paper's single
1800-request stream every condition gets ``n`` requests x ``seeds``
replicas (50k x 5 in the full run). Reported medians are the median of
the per-seed medians, with the seed spread (max - min of the per-seed
medians) as the error bar; CDF data (to the 99th percentile, as in the
paper's figures) is written to experiments/paper_figs/ from the pooled
totals.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import simulator as S

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "paper_figs")

PAPER = {
    "fig4_prefetch": {"baseline": 4.65, "geoff": 2.19, "improv": 53.02},
    "fig6_shipping": {"baseline": 10.47, "geoff": 7.65, "improv": 26.90},
    "fig8_native": {"baseline": 5.87, "geoff": 5.08, "improv": 12.08},
}

SEEDS = (42, 43, 44, 45, 46)


def cdf99(xs):
    xs = np.sort(np.asarray(xs))
    n = int(len(xs) * 0.99)
    return xs[:n]


def sweep(steps, n, prefetch, seeds):
    """(len(seeds), n) totals through the vectorized path."""
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=seeds[0])
    spec = S.ExperimentSpec(steps, n_requests=n, prefetch=prefetch, seeds=tuple(seeds))
    return sim.simulate(spec, backend="numpy")


def run_fig4(n=1800, seeds=SEEDS):
    steps = S.document_workflow_fig4()
    base = sweep(steps, n, False, seeds)
    geo = sweep(steps, n, True, seeds)
    return base, geo


def run_fig6(n=1800, seeds=SEEDS):
    far = sweep(S.shipping_workflow_fig6("lambda-eu-central-1"), n, True, seeds)
    close = sweep(S.shipping_workflow_fig6("lambda-us-east-1"), n, True, seeds)
    return far, close


def run_fig8(n=1800, seeds=SEEDS):
    steps = S.native_prefetch_workflow_fig8()
    base = sweep(steps, n, False, seeds)
    geo = sweep(steps, n, True, seeds)
    return base, geo


def run_shipping_optimizer_check():
    """§5.3 automation: the placement DP must pick the paper's §4.3 winner."""
    from repro.core.shipping import PlacementCosts, place_chain
    from repro.core.workflow import DataRef, StepSpec, WorkflowSpec

    spec = WorkflowSpec(
        (
            StepSpec("check", "tinyfaas-edge"),
            StepSpec("virus", "tinyfaas-edge"),
            StepSpec(
                "ocr",
                "lambda-eu-central-1",
                data_deps=(DataRef("scans", "us-east-1", int(30e6)),),
            ),
            StepSpec("e_mail", "lambda-us-east-1"),
        )
    )
    fetch = {("ocr", "lambda-eu-central-1"): 3.6, ("ocr", "lambda-us-east-1"): 0.9}
    compute = {("ocr", p): 5.85 for p in ("lambda-eu-central-1", "lambda-us-east-1")}
    costs = PlacementCosts(
        fetch_s=lambda n, p, d: fetch.get((n, p), 0.0),
        compute_s=lambda n, p: compute.get((n, p), 0.3),
        transfer_s=lambda a, b, s: 0.05 if a == b else 0.8,
    )
    placed = place_chain(
        spec, {"ocr": ["lambda-eu-central-1", "lambda-us-east-1"]}, costs
    )
    return placed.steps[2].platform


def _stats(totals):
    """(median of per-seed medians, seed spread) for a (seeds, n) sweep."""
    per_seed = np.median(totals, axis=1)
    return float(np.median(per_seed)), float(per_seed.max() - per_seed.min())


def main(n=1800, write=True, seeds=SEEDS):
    seeds = tuple(seeds)
    rows = []
    b4, g4 = run_fig4(n, seeds)
    rows.append(("fig4_prefetch", _stats(b4), _stats(g4)))
    far, close = run_fig6(n, seeds)
    rows.append(("fig6_shipping", _stats(far), _stats(close)))
    b8, g8 = run_fig8(n, seeds)
    rows.append(("fig8_native", _stats(b8), _stats(g8)))

    if write:
        os.makedirs(OUT, exist_ok=True)
        for (name, _, _), (b, g) in zip(rows, [(b4, g4), (far, close), (b8, g8)]):
            np.savez(
                os.path.join(OUT, name + "_cdf.npz"),
                baseline=cdf99(b.ravel()),
                geoff=cdf99(g.ravel()),
            )

    print(
        "name,baseline_median_s,baseline_spread_s,geoff_median_s,"
        "geoff_spread_s,improvement_pct,paper_baseline,paper_geoff,"
        "paper_improvement_pct"
    )
    results = {"n_requests": n, "seeds": list(seeds)}
    for name, (b, b_spread), (g, g_spread) in rows:
        imp = (b - g) / b * 100
        p = PAPER[name]
        print(
            f"{name},{b:.3f},{b_spread:.4f},{g:.3f},{g_spread:.4f},"
            f"{imp:.2f},{p['baseline']},{p['geoff']},{p['improv']}"
        )
        results[name] = {
            "baseline": b,
            "baseline_spread": b_spread,
            "geoff": g,
            "geoff_spread": g_spread,
            "improv_pct": imp,
            "paper": p,
        }
    ship = run_shipping_optimizer_check()
    print(f"shipping_optimizer_choice,{ship},,,,,(paper ships OCR to us-east-1)")
    results["shipping_optimizer_choice"] = ship
    if write:
        with open(os.path.join(OUT, "summary.json"), "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
