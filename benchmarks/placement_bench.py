"""Placement bench: exact DAG DP vs the greedy baseline (placement bench).

``place_dag`` solves the placement exactly (series-parallel DP, exhaustive
fallback); ``place_dag_greedy`` is the pre-DP topological scorer kept as
the baseline. Both placements are scored by the same ``dag_cost`` model on
four topologies:

  chain_shipping       the paper's §4.3 OCR-shipping chain
  diamond_uniform      diamond where every hop off-platform costs 5 s —
                       both optimizers colocate (sanity: DP == greedy)
  diamond_correlated   each branch's data is homed on a DIFFERENT platform;
                       the greedy ships each branch to its local optimum
                       and the join then pays a cross-platform fan-in —
                       the DP sees the coupling and wins outright
  fan_out_3            3-way fan-out with per-branch data homes

Asserts the DP never scores worse than the greedy anywhere and is STRICTLY
better on the correlated diamond (the CI smoke gate for the optimizer).
"""

from __future__ import annotations

from repro.core.shipping import (
    PlacementCosts,
    dag_cost,
    place_dag,
    place_dag_greedy,
)
from repro.core.workflow import StepSpec


def costs_from_tables(fetch=None, compute=None, transfer=None, default_compute=0.1):
    fetch = fetch or {}
    compute = compute or {}
    transfer = transfer or {}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: fetch.get((name, p), 0.0),
        compute_s=lambda name, p: compute.get((name, p), default_compute),
        transfer_s=lambda a, b, size: transfer.get((a, b), 0.0),
        payload_size=1.0,
    )


def _nodes(names, platform="pE"):
    return {n: StepSpec(n, platform) for n in names}


def _cross(platforms, same=0.0, cross=1.5):
    return {(a, b): (same if a == b else cross) for a in platforms for b in platforms}


DIAMOND = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]


def chain_shipping():
    """§4.3: ship OCR to the region its scans live in."""
    nodes = _nodes(["check", "virus", "ocr", "e_mail"], "edge")
    edges = [("check", "virus"), ("virus", "ocr"), ("ocr", "e_mail")]
    plats = ["edge", "eu-central-1", "us-east-1"]
    fetch = {("ocr", "eu-central-1"): 3.6, ("ocr", "us-east-1"): 0.9}
    compute = {("ocr", p): 5.85 for p in plats}
    candidates = {"ocr": ["eu-central-1", "us-east-1"], "e_mail": ["us-east-1"]}
    costs = costs_from_tables(fetch, compute, _cross(plats, 0.1, 0.8))
    return nodes, edges, candidates, costs


def diamond_uniform():
    nodes = _nodes(["a", "b", "c", "d"])
    candidates = {n: ["pE", "pU"] for n in nodes}
    costs = costs_from_tables(transfer=_cross(["pE", "pU"], 0.0, 5.0))
    return nodes, DIAMOND, candidates, costs


def diamond_correlated():
    """Branch b's data is homed on pE, branch c's on pU; moving either
    branch off its home costs 2 s of fetch, but every cross-platform hop
    costs 1.5 s. The greedy sends each branch home and leaves the join a
    cross-platform fan-in; the exact DP keeps the graph coherent."""
    nodes = _nodes(["a", "b", "c", "d"])
    candidates = {n: ["pE", "pU"] for n in nodes}
    fetch = {("b", "pE"): 0.0, ("b", "pU"): 2.0, ("c", "pE"): 2.0, ("c", "pU"): 0.0}
    costs = costs_from_tables(fetch=fetch, transfer=_cross(["pE", "pU"], 0.0, 1.5))
    return nodes, DIAMOND, candidates, costs


def fan_out_3():
    names = ["head", "b0", "b1", "b2", "join"]
    nodes = _nodes(names, "p0")
    plats = ["p0", "p1", "p2"]
    edges = [("head", b) for b in names[1:-1]] + [(b, "join") for b in names[1:-1]]
    candidates = {n: plats for n in names}
    fetch = {
        (f"b{i}", p): (0.0 if p == f"p{i}" else 1.2)
        for i in range(3)
        for p in plats
    }
    costs = costs_from_tables(fetch=fetch, transfer=_cross(plats, 0.0, 0.9))
    return nodes, edges, candidates, costs


TOPOLOGIES = [
    ("chain_shipping", chain_shipping),
    ("diamond_uniform", diamond_uniform),
    ("diamond_correlated", diamond_correlated),
    ("fan_out_3", fan_out_3),
]


def main(prefetch: bool = True) -> dict:
    rows = {}
    print("name,greedy_cost_s,dp_cost_s,win_pct")
    for name, build in TOPOLOGIES:
        nodes, edges, candidates, costs = build()
        greedy = place_dag_greedy(nodes, edges, candidates, costs, prefetch)
        exact = place_dag(nodes, edges, candidates, costs, prefetch)
        g = dag_cost(nodes, edges, greedy, costs, prefetch)
        d = dag_cost(nodes, edges, exact, costs, prefetch)
        rows[name] = (g, d)
        print(f"{name},{g:.4f},{d:.4f},{(g - d) / g * 100:.1f}")
        # the DP is exact: it may never score worse than the greedy
        assert d <= g + 1e-9, (name, d, g)
    g, d = rows["diamond_correlated"]
    assert d < g - 0.5, (d, g)  # the DP win on correlated branches is real
    print(f"derived,correlated_diamond_dp_win_s,{g - d:.4f}")
    return rows


if __name__ == "__main__":
    main()
