"""Vectorized vs scalar simulation core (vecsim bench).

The repo's entire evidence chain — paper reproductions, DAG-overlap and
placement benches, the adapt controller's decisions — flows through
``WorkflowSimulator``. This bench gates the batched fast path that makes
those experiments cheap:

  - SPEED: the 1800-request document workflow (the paper's §4.2 stream)
    through ``backend="numpy"`` must be >= 20x faster than
    the scalar per-request loop (measured: ~100x+ on CI-class CPUs).
  - AGREEMENT: pooled medians (3 fixed seeds x n requests) of the scalar
    and vectorized paths must land within 1% on all three paper workflows
    and the diamond DAG — different draw order, same distributions.
  - SCALE: a 50k-request, multi-seed sweep through
    ``run_experiment_many`` with per-seed medians (the error-bar workflow
    the scalar loop could never afford).

Output: CSV-ish ``name,value`` rows; asserts the speedup and agreement
bounds so CI catches both a perf regression and a semantic drift between
the two paths.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import simulator as S
from repro.dag import document_dag_fig4

SEEDS = (0, 1, 2)


def _pooled(make_steps, n, backend, edges=None):
    """Totals pooled across the fixed seeds (one fresh rng stream each)."""
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=SEEDS[0])
    spec = S.ExperimentSpec(make_steps(), edges=edges, n_requests=n, seeds=SEEDS)
    return sim.simulate(spec, backend=backend).ravel()


def _time_experiment(n: int, backend: str, repeats: int = 3) -> float:
    """Best-of wall time for one document-workflow experiment."""
    spec = S.ExperimentSpec(S.document_workflow_fig4(), n_requests=n)
    best = float("inf")
    for _ in range(repeats):
        sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
        t0 = time.perf_counter()
        sim.simulate(spec, backend=backend)
        best = min(best, time.perf_counter() - t0)
    return best


def main(
    n: int = 1800, sweep_n: int = 50_000, sweep_seeds=(42, 43, 44, 45, 46)
) -> dict:
    rows = {}

    # -- speed gate ------------------------------------------------------------
    t_scalar = _time_experiment(n, backend="scalar", repeats=2)
    t_vec = _time_experiment(n, backend="numpy", repeats=5)
    rows["scalar_1800_s"] = t_scalar
    rows["vectorized_1800_s"] = t_vec
    rows["speedup_x"] = t_scalar / t_vec

    # -- agreement gate (fixed seeds -> deterministic, not flaky) --------------
    workflows = [
        ("fig4_document", S.document_workflow_fig4, None),
        ("fig6_far", lambda: S.shipping_workflow_fig6("lambda-eu-central-1"), None),
        ("fig6_close", lambda: S.shipping_workflow_fig6("lambda-us-east-1"), None),
        ("fig8_native", S.native_prefetch_workflow_fig8, None),
        ("diamond_dag", lambda: document_dag_fig4()[0], document_dag_fig4()[1]),
    ]
    for name, make_steps, edges in workflows:
        sc = _pooled(make_steps, n, backend="scalar", edges=edges)
        ve = _pooled(make_steps, n, backend="numpy", edges=edges)
        p99_sc, p99_ve = np.percentile(sc, 99), np.percentile(ve, 99)
        med_gap = abs(np.median(sc) - np.median(ve)) / np.median(sc)
        rows[f"{name}_median_gap_pct"] = med_gap * 100
        rows[f"{name}_p99_gap_pct"] = abs(p99_sc - p99_ve) / p99_sc * 100

    # -- the scale the fast path buys ------------------------------------------
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    t0 = time.perf_counter()
    sweep = sim.run_experiment_many(
        S.document_workflow_fig4(), seeds=sweep_seeds, n_requests=sweep_n
    )
    rows["sweep_wall_s"] = time.perf_counter() - t0
    per_seed = np.median(sweep, axis=1)
    rows["sweep_median_s"] = float(np.median(per_seed))
    rows["sweep_seed_spread_s"] = float(per_seed.max() - per_seed.min())
    rows["sweep_requests"] = float(sweep.size)

    print("name,value")
    for name, value in rows.items():
        print(f"{name},{value:.6f}")
    print(f"derived,requests_per_second_vectorized,{n / t_vec:.0f}")

    assert rows["speedup_x"] >= 20.0, rows
    for name, _, _ in workflows:
        assert rows[f"{name}_median_gap_pct"] <= 1.0, (name, rows)
        assert rows[f"{name}_p99_gap_pct"] <= 1.0, (name, rows)
    return rows


if __name__ == "__main__":
    main()
