"""Streaming data plane bench: chunked, pipelined transfers vs whole-object.

Two parts, mirroring dag_overlap:
  - SIMULATED: the Fig-4 document workflow (and its diamond DAG form) with
    a data-heavy 8 MB payload, chunks=8 vs streaming off, through the
    vectorized backend — the pipelined closed form must cut the p50 by
    >= 20% on the chain and strictly win on the diamond.
  - REAL: a 3-node chain on the actual dataflow engine with enforced store
    latencies and a staging ``payload_region`` (both modes pay the same
    two wire hops; streaming cut-through pipelines them) — the wall-clock
    p50 must also drop >= 20%. A third mode turns on the P2P bypass for
    the same payload to show the direct path under the threshold.

Output: CSV-ish ``name,median_s`` rows (written to
``experiments/bench/BENCH_streaming.json`` by the runner, trended by
``scripts/bench_trend.py``).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Platform, PlatformRegistry, StreamConfig
from repro.core.simulator import ExperimentSpec, WorkflowSimulator
from repro.core.simulator import document_workflow_fig4, paper_platforms
from repro.dag import (
    DagDeployment,
    DagSpec,
    DagStep,
    DagWorkflowSimulator,
    document_dag_fig4,
)

PAYLOAD_BYTES = 8e6
CHUNKS = 8


def run_sim(n: int = 2000) -> dict:
    rows = {}
    for label, stream in [("off", None), ("stream", StreamConfig(chunks=CHUNKS))]:
        sim = WorkflowSimulator(
            paper_platforms(),
            seed=42,
            payload_size_bytes=PAYLOAD_BYTES,
            stream=stream,
        )
        out = sim.simulate(
            ExperimentSpec(document_workflow_fig4(), n_requests=n),
            backend="numpy",
        )
        rows[f"sim_chain_{label}"] = float(np.median(out))
    steps, edges = document_dag_fig4()
    for label, stream in [("off", None), ("stream", StreamConfig(chunks=CHUNKS))]:
        sim = DagWorkflowSimulator(
            paper_platforms(),
            seed=42,
            payload_size_bytes=PAYLOAD_BYTES,
            stream=stream,
        )
        out = sim.simulate(
            ExperimentSpec(steps, edges=edges, n_requests=n), backend="numpy"
        )
        rows[f"sim_dag_{label}"] = float(np.median(out))
    return rows


def _make_engine(stream=None):
    reg = PlatformRegistry()
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us", kind="cloud"))
    # staging region "mid": payload buffers home there for BOTH modes, so
    # each buffered edge pays two real wire hops — the comparison is fair
    # and the streamed cut-through has an actual pipeline to collapse
    dep = DagDeployment(reg, stream=stream, payload_region="mid")
    dep.store.enforce_latency = True
    dep.store.network.set_link("eu", "us", 0.04, 8e6)
    dep.store.network.set_link("eu", "mid", 0.03, 8e6)
    dep.store.network.set_link("mid", "us", 0.03, 8e6)

    def handler(s):
        def h(payload, data):
            time.sleep(s)
            return payload

        return h

    dep.deploy("a", handler(0.02), ["edge-eu"])
    dep.deploy("b", handler(0.25), ["cloud-us"])
    dep.deploy("c", handler(0.02), ["cloud-us"])
    return dep


ENGINE_SPEC = DagSpec(
    (DagStep("a", "edge-eu"), DagStep("b", "cloud-us"), DagStep("c", "cloud-us")),
    (("a", "b"), ("b", "c")),
    "stream-chain",
)


def run_real(runs: int = 5) -> dict:
    payload = np.zeros(int(2e6 // 8))  # 2 MB on the wire per edge
    rows = {}
    modes = [
        ("off", None),
        ("stream", StreamConfig(chunks=CHUNKS)),
        ("p2p", StreamConfig(chunks=CHUNKS, p2p_threshold_bytes=4e6)),
    ]
    for label, stream in modes:
        with _make_engine(stream) as dep:
            dep.run(ENGINE_SPEC, payload)  # warm pools
            ts = [dep.run(ENGINE_SPEC, payload).total_s for _ in range(runs)]
            rows[f"real_chain_{label}"] = float(np.median(ts))
            if label == "stream":
                assert dep.stats["streamed_edges"] > 0, dep.stats
            if label == "p2p":
                assert dep.stats["p2p_edges"] > 0, dep.stats
    return rows


def main(quick: bool = False) -> dict:
    rows = run_sim(n=400 if quick else 2000)
    rows.update(run_real(runs=3 if quick else 7))
    print("name,median_s")
    for name, value in rows.items():
        print(f"{name},{value:.4f}")
    sim_win = 1.0 - rows["sim_chain_stream"] / rows["sim_chain_off"]
    real_win = 1.0 - rows["real_chain_stream"] / rows["real_chain_off"]
    print(f"derived,sim_p50_reduction,{sim_win:.3f}")
    print(f"derived,real_p50_reduction,{real_win:.3f}")
    # acceptance: pipelining beats whole-object by >= 20% p50 in the sim
    # AND on the real engine; the diamond DAG must improve too
    assert sim_win >= 0.20, rows
    assert real_win >= 0.20, rows
    assert rows["sim_dag_stream"] < rows["sim_dag_off"], rows
    assert rows["real_chain_p2p"] < rows["real_chain_off"], rows
    return rows


if __name__ == "__main__":
    main()
