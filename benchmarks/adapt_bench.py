"""Adaptive recomposition vs static placement under mid-run drift.

The GeoFF recomposition claim, measured end to end. Two parts:

  - SIMULATED: a 3-step chain (ingest on the edge, a heavy ``work`` step
    placeable on pA or pB, deliver on the edge). pA is the modeled optimum
    (1.0 s vs pB's 1.3 s) — until a ``DriftSchedule`` degrades pA's compute
    5x at the midpoint (the integer-factor drift public clouds exhibit,
    Kulkarni et al. 2025). The ADAPTIVE run feeds a ``TelemetryHub`` from
    the simulator and ticks a ``RecompositionController`` after every
    request: the drift trigger fires within a few requests, the exact
    placement DP re-places ``work`` onto pB under observed costs, and the
    post-drift steady state recovers most of the lost latency. The STATIC
    run keeps the original placement. Asserts the adaptive post-drift
    steady-state median beats the static one by >= 25%, and that a
    no-drift adaptive run costs <= 2% over static (the controller never
    swaps, so the draw stream is untouched; control-plane seconds are
    reported separately).

  - REAL: the same chain with sleeping handlers on the actual dataflow
    engine, ``work`` deployed to BOTH platforms, an ``AdaptiveDeployment``
    wrapping the engine. Mid-run the pA handler's sleep is scaled 6x; the
    hub (fed by the engine's instrumentation hooks) sees compute drift,
    the controller hot-swaps the route table, in-flight requests finish on
    their captured routes, and post-drift wall-clock latency drops back.

Output: CSV-ish ``name,value`` rows.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.adapt import AdaptiveDeployment, RecompositionController, TelemetryHub
from repro.core import Platform, PlatformRegistry
from repro.core.shipping import PlacementCosts
from repro.core.simulator import (
    Dist,
    DriftEvent,
    DriftSchedule,
    SimPlatform,
    SimStep,
    WorkflowSimulator,
)
from repro.dag import DagDeployment, DagSpec, DagStep

# ---------------------------------------------------------------------------
# simulated: drift injection + controller-in-the-loop
# ---------------------------------------------------------------------------
SIM_PLATFORMS = [
    SimPlatform(
        "client",
        "edge",
        native_prefetch=True,
        allows_sync=True,
        cold_start=Dist(0.2, 0.2),
    ),
    SimPlatform("pA", "region-a", cold_start=Dist(0.8, 0.3)),
    SimPlatform("pB", "region-b", cold_start=Dist(0.8, 0.3)),
]
SIM_REGIONS = {"client": "edge", "pA": "region-a", "pB": "region-b"}
WORK_COMPUTE = {"pA": Dist(1.0, 0.05), "pB": Dist(1.3, 0.05)}
SPEC = DagSpec(
    (
        DagStep("ingest", "client"),
        DagStep("work", "pA"),
        DagStep("deliver", "client"),
    ),
    (("ingest", "work"), ("work", "deliver")),
    "adapt-bench",
)
CANDIDATES = {"work": ["pA", "pB"]}


def modeled_costs() -> PlacementCosts:
    """The static (fallback) cost model: matches the simulator's medians at
    calibration time — i.e. BEFORE any drift, which is the point."""
    compute = {
        ("ingest", "client"): 0.04,
        ("deliver", "client"): 0.04,
        ("work", "pA"): 1.0,
        ("work", "pB"): 1.3,
    }
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.0,
        compute_s=lambda name, p: compute.get((name, p), 0.05),
        transfer_s=lambda a, b, size: 0.001 if a == b else 0.6,
        payload_size=1.5e6,
    )


def steps_for(placement: dict) -> list:
    wp = placement["work"]
    return [
        SimStep("ingest", "client", compute=Dist(0.04, 0.05)),
        SimStep("work", wp, compute=WORK_COMPUTE[wp]),
        SimStep("deliver", "client", compute=Dist(0.04, 0.05)),
    ]


def run_sim(n: int, drift, adaptive: bool, seed: int = 11, scorer=None):
    """One simulated request stream. Returns (totals, swaps, ctrl_wall_s).
    With ``scorer`` set, swaps are additionally gated on the batched
    candidate scorer: the proposed placement must beat the active one on
    simulated latency distributions at the scorer's quantile."""
    hub = TelemetryHub(alpha=0.4)
    sim = WorkflowSimulator(
        SIM_PLATFORMS, seed=seed, telemetry=hub if adaptive else None, drift=drift
    )
    ctrl = RecompositionController(
        hub,
        modeled_costs(),
        CANDIDATES,
        regions=SIM_REGIONS,
        every_n=8,
        drift_ratio=1.4,
        min_samples=2,
        scorer=scorer,
    )
    spec = SPEC
    totals = np.empty(n)
    swaps, ctrl_s = [], 0.0
    for k in range(n):
        steps = steps_for({s.name: s.platform for s in spec.steps})
        totals[k] = sim.run_request(steps, k * 1.0, prefetch=True).total_s
        if adaptive:
            t0 = time.perf_counter()
            placement = ctrl.tick(spec)
            ctrl_s += time.perf_counter() - t0
            if placement is not None:
                spec = spec.apply_placement(placement)
                swaps.append((k, placement))
    return totals, swaps, ctrl_s


def steady_state(totals: np.ndarray) -> float:
    """Median of the last quarter of the stream (post-drift, post-swap)."""
    return float(np.median(totals[-(len(totals) // 4) :]))


# ---------------------------------------------------------------------------
# real engine: AdaptiveDeployment hot-swap under a degrading handler
# ---------------------------------------------------------------------------
def _registry():
    reg = PlatformRegistry()
    reg.register(Platform("edge", "edge", kind="edge", native_prefetch=True))
    reg.register(Platform("pA", "region-a", kind="cloud"))
    reg.register(Platform("pB", "region-b", kind="cloud"))
    return reg


def _handlers(slow: dict):
    def ingest(p, d):
        return p

    def work(p, d):
        # the pA deployment degrades when slow["scale"] rises; pB is the
        # steady alternative (thread names carry the platform)
        if "plat-pA" in threading.current_thread().name:
            time.sleep(0.03 * slow["scale"])
        else:
            time.sleep(0.045)
        return p

    def deliver(p, d):
        return p

    return ingest, work, deliver


def real_fallback() -> PlacementCosts:
    compute = {("work", "pA"): 0.03, ("work", "pB"): 0.045}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.0,
        compute_s=lambda name, p: compute.get((name, p), 0.001),
        transfer_s=lambda a, b, size: 0.0005 if a == b else 0.05,
        payload_size=1.5e6,
    )


def _deploy(engine, slow):
    ingest, work, deliver = _handlers(slow)
    engine.deploy("ingest", ingest, ["edge"])
    engine.deploy("work", work, ["pA", "pB"])
    engine.deploy("deliver", deliver, ["edge"])
    return engine


def run_real(requests: int = 48, every_n: int = 6):
    spec = DagSpec(
        (
            DagStep("ingest", "edge"),
            DagStep("work", "pA"),
            DagStep("deliver", "edge"),
        ),
        (("ingest", "work"), ("work", "deliver")),
        "adapt-real",
    )
    rows = {}

    slow = {"scale": 1.0}
    with _deploy(DagDeployment(_registry()), slow) as engine:
        adapt = AdaptiveDeployment(
            engine,
            spec,
            CANDIDATES,
            real_fallback(),
            every_n=every_n,
            drift_ratio=1.5,
            min_samples=2,
        )
        lat = []
        for k in range(requests):
            if k == requests // 2:
                slow["scale"] = 6.0
            lat.append(adapt.run(1.0).total_s)
        rows["real_adaptive_post_drift_s"] = float(np.median(lat[-(requests // 4) :]))
        rows["real_route_version"] = float(adapt.routes.version)
        moved = [s["moved"] for s in adapt.swaps]
        assert any("work" in m and m["work"][1] == "pB" for m in moved), moved

    slow = {"scale": 1.0}
    with _deploy(DagDeployment(_registry()), slow) as engine:
        lat = []
        for k in range(requests):
            if k == requests // 2:
                slow["scale"] = 6.0
            lat.append(engine.run(spec, 1.0).total_s)
        rows["real_static_post_drift_s"] = float(np.median(lat[-(requests // 4) :]))
    return rows


def main(n: int = 1200, runs_real: int = 48) -> dict:
    half = n // 2
    drift = DriftSchedule([DriftEvent(half, "pA", compute_scale=5.0)])

    static, _, _ = run_sim(n, drift, adaptive=False)
    adaptive, swaps, ctrl_s = run_sim(n, drift, adaptive=True)
    nd_static, _, _ = run_sim(n, None, adaptive=False)
    nd_adaptive, nd_swaps, nd_ctrl_s = run_sim(n, None, adaptive=True)
    # distribution-gated variant: the DP's proposal must also win at p90
    # of the scorer's simulated latency distributions before swapping
    from repro.adapt import PlacementScorer

    scored, scored_swaps, scored_ctrl_s = run_sim(
        n,
        drift,
        adaptive=True,
        scorer=PlacementScorer(n_requests=128, quantile=0.9),
    )
    # same gate on the jax backend: the whole candidate set is scored by
    # ONE jitted sweep per controller decision instead of a per-candidate
    # numpy loop (draws differ — jax.random — so decisions may differ at
    # the margin; the recovery bar below must hold regardless)
    jax_scored, jax_swaps, jax_ctrl_s = run_sim(
        n,
        drift,
        adaptive=True,
        scorer=PlacementScorer(n_requests=128, quantile=0.9, backend="jax"),
    )

    rows = {
        "sim_static_post_drift_s": steady_state(static),
        "sim_adaptive_post_drift_s": steady_state(adaptive),
        "sim_scored_post_drift_s": steady_state(scored),
        "sim_static_nodrift_s": float(np.median(nd_static)),
        "sim_adaptive_nodrift_s": float(np.median(nd_adaptive)),
        "sim_jax_scored_post_drift_s": steady_state(jax_scored),
        "sim_controller_wall_s": ctrl_s,
        "sim_scored_controller_wall_s": scored_ctrl_s,
        "sim_jax_scored_controller_wall_s": jax_ctrl_s,
    }
    rows.update(run_real(runs_real))
    print("name,value")
    for name, value in rows.items():
        print(f"{name},{value:.4f}")

    # the headline: adaptive recomposition recovers >= 25% of the static
    # post-drift latency (in practice it recovers ~60%)
    recovery = (
        1.0 - rows["sim_adaptive_post_drift_s"] / rows["sim_static_post_drift_s"]
    )
    assert recovery >= 0.25, rows
    assert swaps, "drifted run never recomposed"
    # the distribution-gated controller recovers too (same drift, same bar)
    scored_recovery = (
        1.0 - rows["sim_scored_post_drift_s"] / rows["sim_static_post_drift_s"]
    )
    assert scored_recovery >= 0.25, rows
    assert scored_swaps, "scored run never recomposed"
    jax_recovery = (
        1.0 - rows["sim_jax_scored_post_drift_s"] / rows["sim_static_post_drift_s"]
    )
    assert jax_recovery >= 0.25, rows
    assert jax_swaps, "jax-scored run never recomposed"
    # no drift -> no swap, and the adaptive stream costs <= 2% extra
    assert not nd_swaps, nd_swaps
    overhead = (
        rows["sim_adaptive_nodrift_s"] - rows["sim_static_nodrift_s"]
    ) / rows["sim_static_nodrift_s"]
    assert overhead <= 0.02, rows
    # the real engine swapped and recovered too
    assert rows["real_route_version"] >= 1
    assert rows["real_adaptive_post_drift_s"] < rows["real_static_post_drift_s"], rows
    print(f"derived,sim_post_drift_recovery_pct,{recovery * 100:.1f}")
    print(f"derived,sim_scored_recovery_pct,{scored_recovery * 100:.1f}")
    print(f"derived,sim_jax_scored_recovery_pct,{jax_recovery * 100:.1f}")
    print(f"derived,sim_nodrift_overhead_pct,{overhead * 100:.2f}")
    print(f"derived,sim_swap_at_request,{swaps[0][0]}")
    return rows


if __name__ == "__main__":
    main()
