"""Minimal, dependency-free stand-in for the ``hypothesis`` package.

This shim exists ONLY for environments where the real hypothesis cannot be
installed (the offline dev container); tests/conftest.py puts it on sys.path
strictly as a fallback after ``import hypothesis`` fails, and CI installs the
real package (see pyproject.toml extras), so every property still runs under
genuine shrinking + edge-case search on the PR gate.

Semantics implemented: ``@given`` draws ``max_examples`` pseudo-random
examples (deterministically seeded per test) and calls the test once per
example; ``@settings`` only honors ``max_examples``. Strategies cover the
subset this repo uses — integers / floats / lists / tuples / sampled_from /
characters / text, plus .map and .filter. No shrinking: a failing example is
re-raised as-is with the drawn values attached to the error message.
"""
from __future__ import annotations

import random
import zlib

from . import strategies  # noqa: F401  (re-export: `from hypothesis import strategies`)

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]


class HealthCheck:
    all = staticmethod(lambda: [])


def assume(condition):
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass


def settings(**kw):
    """Decorator recording settings; only ``max_examples`` is honored."""

    def deco(fn):
        fn._hyp_settings = dict(getattr(fn, "_hyp_settings", {}), **kw)
        return fn

    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        def wrapper():
            conf = getattr(wrapper, "_hyp_settings", {})
            n = conf.get("max_examples", 100)
            strategies.new_epoch()   # shared strategies restart their
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            # boundary-example sequence: per-test determinism
            draws = 0
            done = 0
            while done < n and draws < n * 20:
                draws += 1
                try:
                    args = [s.example(rng) for s in strats]
                    kwargs = {k: s.example(rng)
                              for k, s in kwstrats.items()}
                except _UnsatisfiedAssumption:
                    continue
                try:
                    fn(*args, **kwargs)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"property {fn.__qualname__} falsified on example "
                        f"#{done}: args={args!r} kwargs={kwargs!r}") from e
                done += 1

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hyp_settings = dict(getattr(fn, "_hyp_settings", {}))
        wrapper.hypothesis_inner = fn
        return wrapper

    return deco
