"""Strategy subset for the shim — see package docstring for scope/caveats.

Each strategy implements ``example(rng)``. Numeric strategies bias early
draws toward their bounds (the cheap half of hypothesis's edge-case search:
boundary values find divisibility/off-by-one bugs far more often than the
interior). The draw counter behind that is epoch-scoped: ``@given`` bumps
``new_epoch()`` per test run, so module-level strategies shared by several
tests re-emit their boundary examples in EVERY test and a test's draws
never depend on which tests ran before it (per-test determinism).
"""
from __future__ import annotations

_EPOCH = 0


def new_epoch():
    global _EPOCH
    _EPOCH += 1


class SearchStrategy:
    def example(self, rng):
        raise NotImplementedError

    def map(self, f):
        return _Mapped(self, f)

    def filter(self, pred):
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base, f):
        self.base, self.f = base, f

    def example(self, rng):
        return self.f(self.base.example(rng))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def example(self, rng):
        from . import _UnsatisfiedAssumption
        for _ in range(100):
            v = self.base.example(rng)
            if self.pred(v):
                return v
        raise _UnsatisfiedAssumption()


class _Bounded(SearchStrategy):
    """Numeric base: first two draws of each epoch are the bounds."""

    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi
        self._n = 0
        self._epoch = -1

    def _draw_index(self):
        if self._epoch != _EPOCH:
            self._epoch, self._n = _EPOCH, 0
        self._n += 1
        return self._n

    def example(self, rng):
        n = self._draw_index()
        if n == 1:
            return self.lo
        if n == 2:
            return self.hi
        return self._interior(rng)


class _Integers(_Bounded):
    def _interior(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(_Bounded):
    def _interior(self, rng):
        return rng.uniform(self.lo, self.hi)


class _Lists(SearchStrategy):
    def __init__(self, elem, min_size, max_size):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, elems):
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class _SampledFrom(SearchStrategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng):
        return rng.choice(self.options)


class _Characters(SearchStrategy):
    def __init__(self, min_codepoint, max_codepoint):
        self.lo, self.hi = min_codepoint, max_codepoint

    def example(self, rng):
        return chr(rng.randint(self.lo, self.hi))


class _Text(SearchStrategy):
    def __init__(self, alphabet, min_size, max_size):
        self.alphabet = alphabet
        self.min_size, self.max_size = min_size, max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return "".join(self.alphabet.example(rng) for _ in range(n))


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def floats(min_value, max_value):
    return _Floats(min_value, max_value)


def lists(elements, *, min_size=0, max_size=None):
    return _Lists(elements, min_size, max_size if max_size is not None
                  else min_size + 10)


def tuples(*elements):
    return _Tuples(elements)


def sampled_from(options):
    return _SampledFrom(options)


def characters(*, min_codepoint=97, max_codepoint=122, **_ignored):
    return _Characters(min_codepoint, max_codepoint)


def text(alphabet=None, *, min_size=0, max_size=None):
    if alphabet is None:
        alphabet = characters()
    return _Text(alphabet, min_size, max_size if max_size is not None
                 else min_size + 10)


def booleans():
    return _SampledFrom([False, True])


def just(value):
    return _SampledFrom([value])
