"""Sim-vs-real critical-path diffing: where does the model disagree?

Runs the §4.2 document workflow twice —

  1. on the REAL dataflow engine (``examples/document_workflow.py``'s
     deployment) with an ``obs.Tracer`` attached, and
  2. on the SCALAR simulator, calibrated step by step from what the real
     trace actually observed (compute/fetch/cold medians, per-edge
     transfer seconds, estimated poke message latency),

then extracts the critical path of each trace and prints the per-bucket
latency attribution side by side. A large delta in one bucket is a
localized statement about the model: "the simulator's transfer model is
0.3 s optimistic on virus->e_mail", not "the totals differ".

Both traces are also exported as one Chrome/Perfetto JSON
(``experiments/bench/TRACE_docflow.json``) — load it in ui.perfetto.dev
to see the real and simulated requests as adjacent process tracks.

    PYTHONPATH=src python scripts/trace_diff.py [--quick]

Importable: ``main(quick=True)`` returns the diff rows as a dict (the
``benchmarks/run.py --quick`` smoke gate calls it that way).
"""

from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, os.path.join(_ROOT, "examples"))

import numpy as np

OUT_DIR = os.path.join(_ROOT, "experiments", "bench")


# -- real engine run ------------------------------------------------------------
def run_real(warm_runs: int = 1):
    """One traced request through the real document-workflow DAG (after
    ``warm_runs`` untraced warm-up requests). Returns (trace, tracer)."""
    import document_workflow as dw
    from repro.dag import DagDeployment
    from repro.obs import MetricsRegistry, Tracer

    tracer = Tracer(metrics=MetricsRegistry())
    rng = np.random.default_rng(7)
    pdf = b"%PDF-1.7 " + rng.bytes(int(1.2e6))
    with dw.deploy_all(DagDeployment(dw.build_platforms(), tracer=tracer)) as dag:
        dw.seed_store(dag.store, np.random.default_rng(11))
        spec = dw.dag_spec(True)
        for _ in range(warm_runs):
            dag.run(spec, pdf)
        tracer.clear()  # keep only the measured request
        dag.run(spec, pdf)
    return tracer.last(), tracer


# -- calibration ----------------------------------------------------------------
def _full_fetch_s(trace) -> dict:
    """Full (pre-overlap) fetch seconds per store key, from the component
    span events. The node span's ``fetch_s`` is only the RESIDUAL the
    request waited; the prefetch/fetch events carry the modeled duration
    the simulator should reproduce."""
    out = {}
    for span in trace.spans:
        for _t, name, attrs in span.events:
            if name in ("prefetch.done", "fetch.cold") and "modeled_s" in attrs:
                key = attrs.get("key")
                out[key] = max(out.get(key, 0.0), float(attrs["modeled_s"]))
    return out


def _estimate_msg_s(trace, default: float = 0.005) -> float:
    """Poke message latency from observed poke times: median of
    ``(poke_t - t0) / depth`` over nodes with depth >= 1."""
    nodes = trace.node_spans()
    preds = {n: set(s.attrs.get("preds") or ()) for n, s in nodes.items()}
    depth, frontier, d = {}, {n for n, p in preds.items() if not p}, 0
    while frontier:
        for n in frontier:
            depth[n] = d
        frontier = {
            n for n in preds if n not in depth and preds[n] <= set(depth)
        }
        d += 1
    ests = [
        (nodes[n].attrs["poke_t"] - trace.root.t_start) / depth[n]
        for n in nodes
        if depth.get(n, 0) >= 1 and nodes[n].attrs.get("poke_t") is not None
    ]
    return float(np.median(ests)) if ests else default


def calibrated_sim_trace(real_trace):
    """Simulate the same DAG with every draw pinned to what the real trace
    observed. Returns (trace, simulator)."""
    import document_workflow as dw
    from repro.core import simulator as sm
    from repro.obs import Tracer

    dag = dw.dag_spec(True)
    nodes = real_trace.node_spans()
    fetch_by_key = _full_fetch_s(real_trace)

    reg = dw.build_platforms()
    platforms = []
    for pname in reg.names():
        plat = reg.get(pname)
        colds = [
            nodes[s.name].attrs.get("cold_s", 0.0)
            for s in dag.steps
            if s.platform == pname and s.name in nodes
        ]
        platforms.append(
            sm.SimPlatform(
                pname,
                plat.region,
                native_prefetch=plat.native_prefetch,
                allows_sync=getattr(plat, "allows_sync", True),
                cold_start=sm.Dist(max(colds, default=0.0), 0.0),
            )
        )

    steps = []
    for s in dag.steps:
        span = nodes[s.name]
        fetch = sum(fetch_by_key.get(ref.key, 0.0) for ref in s.data_deps)
        # residual fetch the prefetcher could not hide is a lower bound
        fetch = max(fetch, span.attrs.get("fetch_s", 0.0))
        steps.append(
            sm.SimStep(
                s.name,
                s.platform,
                compute=sm.Dist(span.attrs.get("compute_s", 0.0), 0.0),
                fetch=sm.Dist(fetch, 0.0),
                prefetch=True,
            )
        )

    edge_table = {}
    for name, span in nodes.items():
        for pred, tr_s in (span.attrs.get("transfer_s") or {}).items():
            edge_table[(pred, name)] = float(tr_s)

    class _CalibratedSim(sm.WorkflowSimulator):
        def _edge_transfer_s(self, src_step, dst_step):
            key = (src_step.name, dst_step.name)
            if key in edge_table:
                return edge_table[key]
            return super()._edge_transfer_s(src_step, dst_step)

    tracer = Tracer()
    simulator = _CalibratedSim(
        platforms, msg_latency_s=_estimate_msg_s(real_trace), seed=0
    )
    spec = sm.ExperimentSpec(
        steps, edges=dag.edges, n_requests=1, prefetch=True, tracer=tracer
    )
    simulator.simulate(spec, backend="scalar")
    return tracer.last(), simulator


# -- diff -----------------------------------------------------------------------
def diff_rows(real_trace, sim_trace) -> dict:
    from repro.obs import BUCKETS, extract_critical_path

    real_cp = extract_critical_path(real_trace)
    sim_cp = extract_critical_path(sim_trace)
    rows = {
        "real_total_s": round(real_cp.total_s, 6),
        "sim_total_s": round(sim_cp.total_s, 6),
        "real_path": "->".join(real_cp.nodes),
        "sim_path": "->".join(sim_cp.nodes),
    }
    ra, sa = real_cp.attribution, sim_cp.attribution
    for bucket in BUCKETS:
        rows[f"real_{bucket}_s"] = round(ra.get(bucket, 0.0), 6)
        rows[f"sim_{bucket}_s"] = round(sa.get(bucket, 0.0), 6)
        rows[f"delta_{bucket}_s"] = round(
            sa.get(bucket, 0.0) - ra.get(bucket, 0.0), 6
        )
    return rows


def print_table(rows: dict) -> None:
    from repro.obs import BUCKETS

    print(f"{'bucket':12s} {'real_s':>9s} {'sim_s':>9s} {'delta_s':>9s}")
    for bucket in BUCKETS:
        print(
            f"{bucket:12s} {rows[f'real_{bucket}_s']:9.4f}"
            f" {rows[f'sim_{bucket}_s']:9.4f}"
            f" {rows[f'delta_{bucket}_s']:+9.4f}"
        )
    print(
        f"{'total':12s} {rows['real_total_s']:9.4f} {rows['sim_total_s']:9.4f}"
        f" {rows['sim_total_s'] - rows['real_total_s']:+9.4f}"
    )
    print(f"real path: {rows['real_path']}")
    print(f"sim path:  {rows['sim_path']}")


def main(quick: bool = False, out_dir: str = OUT_DIR) -> dict:
    from repro.obs import write_chrome_trace

    real_trace, tracer = run_real(warm_runs=1 if quick else 2)
    sim_trace, _ = calibrated_sim_trace(real_trace)
    rows = diff_rows(real_trace, sim_trace)
    print_table(rows)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "TRACE_docflow.json")
    write_chrome_trace(path, [real_trace, sim_trace], tracer=tracer)
    print(f"perfetto trace: {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="single warm-up run")
    main(quick=ap.parse_args().quick)
