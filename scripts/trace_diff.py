"""Sim-vs-real critical-path diffing: where does the model disagree?

Runs the §4.2 document workflow twice —

  1. on the REAL dataflow engine (``examples/document_workflow.py``'s
     deployment) with an ``obs.Tracer`` attached, and
  2. on the SCALAR simulator, calibrated step by step from what the real
     trace actually observed (compute/fetch/cold medians, per-edge
     transfer seconds, estimated poke message latency),

then extracts the critical path of each trace and prints the per-bucket
latency attribution side by side. A large delta in one bucket is a
localized statement about the model: "the simulator's transfer model is
0.3 s optimistic on virus->e_mail", not "the totals differ".

Both traces are also exported as one Chrome/Perfetto JSON
(``experiments/bench/TRACE_docflow.json``) — load it in ui.perfetto.dev
to see the real and simulated requests as adjacent process tracks.

    PYTHONPATH=src python scripts/trace_diff.py [--quick]

Importable: ``main(quick=True)`` returns the diff rows as a dict (the
``benchmarks/run.py --quick`` smoke gate calls it that way).
"""

from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, os.path.join(_ROOT, "examples"))

import numpy as np

OUT_DIR = os.path.join(_ROOT, "experiments", "bench")


# -- real engine run ------------------------------------------------------------
def run_real(warm_runs: int = 1):
    """One traced request through the real document-workflow DAG (after
    ``warm_runs`` untraced warm-up requests). Returns (trace, tracer)."""
    import document_workflow as dw
    from repro.dag import DagDeployment
    from repro.obs import MetricsRegistry, Tracer

    tracer = Tracer(metrics=MetricsRegistry())
    rng = np.random.default_rng(7)
    pdf = b"%PDF-1.7 " + rng.bytes(int(1.2e6))
    with dw.deploy_all(DagDeployment(dw.build_platforms(), tracer=tracer)) as dag:
        dw.seed_store(dag.store, np.random.default_rng(11))
        spec = dw.dag_spec(True)
        for _ in range(warm_runs):
            dag.run(spec, pdf)
        tracer.clear()  # keep only the measured request
        dag.run(spec, pdf)
    return tracer.last(), tracer


# -- calibration ----------------------------------------------------------------
def calibrated_sim_trace(real_trace):
    """Simulate the same DAG with every draw pinned to what the real trace
    observed — ``obs.profiler.calibrate`` does the trace -> model
    extraction (cold/compute/fetch medians, per-edge ``transfer_table``,
    estimated poke latency); region metadata comes from the deployment's
    platform registry so unobserved edges still price correctly. Returns
    (trace, simulator)."""
    import document_workflow as dw
    from repro.core import simulator as sm
    from repro.obs import Tracer, calibrate

    reg = dw.build_platforms()
    world = calibrate(
        real_trace, regions={name: reg.get(name).region for name in reg.names()}
    )
    tracer = Tracer()
    simulator = world.simulator(seed=0)
    spec = sm.ExperimentSpec(
        world.steps,
        edges=world.edges,
        n_requests=1,
        prefetch=world.prefetch,
        tracer=tracer,
    )
    simulator.simulate(spec, backend="scalar")
    return tracer.last(), simulator


# -- diff -----------------------------------------------------------------------
def diff_rows(real_trace, sim_trace) -> dict:
    from repro.obs import BUCKETS, extract_critical_path

    real_cp = extract_critical_path(real_trace)
    sim_cp = extract_critical_path(sim_trace)
    rows = {
        "real_total_s": round(real_cp.total_s, 6),
        "sim_total_s": round(sim_cp.total_s, 6),
        "real_path": "->".join(real_cp.nodes),
        "sim_path": "->".join(sim_cp.nodes),
    }
    ra, sa = real_cp.attribution, sim_cp.attribution
    for bucket in BUCKETS:
        rows[f"real_{bucket}_s"] = round(ra.get(bucket, 0.0), 6)
        rows[f"sim_{bucket}_s"] = round(sa.get(bucket, 0.0), 6)
        rows[f"delta_{bucket}_s"] = round(sa.get(bucket, 0.0) - ra.get(bucket, 0.0), 6)
    return rows


def print_table(rows: dict) -> None:
    from repro.obs import BUCKETS

    print(f"{'bucket':12s} {'real_s':>9s} {'sim_s':>9s} {'delta_s':>9s}")
    for bucket in BUCKETS:
        print(
            f"{bucket:12s} {rows[f'real_{bucket}_s']:9.4f}"
            f" {rows[f'sim_{bucket}_s']:9.4f}"
            f" {rows[f'delta_{bucket}_s']:+9.4f}"
        )
    print(
        f"{'total':12s} {rows['real_total_s']:9.4f} {rows['sim_total_s']:9.4f}"
        f" {rows['sim_total_s'] - rows['real_total_s']:+9.4f}"
    )
    print(f"real path: {rows['real_path']}")
    print(f"sim path:  {rows['sim_path']}")


def main(quick: bool = False, out_dir: str = OUT_DIR) -> dict:
    from repro.obs import write_chrome_trace

    real_trace, tracer = run_real(warm_runs=1 if quick else 2)
    sim_trace, _ = calibrated_sim_trace(real_trace)
    rows = diff_rows(real_trace, sim_trace)
    print_table(rows)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "TRACE_docflow.json")
    write_chrome_trace(path, [real_trace, sim_trace], tracer=tracer)
    print(f"perfetto trace: {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="single warm-up run")
    main(quick=ap.parse_args().quick)
