"""Bench trend: line the stamped ``BENCH_<name>.json`` artifacts up over time.

``benchmarks/run.py`` overwrites one JSON per bench per run, each stamped
with the commit SHA, UTC timestamp and run flags. This script makes those
stamps useful:

  1. it APPENDS the current snapshot to ``experiments/bench/trend.jsonl``
     (one line per bench per run, idempotent per (bench, git_sha, utc)),
  2. it prints the per-bench wall-time trajectory across every recorded
     run, so a bench that got 3x slower two commits ago is visible in one
     table instead of buried in CI logs.

    python scripts/bench_trend.py [--no-append]

Importable: ``main(append=...)`` returns the trend rows as a list of
dicts.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
BENCH_DIR = os.path.join(_ROOT, "experiments", "bench")
TREND_PATH = os.path.join(BENCH_DIR, "trend.jsonl")


def snapshot_rows(bench_dir: str = BENCH_DIR) -> list:
    """Current BENCH_*.json artifacts as flat stamped rows."""
    rows = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        rows.append(
            {
                "bench": payload.get("bench", os.path.basename(path)),
                "wall_s": payload.get("wall_s"),
                "git_sha": payload.get("git_sha", "unknown"),
                "utc": payload.get("utc", ""),
                "quick": payload.get("quick", False),
                "jax_backend": payload.get("jax_backend", "unknown"),
            }
        )
    return rows


def load_trend(path: str = TREND_PATH) -> list:
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    try:
                        rows.append(json.loads(line))
                    except json.JSONDecodeError:
                        continue
    return rows


def append_snapshot(path: str = TREND_PATH, bench_dir: str = BENCH_DIR) -> int:
    """Append the current artifacts to the trend log; a (bench, sha, utc)
    triple already present is skipped, so re-running is idempotent."""
    have = {(r["bench"], r.get("git_sha"), r.get("utc")) for r in load_trend(path)}
    fresh = [
        r
        for r in snapshot_rows(bench_dir)
        if (r["bench"], r.get("git_sha"), r.get("utc")) not in have
    ]
    if fresh:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "a") as f:
            for r in fresh:
                f.write(json.dumps(r, sort_keys=True) + "\n")
    return len(fresh)


def print_trend(rows: list) -> None:
    by_bench: dict = {}
    for r in sorted(rows, key=lambda r: (r.get("utc") or "", r["bench"])):
        by_bench.setdefault(r["bench"], []).append(r)
    print(f"{'bench':18s} {'runs':>4s} {'latest_s':>9s}  {'wall_s trajectory'}")
    for bench in sorted(by_bench):
        hist = by_bench[bench]
        walls = [r.get("wall_s") for r in hist if r.get("wall_s") is not None]
        traj = " -> ".join(f"{w:.2f}" for w in walls[-6:])
        latest = f"{walls[-1]:9.2f}" if walls else f"{'?':>9s}"
        sha = (hist[-1].get("git_sha") or "unknown")[:8]
        flag = " (quick)" if hist[-1].get("quick") else ""
        print(f"{bench:18s} {len(hist):4d} {latest}  {traj}  @{sha}{flag}")


def main(append: bool = True) -> list:
    if append:
        n = append_snapshot()
        print(f"appended {n} new row(s) to {os.path.relpath(TREND_PATH, _ROOT)}")
    rows = load_trend()
    if not rows:  # nothing recorded yet: show the live snapshot instead
        rows = snapshot_rows()
    print_trend(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--no-append", action="store_true", help="print only, don't record"
    )
    main(append=not ap.parse_args().no_append)
