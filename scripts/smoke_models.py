"""Quick CPU smoke of every arch's reduced config: train fwd + prefill/decode."""
import sys
import jax
import jax.numpy as jnp

sys.path.insert(0, "src")
from repro.configs.registry import ARCH_IDS, smoke_config
from repro.models import model as M

for arch in ARCH_IDS:
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, T = 2, 32
    if cfg.input_kind == "frames":
        batch = {"frames": jax.random.normal(key, (B, T, cfg.d_model)),
                 "labels": jnp.zeros((B, T), jnp.int32)}
    elif cfg.input_kind == "tokens+patches":
        P = cfg.num_patches
        batch = {"tokens": jnp.zeros((B, T - P), jnp.int32),
                 "patches": jax.random.normal(key, (B, P, cfg.d_model)),
                 "labels": jnp.zeros((B, T - P), jnp.int32)}
    else:
        batch = {"tokens": jnp.zeros((B, T), jnp.int32),
                 "labels": jnp.zeros((B, T), jnp.int32)}
    loss, metrics = M.forward_train(cfg, params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    line = f"{arch:24s} loss={float(loss):8.4f}"
    if cfg.supports_decode:
        pf_batch = {k: v for k, v in batch.items() if k != "labels"}
        logits, caches = M.prefill(cfg, params, pf_batch)
        assert logits.shape == (B, cfg.vocab_size)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        # decode one step at cur_index=T. Pad caches? prefill cache cap == T,
        # decode writes at index T -> serving pads; here test in-place decode
        # at the last position instead (cur_index = T-1 rewrite is fine for
        # shape smoke).
        logits2, caches2 = M.decode_step(cfg, params, tok, caches,
                                         jnp.int32(T - 1))
        assert logits2.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits2))), arch
        line += "  decode ok"
    print(line)
print("ALL OK")
