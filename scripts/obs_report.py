"""Ops snapshot of the observability control plane, in one page.

Drives a handful of requests through the real document workflow
(``examples/document_workflow.py``) with the full repro.obs level-2 stack
attached — windowed metrics, an SLO tracker, tail-based trace sampling —
then prints (and writes ``experiments/bench/OBS_report.json``):

  - the hottest metric series by windowed p99 (what is slow RIGHT NOW,
    not since birth),
  - the SLO's fast/slow burn rates and alert counters,
  - the tail sampler's retention accounting (kept/evicted, threshold),
  - the top-3 what-if profiler recommendations calibrated from the last
    retained trace ("pre-fetch X / stream edge Y / keep Z warm: -N% p95").

CI uploads the JSON as an artifact, so every commit carries the ops view
of the workflow it shipped.

    PYTHONPATH=src python scripts/obs_report.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, os.path.join(_ROOT, "examples"))

import numpy as np

OUT_DIR = os.path.join(_ROOT, "experiments", "bench")


def run_workflow(requests: int):
    """Traced requests through the real document workflow with the level-2
    stack attached. Returns (tracer, slo, registry regions)."""
    import document_workflow as dw
    from repro.dag import DagDeployment
    from repro.obs import (
        MetricsRegistry,
        SloSpec,
        SloTracker,
        TailSampler,
        Tracer,
    )

    # tight window (seconds of wall clock) so the report is about NOW;
    # min_count low enough that a short demo run arms the slow-trace test
    sampler = TailSampler(window_s=60.0, epochs=10, head_every=4, min_count=4)
    tracer = Tracer(metrics=MetricsRegistry(window_s=60.0), sampler=sampler)
    slo = SloTracker(
        SloSpec(
            "docflow-p95",
            objective_s=1.0,
            target=0.9,
            fast_window_s=10.0,
            slow_window_s=30.0,
            burn_threshold=2.0,
            min_count=4,
        ),
        tracer=tracer,
    )
    rng = np.random.default_rng(7)
    pdf = b"%PDF-1.7 " + rng.bytes(int(1.2e6))
    import time

    with dw.deploy_all(DagDeployment(dw.build_platforms(), tracer=tracer)) as dag:
        dw.seed_store(dag.store, np.random.default_rng(11))
        spec = dw.dag_spec(True)
        for _ in range(requests):
            result = dag.run(spec, pdf)
            slo.record(result.total_s, now=time.perf_counter())
        regions = {name: dag.registry.get(name).region for name in dag.registry.names()}
    return tracer, slo, regions


def build_report(tracer, slo, regions, quick: bool) -> dict:
    from repro.obs import profile_trace

    top_series = [
        {"series": name, "w_p99_s": round(s["w_p99_s"], 6), "w_count": s["w_count"]}
        for name, s in tracer.metrics.top(5, key="w_p99_s")
    ]
    recs = []
    last = tracer.last()
    if last is not None:
        for iv in profile_trace(
            last, regions=regions, top=3, n_requests=60 if quick else 200
        ):
            recs.append(
                {
                    "label": iv.label,
                    "kind": iv.kind,
                    "target": iv.target,
                    "delta_pct": round(iv.delta_pct, 2),
                    "predicted_p95_s": round(iv.predicted_s, 6),
                }
            )
    return {
        "top_series_by_windowed_p99": top_series,
        "slo": slo.snapshot(),
        "trace_sampler": tracer.sampler.snapshot(),
        "profiler_top3": recs,
    }


def print_report(report: dict) -> None:
    print("== hottest series (windowed p99) ==")
    for row in report["top_series_by_windowed_p99"]:
        print(f"  {row['series']:32s} {row['w_p99_s']:9.4f}s  n={row['w_count']}")
    s = report["slo"]
    print(
        f"== slo {s['slo']} ==  objective={s['objective_s']}s "
        f"burning={s['burning']} fast_burn={s['fast_burn']:.2f} "
        f"slow_burn={s['slow_burn']:.2f} alerts={s['alerts']}"
    )
    t = report["trace_sampler"]
    print(
        f"== tail sampler ==  seen={t['seen']} kept={t['kept']} "
        f"(slow={t['kept_slow']} slo={t['kept_slo']} head={t['kept_head']}) "
        f"evicted={t['evicted']} threshold={t['threshold_s']:.4f}s"
    )
    print("== what to fix next (what-if profiler) ==")
    for rec in report["profiler_top3"]:
        print(f"  {rec['label']}")


def main(quick: bool = False, out_dir: str = OUT_DIR) -> dict:
    tracer, slo, regions = run_workflow(requests=4 if quick else 8)
    report = build_report(tracer, slo, regions, quick)
    print_report(report)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "OBS_report.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, default=str)
    print(f"report: {path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="fewer requests")
    main(quick=ap.parse_args().quick)
