"""Diff dry-run roofline artifacts against checked-in baselines.

The scheduled CI sweep (``.github/workflows/nightly.yml``) runs
``python -m repro.launch.dryrun --all --both-meshes`` (512 simulated
devices) and then this script: every cell present in
``experiments/baselines/roofline_baselines.json`` must still exist in the
fresh artifacts and agree on its three roofline terms (compute / memory /
collective seconds), the useful-FLOPs ratio, and the bottleneck — within
``--rtol`` (default 5%, absorbing XLA version noise). A drifted cell means
a distribution-config or cost-model regression landed silently; the job
fails and prints the per-term deltas.

Cells WITHOUT a baseline are reported as "new" but do not fail — the
baseline set grows file-by-file as cells are vetted (run with ``--write``
to regenerate the baseline file from the current artifacts after an
intentional change, then commit it).

Usage:
  python scripts/check_roofline_baselines.py             # diff (CI gate)
  python scripts/check_roofline_baselines.py --write     # refresh baselines
  python scripts/check_roofline_baselines.py --allow-missing   # partial
      local artifact sets: baseline cells absent from disk only warn
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "baselines",
    "roofline_baselines.json",
)

TERMS = ("compute_s", "memory_s", "collective_s")


def cell_key(r: dict) -> str:
    return f"{r['arch']}|{r['shape']}|{r['mesh']}|{r.get('tag', '') or ''}"


def summarize(r: dict) -> dict:
    from benchmarks.roofline import roofline_fraction

    rl = r["roofline"]
    out = {t: rl[t] for t in TERMS}
    out["bottleneck"] = rl["bottleneck"]
    out["useful_flops_ratio"] = r["useful_flops_ratio"]
    out["roofline_fraction"] = roofline_fraction(r)
    return out


def rel_delta(a: float, b: float) -> float:
    scale = max(abs(a), abs(b), 1e-12)
    return abs(a - b) / scale


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="relative tolerance per numeric term")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the baseline file from current artifacts")
    ap.add_argument("--allow-missing", action="store_true",
                    help="baseline cells absent from the artifact set warn "
                         "instead of fail (partial local runs)")
    args = ap.parse_args(argv)

    from benchmarks.roofline import load

    rows = load()
    if not rows:
        print("no dry-run artifacts under experiments/dryrun — run "
              "`python -m repro.launch.dryrun` first")
        return 1
    current = {cell_key(r): summarize(r) for r in rows}

    if args.write:
        os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
        with open(BASELINE_PATH, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
        print(f"wrote {len(current)} baseline cells -> {BASELINE_PATH}")
        return 0

    if not os.path.exists(BASELINE_PATH):
        print(f"no baseline file at {BASELINE_PATH} — run with --write first")
        return 1
    with open(BASELINE_PATH) as f:
        baselines = json.load(f)

    failures, missing, drifted = [], [], []
    for key, base in sorted(baselines.items()):
        got = current.get(key)
        if got is None:
            missing.append(key)
            continue
        deltas = {}
        for term in (*TERMS, "useful_flops_ratio", "roofline_fraction"):
            d = rel_delta(base[term], got[term])
            if d > args.rtol:
                deltas[term] = (base[term], got[term], d)
        if base["bottleneck"] != got["bottleneck"]:
            deltas["bottleneck"] = (base["bottleneck"], got["bottleneck"], "")
        if deltas:
            drifted.append((key, deltas))

    new = sorted(set(current) - set(baselines))
    print(f"cells: {len(current)} current, {len(baselines)} baselined, "
          f"{len(new)} new (no baseline)")
    for key in new:
        print(f"  new: {key}")
    for key in missing:
        line = f"  MISSING from artifacts: {key}"
        if args.allow_missing:
            print(line + " (allowed)")
        else:
            print(line)
            failures.append(key)
    for key, deltas in drifted:
        failures.append(key)
        print(f"  DRIFTED: {key}")
        for term, (want, got_v, d) in deltas.items():
            extra = f" ({d * 100:.1f}% off)" if d != "" else ""
            print(f"    {term}: baseline={want} current={got_v}{extra}")

    if failures:
        print(f"\nFAIL: {len(failures)} cell(s) drifted or missing "
              f"(rtol={args.rtol})")
        return 1
    print("\nall baselined roofline cells within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
