"""Region-homed object store (the S3 stand-in).

Stores REAL bytes/arrays in memory, keyed by (key) with a home region.
Transfer latency is modeled from the NetworkModel (size-based), and can be
optionally *enforced* (sleep) so real-JAX overlap experiments see true
wall-clock effects, or just *accounted* (returned) for the simulator.

GeoFF uses the store in two roles (paper §4.1):
  - external data dependencies that steps pre-fetch, and
  - the inter-step payload buffer for public-cloud platforms that don't
    allow direct function-to-function traffic (non-native pre-fetching).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

from repro.core.platform import NetworkModel


@dataclass
class StoredObject:
    value: object
    size_bytes: int
    region: str


def _sizeof(value) -> int:
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    if isinstance(value, (bytes, bytearray)):
        return len(value)
    if isinstance(value, str):
        return len(value.encode())
    if isinstance(value, dict):
        return sum(_sizeof(v) for v in value.values()) or 64
    if isinstance(value, (list, tuple)):
        return sum(_sizeof(v) for v in value) or 64
    return 64


class ObjectStore:
    def __init__(
        self, network: Optional[NetworkModel] = None, enforce_latency: bool = False
    ):
        self.network = network or NetworkModel()
        self.enforce_latency = enforce_latency
        self._objects: dict = {}
        self._lock = threading.Lock()
        self.telemetry = None  # duck-typed TelemetryHub (repro.adapt)
        self.tracer = None  # duck-typed obs.Tracer (span events)
        self.stats = {
            "puts": 0,
            "gets": 0,  # successful GETs (hits; a missing key raises)
            "misses": 0,
            "bytes_in": 0,
            "bytes_out": 0,
            "modeled_get_s": 0.0,
            "modeled_put_s": 0.0,
        }

    def stats_snapshot(self) -> dict:
        """Copy of ``stats`` under the store lock."""
        with self._lock:
            return dict(self.stats)

    # -- api -------------------------------------------------------------------
    def put(self, key: str, value, region: str, from_region: str = "") -> float:
        size = _sizeof(value)
        dt = self.network.transfer_s(from_region or region, region, size)
        with self._lock:
            self._objects[key] = StoredObject(value, size, region)
            self.stats["puts"] += 1
            self.stats["bytes_in"] += size
            self.stats["modeled_put_s"] += dt
        if self.enforce_latency:
            time.sleep(dt)
        if self.telemetry is not None:
            self.telemetry.record_transfer(from_region or region, region, size, dt)
        if self.tracer is not None:
            self.tracer.event(
                "store.put",
                {"key": key, "region": region, "size_bytes": size, "modeled_s": dt},
            )
        return dt

    def get(self, key: str, to_region: str) -> tuple:
        """Returns (value, modeled_transfer_seconds).

        A missing key raises a KeyError that names the key, the requesting
        region, and the keys living under the same prefix — payload-buffer
        keys (``__payload__/{rid}/{edge}``) are one-shot, so a stale or
        mistyped buffer key is otherwise undebuggable."""
        with self._lock:
            obj = self._objects.get(key)
            if obj is None:
                self.stats["misses"] += 1
                prefix = key.rsplit("/", 1)[0] + "/" if "/" in key else key[:4]
                near = sorted(k for k in self._objects if k.startswith(prefix))[:8]
                hint = (
                    f"; keys under {prefix!r}: {near}"
                    if near
                    else f"; store holds {len(self._objects)} keys "
                    f"(sample: {sorted(self._objects)[:5]})"
                )
                raise KeyError(
                    f"object {key!r} not in store (GET from region "
                    f"{to_region!r}){hint}"
                )
            self.stats["gets"] += 1
            self.stats["bytes_out"] += obj.size_bytes
        dt = self.network.transfer_s(obj.region, to_region, obj.size_bytes)
        with self._lock:
            self.stats["modeled_get_s"] += dt
        if self.enforce_latency:
            time.sleep(dt)
        if self.telemetry is not None:
            self.telemetry.record_transfer(obj.region, to_region, obj.size_bytes, dt)
        if self.tracer is not None:
            self.tracer.event(
                "store.get",
                {
                    "key": key,
                    "from_region": obj.region,
                    "to_region": to_region,
                    "size_bytes": obj.size_bytes,
                    "modeled_s": dt,
                },
            )
        return obj.value, dt

    def head(self, key: str) -> Optional[StoredObject]:
        with self._lock:
            return self._objects.get(key)

    def region_of(self, key: str) -> Optional[str]:
        o = self.head(key)
        return o.region if o else None

    def delete(self, key: str):
        with self._lock:
            self._objects.pop(key, None)

    def keys(self, prefix: str = "") -> list:
        with self._lock:
            return [k for k in self._objects if k.startswith(prefix)]

    def __contains__(self, key: str):
        with self._lock:
            return key in self._objects
