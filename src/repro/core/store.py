"""Region-homed object store (the S3 stand-in) + the streaming data plane.

Stores REAL bytes/arrays in memory, keyed by (key) with a home region.
Transfer latency is modeled from the NetworkModel (size-based), and can be
optionally *enforced* (sleep) so real-JAX overlap experiments see true
wall-clock effects, or just *accounted* (returned) for the simulator.

GeoFF uses the store in two roles (paper §4.1):
  - external data dependencies that steps pre-fetch, and
  - the inter-step payload buffer for public-cloud platforms that don't
    allow direct function-to-function traffic (non-native pre-fetching).

The streaming data plane (``StreamConfig``) chunks both roles: a
``put_stream``/``get_stream`` pair moves an object as ``chunks`` wire
pieces — only the first piece pays the link's fixed latency, the rest
pipeline at its bandwidth — so a consumer interleaving the two (the
dataflow engine's cut-through transfer) sees the first byte after one
chunk per hop instead of the whole object per hop. Accounting stays
whole-object: one logical put/get, ``size`` bytes on the region pair
(never ``chunks x size``), modeled seconds summing exactly to the
unchunked transfer.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

from repro.core.platform import NetworkModel


@dataclass(frozen=True)
class StreamConfig:
    """Streaming data plane configuration (chunked, pipelined transfers).

    ``chunks`` is the wire granularity: an object of B bytes moves as
    ``chunks`` pieces of B/chunks, so a consumer can act on the first
    piece while the rest pipeline behind it. ``chunks=1`` is whole-object
    semantics — every path that accepts a StreamConfig is bit-for-bit
    identical to streaming disabled then.

    ``p2p_threshold_bytes`` enables the direct peer-to-peer payload path:
    edges whose payload size (learned per edge from TelemetryHub byte
    EWMAs, falling back to the live payload's size) is at or below the
    threshold skip the object-store round-trip entirely. 0 disables.
    """

    chunks: int = 4
    p2p_threshold_bytes: float = 0.0

    def __post_init__(self):
        if self.chunks < 1:
            raise ValueError(f"StreamConfig.chunks must be >= 1, got {self.chunks}")


@dataclass
class StoredObject:
    value: object
    size_bytes: int
    region: str


def _sizeof(value) -> int:
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    if isinstance(value, (bytes, bytearray)):
        return len(value)
    if isinstance(value, str):
        return len(value.encode())
    if isinstance(value, dict):
        return sum(_sizeof(v) for v in value.values()) or 64
    if isinstance(value, (list, tuple)):
        return sum(_sizeof(v) for v in value) or 64
    return 64


class ObjectStore:
    def __init__(
        self, network: Optional[NetworkModel] = None, enforce_latency: bool = False
    ):
        self.network = network or NetworkModel()
        self.enforce_latency = enforce_latency
        self._objects: dict = {}
        self._lock = threading.Lock()
        self.telemetry = None  # duck-typed TelemetryHub (repro.adapt)
        self.tracer = None  # duck-typed obs.Tracer (span events)
        self.stats = {
            "puts": 0,
            "gets": 0,  # successful GETs (hits; a missing key raises)
            "misses": 0,
            "bytes_in": 0,
            "bytes_out": 0,
            "modeled_get_s": 0.0,
            "modeled_put_s": 0.0,
            # bytes moved per region pair ("src->dst"), both directions;
            # chunked transfers account their object ONCE (no double count)
            "bytes_by_pair": {},
        }

    def stats_snapshot(self) -> dict:
        """Copy of ``stats`` under the store lock."""
        with self._lock:
            out = dict(self.stats)
            out["bytes_by_pair"] = dict(self.stats["bytes_by_pair"])
            return out

    def _account_pair(self, src_region: str, dst_region: str, size: int):
        # callers hold self._lock
        pair = f"{src_region}->{dst_region}"
        by_pair = self.stats["bytes_by_pair"]
        by_pair[pair] = by_pair.get(pair, 0) + size

    def _chunk_dts(self, src_region: str, dst_region: str, size: int, chunks: int):
        """Per-chunk modeled seconds for one hop: the first chunk carries
        the link's fixed (latency) term, every chunk carries size/chunks of
        the bandwidth term — summing exactly to the unchunked transfer."""
        whole = self.network.transfer_s(src_region, dst_region, size)
        base = self.network.transfer_s(src_region, dst_region, 0)
        per_bw = (whole - base) / chunks
        return [per_bw + (base if i == 0 else 0.0) for i in range(chunks)]

    # -- api -------------------------------------------------------------------
    def put(self, key: str, value, region: str, from_region: str = "") -> float:
        size = _sizeof(value)
        src = from_region or region
        dt = self.network.transfer_s(src, region, size)
        with self._lock:
            self._objects[key] = StoredObject(value, size, region)
            self.stats["puts"] += 1
            self.stats["bytes_in"] += size
            self.stats["modeled_put_s"] += dt
            self._account_pair(src, region, size)
        if self.enforce_latency:
            time.sleep(dt)
        if self.telemetry is not None:
            self.telemetry.record_transfer(src, region, size, dt)
        if self.tracer is not None:
            self.tracer.event(
                "store.put",
                {"key": key, "region": region, "size_bytes": size, "modeled_s": dt},
            )
        return dt

    def _resolve_for_get(self, key: str, to_region: str) -> StoredObject:
        """Hit accounting + the named KeyError contract, under the lock."""
        with self._lock:
            obj = self._objects.get(key)
            if obj is None:
                self.stats["misses"] += 1
                prefix = key.rsplit("/", 1)[0] + "/" if "/" in key else key[:4]
                near = sorted(k for k in self._objects if k.startswith(prefix))[:8]
                hint = (
                    f"; keys under {prefix!r}: {near}"
                    if near
                    else f"; store holds {len(self._objects)} keys "
                    f"(sample: {sorted(self._objects)[:5]})"
                )
                raise KeyError(
                    f"object {key!r} not in store (GET from region "
                    f"{to_region!r}){hint}"
                )
            self.stats["gets"] += 1
            self.stats["bytes_out"] += obj.size_bytes
            self._account_pair(obj.region, to_region, obj.size_bytes)
        return obj

    def get(self, key: str, to_region: str) -> tuple:
        """Returns (value, modeled_transfer_seconds).

        A missing key raises a KeyError that names the key, the requesting
        region, and the keys living under the same prefix — payload-buffer
        keys (``__payload__/{rid}/{edge}``) are one-shot, so a stale or
        mistyped buffer key is otherwise undebuggable."""
        obj = self._resolve_for_get(key, to_region)
        dt = self.network.transfer_s(obj.region, to_region, obj.size_bytes)
        with self._lock:
            self.stats["modeled_get_s"] += dt
        if self.enforce_latency:
            time.sleep(dt)
        if self.telemetry is not None:
            self.telemetry.record_transfer(obj.region, to_region, obj.size_bytes, dt)
        if self.tracer is not None:
            self.tracer.event(
                "store.get",
                {
                    "key": key,
                    "from_region": obj.region,
                    "to_region": to_region,
                    "size_bytes": obj.size_bytes,
                    "modeled_s": dt,
                },
            )
        return obj.value, dt

    # -- streaming api (the chunked data plane) --------------------------------
    def put_stream(self, key: str, value, region: str, from_region: str = "", chunks=4):
        """Chunked PUT: stores the object, then returns a generator yielding
        each wire chunk's modeled seconds in order (sleeping them when
        ``enforce_latency`` — so a consumer driving the generator paces at
        chunk granularity). The stored content is atomic (chunks model the
        wire, not the value): an interleaved ``get_stream`` on the same key
        can cut through after the first chunk. Accounting matches ``put``
        exactly — one logical put, ``size`` bytes once on the pair, modeled
        seconds summing to the unchunked transfer — with one transfer
        telemetry record per chunk (chunk-sized, so link fits see byte
        spread)."""
        size = _sizeof(value)
        src = from_region or region
        chunks = max(1, int(chunks))
        dts = self._chunk_dts(src, region, size, chunks)
        with self._lock:
            self._objects[key] = StoredObject(value, size, region)
            self.stats["puts"] += 1
            self.stats["bytes_in"] += size
            self.stats["modeled_put_s"] += sum(dts)
            self._account_pair(src, region, size)
        if self.tracer is not None:
            self.tracer.event(
                "store.put_stream",
                {
                    "key": key,
                    "region": region,
                    "size_bytes": size,
                    "chunks": chunks,
                    "modeled_s": sum(dts),
                },
            )

        def chunk_iter():
            for dt in dts:
                if self.enforce_latency:
                    time.sleep(dt)
                if self.telemetry is not None:
                    self.telemetry.record_transfer(src, region, size / chunks, dt)
                yield dt

        return chunk_iter()

    def get_stream(self, key: str, to_region: str, chunks=4):
        """Chunked GET: resolves the object up front (same accounting and
        KeyError contract as ``get``), then returns a generator yielding
        ``(value_or_None, chunk_seconds)`` per wire chunk — the value
        arrives with the LAST chunk, mirroring a real ranged download.
        Each step sleeps its chunk when ``enforce_latency``."""
        obj = self._resolve_for_get(key, to_region)
        chunks = max(1, int(chunks))
        dts = self._chunk_dts(obj.region, to_region, obj.size_bytes, chunks)
        with self._lock:
            self.stats["modeled_get_s"] += sum(dts)
        if self.tracer is not None:
            self.tracer.event(
                "store.get_stream",
                {
                    "key": key,
                    "from_region": obj.region,
                    "to_region": to_region,
                    "size_bytes": obj.size_bytes,
                    "chunks": chunks,
                    "modeled_s": sum(dts),
                },
            )

        def chunk_iter():
            for i, dt in enumerate(dts):
                if self.enforce_latency:
                    time.sleep(dt)
                if self.telemetry is not None:
                    self.telemetry.record_transfer(
                        obj.region, to_region, obj.size_bytes / chunks, dt
                    )
                yield (obj.value if i == chunks - 1 else None), dt

        return chunk_iter()

    def head(self, key: str) -> Optional[StoredObject]:
        with self._lock:
            return self._objects.get(key)

    def region_of(self, key: str) -> Optional[str]:
        o = self.head(key)
        return o.region if o else None

    def delete(self, key: str):
        with self._lock:
            self._objects.pop(key, None)

    def keys(self, prefix: str = "") -> list:
        with self._lock:
            return [k for k in self._objects if k.startswith(prefix)]

    def __contains__(self, key: str):
        with self._lock:
            return key in self._objects
