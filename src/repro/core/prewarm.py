"""Function pre-warming: the AOT compile cache (GeoFF cold starts, §3.3).

On a TPU platform the FaaS "cold start" is XLA compilation (hundreds of ms
to minutes) plus weight/state materialization. The poke from the
predecessor step triggers ``lower().compile()`` for the successor's step
function in a background thread — taking the cold start off the critical
path exactly as GeoFF pre-warms function instances.

Keys are (step name, platform, abstract input signature), so re-routing a
step to a different platform (ad-hoc recomposition / function shipping)
compiles per platform and subsequent calls are warm.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable

import jax


def signature_of(args_pytree) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(args_pytree)
    return (
        str(treedef),
        tuple(
            (
                tuple(getattr(leaf, "shape", ())),
                str(getattr(leaf, "dtype", type(leaf).__name__)),
            )
            for leaf in leaves
        ),
    )


class CompileCache:
    """AOT compile cache with background pre-warming."""

    def __init__(self, max_workers: int = 4):
        self._cache: dict = {}
        self._inflight: dict = {}
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="prewarm"
        )
        self.stats = {
            "hits": 0,
            "misses": 0,
            "prewarms": 0,
            "compile_s": 0.0,
            "hidden_compile_s": 0.0,
        }
        self.telemetry = None  # duck-typed TelemetryHub (repro.adapt)
        self.tracer = None  # duck-typed obs.Tracer (span events)

    def stats_snapshot(self) -> dict:
        """Copy of ``stats`` under the cache lock (safe to read while
        compiles land on other threads)."""
        with self._lock:
            return dict(self.stats)

    def _key(self, name: str, platform: str, args) -> tuple:
        return (name, platform, signature_of(args))

    def _compile(self, fn: Callable, args, donate=()):
        t0 = time.perf_counter()
        jitted = jax.jit(fn, donate_argnums=donate)
        compiled = jitted.lower(*args).compile()
        return compiled, time.perf_counter() - t0

    def warm(
        self, name: str, platform: str, fn: Callable, abstract_args, donate=()
    ) -> Future:
        """Start compiling in the background (the poke path). Idempotent."""
        key = self._key(name, platform, abstract_args)
        tr = self.tracer
        # capture the caller's bound span (the poke span) so the compile
        # completion event lands on it even though the job runs on a pool
        # thread
        span = tr.current_span() if tr is not None else None
        with self._lock:
            if key in self._cache:
                if tr is not None:
                    tr.event("prewarm.already_warm", {"fn": name, "platform": platform})
                f = Future()
                f.set_result(self._cache[key])
                return f
            if key in self._inflight:
                return self._inflight[key]
            if tr is not None:
                tr.event("prewarm.start", {"fn": name, "platform": platform})

            def job():
                compiled, dt = self._compile(fn, abstract_args, donate)
                with self._lock:
                    self._cache[key] = compiled
                    self._inflight.pop(key, None)
                    self.stats["prewarms"] += 1
                    self.stats["hidden_compile_s"] += dt
                if tr is not None and span is not None:
                    with tr.bind(span):
                        tr.event(
                            "prewarm.done",
                            {"fn": name, "platform": platform, "compile_s": dt},
                        )
                return compiled

            fut = self._pool.submit(job)
            self._inflight[key] = fut
            return fut

    def get(self, name: str, platform: str, fn: Callable, args, donate=()) -> object:
        """Blocking fetch (the payload path): hit, join in-flight, or
        compile cold (a cold start — counted in stats)."""
        key = self._key(name, platform, args)
        tel = self.telemetry
        tr = self.tracer
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self.stats["hits"] += 1
            fut = self._inflight.get(key)
        if hit is not None:
            if tel is not None:
                tel.record_warm_hit(name, platform)
            if tr is not None:
                tr.event("compile.hit", {"fn": name, "platform": platform})
            return hit
        if fut is not None:
            compiled = fut.result()
            with self._lock:
                self.stats["hits"] += 1
            if tel is not None:
                tel.record_warm_hit(name, platform)
            if tr is not None:
                tr.event("compile.joined_inflight", {"fn": name, "platform": platform})
            return compiled
        compiled, dt = self._compile(fn, args, donate)
        with self._lock:
            self._cache[key] = compiled
            self.stats["misses"] += 1
            self.stats["compile_s"] += dt
        if tel is not None:
            # the compile wall time is the cold-start cost placement wants
            tel.record_cold_start(name, platform, dt)
        if tr is not None:
            tr.event(
                "compile.cold", {"fn": name, "platform": platform, "compile_s": dt}
            )
        return compiled

    def is_warm(self, name: str, platform: str, args) -> bool:
        with self._lock:
            return self._key(name, platform, args) in self._cache

    def shutdown(self):
        self._pool.shutdown(wait=False, cancel_futures=True)
