"""Optimized pre-fetch timing (paper §5.5 — future work, implemented here).

GeoFF pokes the successor as soon as the current step is invoked. That
minimizes workflow duration but maximizes double-billing: if prefetch+warm
finish long before the payload arrives, the successor's instance sits idle
(billed). The paper suggests learning the timing from monitoring data.

``PokeTimingController`` keeps EWMA estimates of (a) the predecessor's
handler duration and (b) the successor's warm+fetch duration, and delays the
poke by  max(0, est_compute - est_prepare - margin)  so preparation finishes
just as the payload arrives. ``margin`` trades duration risk against
double-billing; the controller also reports both costs so the trade-off is
measurable (benchmarks/timing_bench.py).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field


class EWMA:
    def __init__(self, alpha: float = 0.25, init: float = 0.0):
        self.alpha = alpha
        self.value = init
        self.n = 0

    def update(self, x: float) -> float:
        self.value = (
            x if self.n == 0 else (1 - self.alpha) * self.value + self.alpha * x
        )
        self.n += 1
        return self.value


@dataclass
class StepTimings:
    compute: EWMA = field(default_factory=EWMA)
    prepare: EWMA = field(default_factory=EWMA)  # warm + prefetch duration
    slack: EWMA = field(default_factory=EWMA)  # payload_arrival - prepare_done
    double_billed: float = 0.0  # accumulated idle seconds
    exposed_wait: float = 0.0  # accumulated late seconds


class PokeTimingController:
    """mode='eager'  — paper-faithful: poke at invocation (delay 0).
    mode='learned' — §5.5: delay the poke to minimize double-billing."""

    def __init__(
        self, mode: str = "eager", margin_s: float = 0.05, alpha: float = 0.25
    ):
        assert mode in ("eager", "learned")
        self.mode = mode
        self.margin_s = margin_s
        self.alpha = alpha
        self._timings: dict = {}
        self._lock = threading.Lock()

    def _entry(self, step_name: str) -> StepTimings:
        with self._lock:
            if step_name not in self._timings:
                # every EWMA — compute, prepare AND slack — must see the
                # configured alpha (slack silently fell back to the default)
                self._timings[step_name] = StepTimings(
                    EWMA(self.alpha), EWMA(self.alpha), EWMA(self.alpha)
                )
            return self._timings[step_name]

    def poke_delay(self, pred_name: str, succ_name: str) -> float:
        if self.mode == "eager":
            return 0.0
        succ = self._entry(succ_name)
        if succ.slack.n > 0:
            # best estimator: observed idle gap (payload - prepare_done),
            # which accounts for cascaded pokes and upstream dwell
            return max(0.0, succ.slack.value - self.margin_s)
        pred = self._entry(pred_name)
        if pred.compute.n == 0 or succ.prepare.n == 0:
            return 0.0  # no data yet -> eager
        return max(0.0, pred.compute.value - succ.prepare.value - self.margin_s)

    def record_compute(self, step_name: str, seconds: float):
        self._entry(step_name).compute.update(seconds)

    def record_prepare(self, step_name: str, seconds: float):
        self._entry(step_name).prepare.update(seconds)

    def record_slack(self, step_name: str, prepared_early_s: float):
        """+ = instance idle (double-billed); - = payload waited. Feeds the
        learned delay: next poke shifts by ~EWMA(slack) - margin."""
        e = self._entry(step_name)
        e.slack.update(prepared_early_s)
        if prepared_early_s >= 0:
            e.double_billed += prepared_early_s
        else:
            e.exposed_wait += -prepared_early_s

    def report(self) -> dict:
        with self._lock:
            out = {}
            for k, v in self._timings.items():
                out[k] = {
                    "compute_s": v.compute.value,
                    "prepare_s": v.prepare.value,
                    "double_billed_s": v.double_billed,
                    "exposed_wait_s": v.exposed_wait,
                }
            return out
