"""Optimized pre-fetch timing (paper §5.5 — future work, implemented here).

GeoFF pokes the successor as soon as the current step is invoked. That
minimizes workflow duration but maximizes double-billing: if prefetch+warm
finish long before the payload arrives, the successor's instance sits idle
(billed). The paper suggests learning the timing from monitoring data.

``PokeTimingController`` keeps its estimates at two granularities:

  - per STEP: EWMAs of the handler's compute duration and its warm+fetch
    (prepare) duration — properties of the function on its platform;
  - per EDGE ``(pred -> succ)``: the observed slack, i.e. payload arrival
    minus prepare completion. A fan-in node has several in-edges whose
    upstream dwell times differ, so one blended per-step number would delay
    every predecessor's poke by the same amount; keying slack per edge lets
    each predecessor learn its own gap.

The poke along edge ``(pred, succ)`` is delayed by the edge's
``EWMA(slack) - margin`` once slack observations exist (falling back to the
per-step estimate ``est_compute(pred) - est_prepare(succ) - margin``), so
preparation finishes just as that predecessor's payload arrives. ``margin``
trades duration risk against double-billing; both costs are accumulated per
edge and surfaced via ``report()`` so the trade-off is measurable
(benchmarks/timing_bench.py).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field


class EWMA:
    def __init__(self, alpha: float = 0.25, init: float = 0.0):
        self.alpha = alpha
        self.value = init
        self.n = 0

    def update(self, x: float) -> float:
        self.value = (
            x if self.n == 0 else (1 - self.alpha) * self.value + self.alpha * x
        )
        self.n += 1
        return self.value

    def update_many(self, mean: float, count: int) -> float:
        """Fold a batch of ``count`` observations with the given ``mean``
        in one step (the vectorized simulator reports aggregates, not n
        singles): the estimate moves toward the batch mean with the weight
        ``count`` sequential updates would have carried in total,
        ``1 - (1 - alpha) ** count``."""
        if count <= 0:
            return self.value
        if self.n == 0:
            self.value = mean
        else:
            w = 1.0 - (1.0 - self.alpha) ** count
            self.value = (1 - w) * self.value + w * mean
        self.n += count
        return self.value


@dataclass
class StepTimings:
    compute: EWMA = field(default_factory=EWMA)
    prepare: EWMA = field(default_factory=EWMA)  # warm + prefetch duration


@dataclass
class EdgeTimings:
    slack: EWMA = field(default_factory=EWMA)  # payload_arrival - prepare_done
    double_billed: float = 0.0  # accumulated idle seconds on this edge
    exposed_wait: float = 0.0  # accumulated late seconds on this edge


class PokeTimingController:
    """mode='eager'  — paper-faithful: poke at invocation (delay 0).
    mode='learned' — §5.5: delay the poke to minimize double-billing."""

    def __init__(
        self, mode: str = "eager", margin_s: float = 0.05, alpha: float = 0.25
    ):
        assert mode in ("eager", "learned")
        self.mode = mode
        self.margin_s = margin_s
        self.alpha = alpha
        self._steps: dict = {}  # step_name -> StepTimings
        self._edges: dict = {}  # (pred_name, succ_name) -> EdgeTimings
        self._lock = threading.Lock()

    def _step(self, step_name: str) -> StepTimings:
        with self._lock:
            if step_name not in self._steps:
                # every EWMA must see the configured alpha
                self._steps[step_name] = StepTimings(
                    EWMA(self.alpha),
                    EWMA(self.alpha),
                )
            return self._steps[step_name]

    def _edge(self, pred_name: str, succ_name: str) -> EdgeTimings:
        key = (pred_name, succ_name)
        with self._lock:
            if key not in self._edges:
                self._edges[key] = EdgeTimings(EWMA(self.alpha))
            return self._edges[key]

    def poke_delay(self, pred_name: str, succ_name: str) -> float:
        if self.mode == "eager":
            return 0.0
        edge = self._edge(pred_name, succ_name)
        if edge.slack.n > 0:
            # best estimator: this edge's observed idle gap (payload arrival
            # minus prepare completion), which accounts for cascaded pokes
            # and the specific predecessor's dwell
            return max(0.0, edge.slack.value - self.margin_s)
        pred = self._step(pred_name)
        succ = self._step(succ_name)
        if pred.compute.n == 0 or succ.prepare.n == 0:
            return 0.0  # no data yet -> eager
        return max(0.0, pred.compute.value - succ.prepare.value - self.margin_s)

    def record_compute(self, step_name: str, seconds: float):
        self._step(step_name).compute.update(seconds)

    def record_prepare(self, step_name: str, seconds: float):
        self._step(step_name).prepare.update(seconds)

    def record_slack(self, pred_name: str, succ_name: str, prepared_early_s: float):
        """+ = instance idle (double-billed); - = payload waited. Recorded
        relative to the UNDELAYED poke (callers add the applied delay back),
        so the EWMA converges to the true gap and the learned delay tracks
        it instead of chasing its own feedback."""
        e = self._edge(pred_name, succ_name)
        e.slack.update(prepared_early_s)
        if prepared_early_s >= 0:
            e.double_billed += prepared_early_s
        else:
            e.exposed_wait += -prepared_early_s

    def report(self) -> dict:
        with self._lock:
            steps = {
                k: {"compute_s": v.compute.value, "prepare_s": v.prepare.value}
                for k, v in self._steps.items()
            }
            edges = {
                f"{a}->{b}": {
                    "slack_s": e.slack.value,
                    "double_billed_s": e.double_billed,
                    "exposed_wait_s": e.exposed_wait,
                }
                for (a, b), e in self._edges.items()
            }
            return {"steps": steps, "edges": edges}
