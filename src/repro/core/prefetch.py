"""Data pre-fetching (GeoFF §3.3).

A step's external data dependencies don't depend on its predecessor's
output, so the middleware fetches them while the predecessor is still
computing: ``Prefetcher.start`` returns futures (object-store GET +
``jax.device_put`` onto the step's platform), and ``join`` blocks only on
whatever hasn't arrived when the payload shows up — in the ideal case,
nothing (the paper's Figure 2, workflow B).

``DoubleBuffer`` reuses the same machinery for the training data pipeline:
batch k+1 is fetched/transferred while step k computes.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, Optional

import jax

from repro.core.store import ObjectStore, StreamConfig
from repro.core.workflow import DataRef


class Prefetcher:
    def __init__(
        self,
        store: ObjectStore,
        max_workers: int = 8,
        stream: Optional[StreamConfig] = None,
    ):
        self.store = store
        self.stream = stream  # chunked fetches when set with chunks > 1
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="prefetch"
        )
        self.stats = {
            "prefetched": 0,
            "cold_fetches": 0,
            "hidden_s": 0.0,
            "exposed_s": 0.0,
            "streamed": 0,  # fetches that went through get_stream
            "first_byte_s": 0.0,  # summed modeled time-to-first-chunk
        }
        self._lock = threading.Lock()
        self.telemetry = None  # duck-typed TelemetryHub (repro.adapt)
        self.tracer = None  # duck-typed obs.Tracer (span events)

    def stats_snapshot(self) -> dict:
        """Copy of ``stats`` under the lock (joins land on pool threads)."""
        with self._lock:
            return dict(self.stats)

    def start(self, deps: Iterable[DataRef], to_region: str, device=None) -> dict:
        """Kick off async fetches. Returns {key: Future[(value, modeled_s)]}."""
        tr = self.tracer
        # capture the caller's bound span (the poke span): the job runs on
        # a pool thread, so rebind there to attach fetch events to it
        span = tr.current_span() if tr is not None else None
        futs = {}
        for ref in deps:
            if tr is not None:
                tr.event("prefetch.start", {"key": ref.key, "to_region": to_region})

            def job(r=ref):
                stream = self.stream
                if stream is not None and stream.chunks > 1:
                    # chunked fetch: the generator paces per wire chunk
                    # (sleeping when the store enforces latency), so the
                    # fetch overlaps whatever else runs on this pool —
                    # same total seconds, earlier first byte
                    value, dt, first = None, 0.0, None
                    for v, cdt in self.store.get_stream(
                        r.key, to_region, chunks=stream.chunks
                    ):
                        dt += cdt
                        if first is None:
                            first = dt
                        if v is not None:
                            value = v
                    with self._lock:
                        self.stats["streamed"] += 1
                        self.stats["first_byte_s"] += first or 0.0
                else:
                    value, dt = self.store.get(r.key, to_region)
                if device is not None and hasattr(value, "shape"):
                    value = jax.device_put(value, device)
                if self.telemetry is not None:
                    self.telemetry.record_fetch(r.key, to_region, dt)
                if tr is not None and span is not None:
                    with tr.bind(span):
                        tr.event(
                            "prefetch.done",
                            {"key": r.key, "to_region": to_region, "modeled_s": dt},
                        )
                return value, dt

            futs[ref.key] = self._pool.submit(job)
        return futs

    def join(self, futs: dict) -> tuple:
        """Wait for all fetches. Returns ({key: value}, exposed_wait_s,
        modeled_transfer_s) — exposed_wait is what the critical path saw."""
        t0 = time.perf_counter()
        out, modeled = {}, 0.0
        for k, f in futs.items():
            v, dt = f.result()
            out[k] = v
            modeled += dt
        exposed = time.perf_counter() - t0
        with self._lock:
            self.stats["prefetched"] += len(futs)
            self.stats["exposed_s"] += exposed
            self.stats["hidden_s"] += max(0.0, modeled - exposed)
        return out, exposed, modeled

    def fetch_blocking(
        self, deps: Iterable[DataRef], to_region: str, device=None
    ) -> tuple:
        """The baseline (no pre-fetch) path: sequential download."""
        tr = self.tracer
        out, total = {}, 0.0
        for ref in deps:
            value, dt = self.store.get(ref.key, to_region)
            if device is not None and hasattr(value, "shape"):
                value = jax.device_put(value, device)
            if self.telemetry is not None:
                self.telemetry.record_fetch(ref.key, to_region, dt)
            if tr is not None:
                tr.event(
                    "fetch.cold",
                    {"key": ref.key, "to_region": to_region, "modeled_s": dt},
                )
            out[ref.key] = value
            total += dt
        with self._lock:
            self.stats["cold_fetches"] += len(out)
        return out, total

    def shutdown(self):
        self._pool.shutdown(wait=False, cancel_futures=True)


class DoubleBuffer:
    """Prefetch iterator: always keeps `depth` items in flight.

    The produce fn runs on a background thread (host->device transfer,
    decompression, ...) so consumption overlaps production — the data
    pipeline's version of GeoFF pre-fetching.
    """

    def __init__(
        self, it: Iterable, depth: int = 2, transform: Optional[Callable] = None
    ):
        self._it = iter(it)
        self._transform = transform
        self._pool = ThreadPoolExecutor(max_workers=1, thread_name_prefix="databuf")
        self._queue = []
        self._depth = depth
        for _ in range(depth):
            self._enqueue()

    def _produce(self):
        item = next(self._it)
        return self._transform(item) if self._transform else item

    def _enqueue(self):
        self._queue.append(self._pool.submit(self._produce))

    def __iter__(self):
        return self

    def __next__(self):
        if not self._queue:
            raise StopIteration
        fut = self._queue.pop(0)
        try:
            item = fut.result()
        except StopIteration:
            self._pool.shutdown(wait=False)
            raise
        self._enqueue()
        return item
