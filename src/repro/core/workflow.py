"""Per-request workflow specifications (GeoFF §3.2).

A ``WorkflowSpec`` is runtime DATA attached to every invocation — not a
deployment artifact. The client (or a routing optimizer, §5.4) decides the
route per request; the same deployed steps serve different routes without
redeployment ("ad-hoc recomposition"). JSON round-trips so a spec can travel
inside the invocation payload exactly as in the paper.

Each step names: the function to run, the platform to run it on, its
external data dependencies (pre-fetchable), and whether its successor should
be poked (pre-warm + pre-fetch) when this step starts.

Execution-wise a chain is the degenerate DAG: ``Deployment.run`` lifts the
spec via ``repro.dag.spec.DagSpec.from_chain`` onto the dataflow engine, so
this module stays pure data — the protocol lives in one place.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Optional


@dataclass(frozen=True)
class DataRef:
    """A reference to an object in a region-homed object store."""

    key: str
    store_region: str = ""  # "" = wherever the key currently lives
    size_bytes: int = 0  # advisory (placement/pre-fetch planning)

    def to_json(self):
        return asdict(self)

    @staticmethod
    def from_json(d):
        return DataRef(**d)


@dataclass(frozen=True)
class StepSpec:
    name: str  # function name (must be deployed)
    platform: str  # platform id to invoke on (per-request!)
    data_deps: tuple = ()  # tuple[DataRef] — pre-fetchable inputs
    prefetch: bool = True  # poke successor -> prewarm + prefetch
    sync: bool = False  # synchronous call (native platforms only)
    params: dict = field(default_factory=dict)  # free-form step config

    def to_json(self):
        return {
            "name": self.name,
            "platform": self.platform,
            "data_deps": [d.to_json() for d in self.data_deps],
            "prefetch": self.prefetch,
            "sync": self.sync,
            "params": self.params,
        }

    @staticmethod
    def from_json(d):
        return StepSpec(
            name=d["name"],
            platform=d["platform"],
            data_deps=tuple(DataRef.from_json(x) for x in d.get("data_deps", ())),
            prefetch=d.get("prefetch", True),
            sync=d.get("sync", False),
            params=d.get("params", {}),
        )


@dataclass(frozen=True)
class WorkflowSpec:
    """A chain of steps — the degenerate DAG the dataflow core executes."""

    steps: tuple  # tuple[StepSpec]
    workflow_id: str = ""

    def __post_init__(self):
        assert self.steps, "empty workflow"

    def successor(self, index: int) -> Optional[StepSpec]:
        return self.steps[index + 1] if index + 1 < len(self.steps) else None

    def reroute(self, step_name: str, platform: str) -> "WorkflowSpec":
        """Ad-hoc recomposition: same workflow, one step moved (no redeploy)."""
        steps = tuple(
            StepSpec(s.name, platform, s.data_deps, s.prefetch, s.sync, s.params)
            if s.name == step_name
            else s
            for s in self.steps
        )
        return WorkflowSpec(steps, self.workflow_id)

    def to_json(self) -> str:
        return json.dumps(
            {
                "workflow_id": self.workflow_id,
                "steps": [s.to_json() for s in self.steps],
            }
        )

    @staticmethod
    def from_json(s: str) -> "WorkflowSpec":
        d = json.loads(s)
        return WorkflowSpec(
            tuple(StepSpec.from_json(x) for x in d["steps"]),
            d.get("workflow_id", ""),
        )


@dataclass
class Invocation:
    """What travels between steps: payload + the spec + bookkeeping."""

    spec: WorkflowSpec
    step_index: int
    payload: object
    request_id: str = ""
    t_start: float = 0.0  # workflow start (for end-to-end duration)
