"""The GeoFF chain deployer — a thin facade over the dataflow core.

This repo carries exactly ONE implementation of the choreography protocol
(poke -> prepare off the critical path -> payload): the dataflow engine in
``repro.dag.engine``. The paper's workflows are chains (§3.2), and a chain
is the degenerate DAG — each step's single successor is one edge — so
``Deployment`` keeps the paper-shaped client API (``run(WorkflowSpec)`` ->
``StepResult`` with a per-step timeline) and lifts every request through
``DagSpec.from_chain`` onto ``DagDeployment``'s dataflow loop.

Everything the chain middleware used to do itself happens in the engine,
semantics unchanged:

  1. invoking a step POKES its successor (two-phase protocol, phase 1),
     triggering pre-warm (AOT compile) and data pre-fetch OFF the critical
     path; pokes cascade so every step prepares as soon as the workflow is
     invoked (§5.5 eager default; the learned controller delays per edge);
  2. the step joins its own prepared futures, runs the handler, and sends
     the PAYLOAD (phase 2) — directly on native platforms, buffered through
     the object store on public-cloud platforms (§4.1), one one-shot
     ``__payload__`` key per edge, deleted after the GET;
  3. the deployer packages (handler, wrapper, middleware) per
     (function, platform), so one function definition runs anywhere
     (federated deployment, §3.1) — ``deploy`` is inherited unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.dag.engine import DagDeployment, DeployedFn  # noqa: F401 (compat)
from repro.dag.spec import DagSpec
from repro.core.workflow import WorkflowSpec


@dataclass
class StepResult:
    request_id: str
    outputs: object
    timeline: dict  # step -> {phase: seconds}
    total_s: float


class Deployment(DagDeployment):
    """The GeoFF deployer + client entry point for chain workflows.

    Inherits the deployment surface (``deploy``, ``shutdown``, context
    manager) from the dataflow engine; only ``run`` differs, translating
    the chain ``WorkflowSpec`` request/response shapes.
    """

    def run(
        self, spec: WorkflowSpec, payload, timeout_s: Optional[float] = None
    ) -> StepResult:
        """Invoke the first step with the input and the workflow spec —
        exactly what a GeoFF client sends. Executes on the dataflow core
        (chain = degenerate DAG); the result keeps the chain-era shape,
        including the old synchronous semantics of waiting as long as the
        steps take (pass ``timeout_s`` to bound it)."""
        result = super().run(DagSpec.from_chain(spec), payload, timeout_s)
        return StepResult(
            result.request_id, result.outputs, result.timeline, result.total_s
        )
