"""The GeoFF choreography middleware (paper §3.2–§3.3).

One ``Middleware`` instance is co-deployed with every function; there is NO
central orchestrator. A step's middleware:

  1. receives an ``Invocation`` (payload + per-request WorkflowSpec),
  2. immediately POKES its successor (two-phase protocol, phase 1): an
     argument-less signal that triggers the successor's pre-warm (AOT
     compile) and data pre-fetch, both OFF the critical path,
  3. fetches this step's own data deps (already in flight if this step was
     itself poked), runs the handler,
  4. sends the PAYLOAD (phase 2) to the successor — directly when the
     platform allows synchronous calls (native platforms, e.g. our
     tinyFaaS-analogue edge node), or buffered through the object store
     (public-cloud platforms, paper §4.1).

``Deployment`` is the deployer: it packages (handler, wrapper, middleware)
per (function, platform) from a deployment specification, so one function
definition runs anywhere (federated deployment, §3.1).

Chains only: fan-out/fan-in workflows run on the dataflow engine
(repro.dag.engine), which reuses the same pieces.
"""

from __future__ import annotations

import threading
import time
import uuid
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.platform import Platform, PlatformRegistry, PlatformWrapper
from repro.core.prefetch import Prefetcher
from repro.core.prewarm import CompileCache
from repro.core.store import ObjectStore
from repro.core.timing import PokeTimingController
from repro.core.workflow import Invocation, WorkflowSpec


@dataclass
class StepResult:
    request_id: str
    outputs: object
    timeline: dict  # step -> {phase: seconds}
    total_s: float


@dataclass
class _DeployedFn:
    name: str
    platform: Platform
    wrapper: PlatformWrapper
    handler: Callable  # handler(payload, data: dict) -> out
    abstract_args: Optional[object] = None  # for pre-warm (compile) keys
    compile_fn: Optional[Callable] = None  # jit-able step body (optional)


class Middleware:
    """The per-function choreography middleware."""

    def __init__(
        self,
        deployed: _DeployedFn,
        registry: PlatformRegistry,
        store: ObjectStore,
        cache: CompileCache,
        prefetcher: Prefetcher,
        timing: PokeTimingController,
        resolve: Callable,
    ):
        self.fn = deployed
        self.registry = registry
        self.store = store
        self.cache = cache
        self.prefetcher = prefetcher
        self.timing = timing
        self._resolve = resolve  # (name, platform) -> Middleware
        self._poked: dict = {}  # request_id -> (warm_fut, fetch_futs, t)
        self._lock = threading.Lock()

    # -- phase 1: poke ---------------------------------------------------------
    def poke(self, request_id: str, wf: WorkflowSpec, step_index: int):
        """Argument-less pre-warm + pre-fetch trigger. Non-blocking.

        Pokes CASCADE: a poked middleware immediately pokes its own
        successor, so every step in the chain starts preparing as soon as
        the workflow is invoked (paper §5.5 — minimum duration, accepting
        the double-billing upper bound; the learned timing controller is
        the knob that trades this back).
        """
        t0 = time.perf_counter()
        spec = wf.steps[step_index]
        warm_fut = None
        if self.fn.compile_fn is not None and self.fn.abstract_args is not None:
            warm_fut = self.cache.warm(
                self.fn.name,
                self.fn.platform.name,
                self.fn.compile_fn,
                self.fn.abstract_args,
            )
        fetch_futs = {}
        if spec.data_deps:
            fetch_futs = self.prefetcher.start(spec.data_deps, self.fn.platform.region)
        with self._lock:
            self._poked[request_id] = (warm_fut, fetch_futs, t0)
        succ = wf.successor(step_index)
        if succ is not None and succ.prefetch:
            succ_mw = self._resolve(succ.name, succ.platform)
            self.registry.executor(self.fn.platform.name).submit(
                succ_mw.poke, request_id, wf, step_index + 1
            )

    # -- phase 2: payload ------------------------------------------------------
    def invoke(self, inv: Invocation) -> object:
        """Run this step, then hand off to the successor. Returns the final
        workflow output (chains propagate the return value backwards)."""
        spec = inv.spec.steps[inv.step_index]
        succ = inv.spec.successor(inv.step_index)
        rid = inv.request_id
        timeline = {}

        # poke the successor NOW (GeoFF: as early as possible; the learned
        # controller may delay it, §5.5). If this step was itself poked the
        # cascade already covered the successor — poking again is idempotent.
        if succ is not None and succ.prefetch:
            succ_mw = self._resolve(succ.name, succ.platform)
            delay = self.timing.poke_delay(spec.name, succ.name)

            def do_poke():
                if delay > 0:
                    time.sleep(delay)
                succ_mw.poke(rid, inv.spec, inv.step_index + 1)

            self.registry.executor(self.fn.platform.name).submit(do_poke)

        # cold start (compile) — hidden iff this step was poked
        t0 = time.perf_counter()
        with self._lock:
            poked = self._poked.pop(rid, None)
        if self.fn.compile_fn is not None and self.fn.abstract_args is not None:
            self.cache.get(
                self.fn.name,
                self.fn.platform.name,
                self.fn.compile_fn,
                self.fn.abstract_args,
            )
        timeline["warm_s"] = time.perf_counter() - t0

        # data: join prefetch futures, or fetch cold (baseline path)
        t0 = time.perf_counter()
        if poked is not None and poked[1]:
            data, exposed, modeled = self.prefetcher.join(poked[1])
            self.timing.record_slack(
                spec.name, (time.perf_counter() - poked[2]) - modeled
            )
        elif spec.data_deps:
            data, _ = self.prefetcher.fetch_blocking(
                spec.data_deps, self.fn.platform.region
            )
        else:
            data = {}
        timeline["fetch_s"] = time.perf_counter() - t0
        self.timing.record_prepare(spec.name, timeline["warm_s"] + timeline["fetch_s"])

        # handler
        t0 = time.perf_counter()
        out = self.fn.wrapper(inv.payload, data)
        dt = time.perf_counter() - t0
        timeline["compute_s"] = dt
        self.timing.record_compute(spec.name, dt)

        # hand off
        if succ is None:
            return out, {spec.name: timeline}
        succ_mw = self._resolve(succ.name, succ.platform)
        succ_inv = Invocation(inv.spec, inv.step_index + 1, out, rid, inv.t_start)
        src, dst = self.fn.platform, succ_mw.fn.platform
        if not (dst.allows_sync and dst.native_prefetch):
            # public-cloud path: buffer the payload via the object store;
            # the key is a one-shot buffer — delete after the GET so
            # __payload__ keys never accumulate across requests
            key = f"__payload__/{rid}/{succ.name}"
            self.store.put(key, out, dst.region, from_region=src.region)
            value, _ = self.store.get(key, dst.region)
            self.store.delete(key)
            succ_inv = Invocation(inv.spec, inv.step_index + 1, value, rid, inv.t_start)
        result, sub_timeline = succ_mw.invoke(succ_inv)
        sub_timeline[spec.name] = timeline
        return result, sub_timeline


class Deployment:
    """The GeoFF deployer + client entry point."""

    def __init__(
        self,
        registry: Optional[PlatformRegistry] = None,
        store: Optional[ObjectStore] = None,
        timing_mode: str = "eager",
    ):
        self.registry = registry or PlatformRegistry()
        self.store = store or ObjectStore(self.registry.network)
        self.cache = CompileCache()
        self.prefetcher = Prefetcher(self.store)
        self.timing = PokeTimingController(timing_mode)
        self._functions: dict = {}  # (name, platform) -> Middleware

    # -- deployer (§3.1) -------------------------------------------------------
    def deploy(
        self,
        name: str,
        handler: Callable,
        platforms,
        abstract_args=None,
        compile_fn=None,
    ):
        """Deploy one platform-independent handler to N platforms."""
        for pname in platforms:
            plat = self.registry.get(pname)
            wrapper = PlatformWrapper(plat, handler, name)
            fn = _DeployedFn(name, plat, wrapper, handler, abstract_args, compile_fn)
            self._functions[(name, pname)] = Middleware(
                fn,
                self.registry,
                self.store,
                self.cache,
                self.prefetcher,
                self.timing,
                self._resolve,
            )
        return self

    def _resolve(self, name: str, platform: str) -> Middleware:
        try:
            return self._functions[(name, platform)]
        except KeyError:
            raise KeyError(
                f"function {name!r} is not deployed on {platform!r}; "
                f"deployed: {sorted(self._functions)}"
            ) from None

    # -- client ----------------------------------------------------------------
    def run(self, spec: WorkflowSpec, payload) -> StepResult:
        """Invoke the first step with the input and the workflow spec —
        exactly what a GeoFF client sends."""
        rid = uuid.uuid4().hex[:12]
        first = spec.steps[0]
        mw = self._resolve(first.name, first.platform)
        t0 = time.perf_counter()
        out, timeline = mw.invoke(Invocation(spec, 0, payload, rid, t0))
        return StepResult(rid, out, timeline, time.perf_counter() - t0)

    def shutdown(self):
        self.registry.shutdown()
        self.cache.shutdown()
        self.prefetcher.shutdown()
