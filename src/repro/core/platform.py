"""Platforms and platform wrappers (GeoFF §3.1).

A ``Platform`` is a named compute location a step can be deployed to: a TPU
pod (a mesh slice), a single host, or a CPU "edge" node — the analogue of
AWS Lambda / Google Cloud Functions / tinyFaaS in the paper. Platforms carry
a region and capability flags; a ``NetworkModel`` gives inter-region
latency/bandwidth (used by the placement optimizer and the simulator).

The ``PlatformWrapper`` is the paper's platform-specific wrapper: it adapts
a mesh-polymorphic step function to a concrete platform (binds mesh +
sharding rules, stages inputs onto the platform's devices) so the SAME
function code deploys anywhere. The paper reports < 1 ms wrapper overhead
(§4.1); benchmarks/wrapper_overhead.py reproduces that measurement.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Optional

from repro.dist import sharding as shd


@dataclass(frozen=True)
class Platform:
    name: str
    region: str
    kind: str = "cloud"  # cloud | private | edge
    native_prefetch: bool = False  # provider-side poke interception (§4.4)
    allows_sync: bool = True  # public clouds: async only (§4.1)
    cold_start_s: float = 0.5  # modeled cold-start latency
    mesh: Optional[object] = None  # jax Mesh (None = default device)
    rules: Optional[object] = None  # ShardingRules for this platform

    def executor_key(self):
        return self.name


def bind_sharding(
    platform: Platform, mesh=None, rules=None, workload: str = "decode"
) -> Platform:
    """Attach a mesh + sharding rules to a platform (heterogeneous federation).

    Every platform in a GeoFF deployment can carry its own placement config:
    an edge node is a single device (mesh dropped, everything replicated), a
    cloud region runs the logical-axis rules for its workload — multi-pod
    rules when the mesh has a "pod" axis. The PlatformWrapper then binds the
    pair as the ambient ``use_sharding`` context around every step it runs,
    so the SAME step function deploys to either.
    """
    if platform.kind == "edge":
        mesh = None  # edge nodes are single-device
    if rules is None:
        multi_pod = mesh is not None and "pod" in mesh.shape
        rules = shd.rules_for_platform(platform.kind, workload, multi_pod=multi_pod)
    return dataclasses.replace(platform, mesh=mesh, rules=rules)


class NetworkModel:
    """Inter-region RTT/bandwidth. Symmetric; defaults are public-cloud-ish
    medians (calibrated further in core/simulator.py)."""

    def __init__(
        self, rtt_s=None, bandwidth_Bps=None, default_rtt=0.09, default_bw=50e6
    ):
        self._rtt = dict(rtt_s or {})
        self._bw = dict(bandwidth_Bps or {})
        self.default_rtt = default_rtt
        self.default_bw = default_bw

    @staticmethod
    def _key(a, b):
        return (min(a, b), max(a, b))

    def set_link(self, a, b, rtt_s, bw_Bps):
        self._rtt[self._key(a, b)] = rtt_s
        self._bw[self._key(a, b)] = bw_Bps

    def rtt(self, a, b):
        if a == b:
            return 0.001
        return self._rtt.get(self._key(a, b), self.default_rtt)

    def bandwidth(self, a, b):
        if a == b:
            return 10e9
        return self._bw.get(self._key(a, b), self.default_bw)

    def transfer_s(self, a, b, size_bytes):
        return self.rtt(a, b) / 2.0 + size_bytes / self.bandwidth(a, b)


class PlatformRegistry:
    """Deployed platforms + one executor per platform (each FaaS platform
    runs its functions independently — threads model that concurrency, and
    for real-JAX steps they give true compute/transfer overlap)."""

    def __init__(self, network: Optional[NetworkModel] = None):
        self._platforms: dict = {}
        self._executors: dict = {}
        self.network = network or NetworkModel()
        self._lock = threading.Lock()

    def register(self, platform: Platform):
        with self._lock:
            self._platforms[platform.name] = platform
            self._executors.setdefault(
                platform.name,
                ThreadPoolExecutor(
                    max_workers=8, thread_name_prefix=f"plat-{platform.name}"
                ),
            )
        return platform

    def get(self, name: str) -> Platform:
        return self._platforms[name]

    def executor(self, name: str) -> ThreadPoolExecutor:
        return self._executors[name]

    def names(self):
        return list(self._platforms)

    def shutdown(self):
        for ex in self._executors.values():
            ex.shutdown(wait=False, cancel_futures=True)


class PlatformWrapper:
    """Adapts one step function to one platform. Call overhead is measured
    (paper §4.1: < 1 ms) and exposed via ``overhead_s``."""

    def __init__(self, platform: Platform, fn: Callable, name: str = ""):
        self.platform = platform
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "step")
        self.calls = 0
        self.overhead_s = 0.0
        # concurrent requests to the same (function, platform) run this
        # wrapper from several executor threads — the counters need a lock
        # (unlocked += lost updates under contention)
        self._stats_lock = threading.Lock()

    def __call__(self, *args, **kwargs):
        t0 = time.perf_counter()
        p = self.platform
        if p.mesh is not None and p.rules is not None:
            ctx = shd.use_sharding(p.mesh, p.rules)
        else:
            ctx = _null_ctx()
        t1 = time.perf_counter()  # wrapper work before user code
        with ctx:
            out = self.fn(*args, **kwargs)
        with self._stats_lock:
            self.calls += 1
            self.overhead_s += t1 - t0
        return out


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
