"""GeoFF core: federated serverless choreography with data pre-fetching.

The paper's contribution as a composable library:

  workflow        per-request WorkflowSpec / StepSpec / DataRef (ad-hoc
                  recomposition: routing is invocation data, not deployment)
  platform        Platform registry + PlatformWrapper (write once, deploy
                  to any mesh/host/edge device) + NetworkModel
  store           region-homed ObjectStore (S3 stand-in, real payloads)
  choreographer   chain facade over the dataflow core (repro.dag.engine):
                  a chain is the degenerate DAG, lifted via from_chain
  prewarm         AOT CompileCache — XLA compilation as the TPU cold start
  prefetch        future-based data pre-fetching + DoubleBuffer pipeline
  shipping        placement optimizer: exact DAG DP (series-parallel /
                  exhaustive) + greedy baseline; place_chain delegates
  timing          learned poke-delay controller, keyed per (pred -> succ)
                  edge (paper §5.5 future work)
  simulator       unified discrete-event sim: one dataflow recurrence for
                  chains and DAGs, reproducing Figs 4/6/8
  faults          shared fault model: deterministic transient/outage
                  injection + retry budgets, priced identically by every
                  simulator backend and raised for real by the engine
"""

from repro.core.workflow import (  # noqa: F401
    DataRef,
    Invocation,
    StepSpec,
    WorkflowSpec,
)
from repro.core.platform import (  # noqa: F401
    NetworkModel,
    Platform,
    PlatformRegistry,
    PlatformWrapper,
    bind_sharding,
)
from repro.core.store import ObjectStore, StreamConfig  # noqa: F401
from repro.core.choreographer import Deployment, StepResult  # noqa: F401
from repro.core.prewarm import CompileCache  # noqa: F401
from repro.core.prefetch import DoubleBuffer, Prefetcher  # noqa: F401
from repro.core.shipping import (  # noqa: F401
    PlacementCosts,
    chain_cost,
    dag_cost,
    place_chain,
    place_dag,
    place_dag_greedy,
)
from repro.core.timing import PokeTimingController  # noqa: F401
from repro.core.faults import (  # noqa: F401
    FaultEvent,
    FaultSchedule,
    InjectedFault,
    OutageEvent,
    RetryPolicy,
    availability,
)
