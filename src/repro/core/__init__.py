"""GeoFF core: federated serverless choreography with data pre-fetching.

The paper's contribution as a composable library:

  workflow        per-request WorkflowSpec / StepSpec / DataRef (ad-hoc
                  recomposition: routing is invocation data, not deployment)
  platform        Platform registry + PlatformWrapper (write once, deploy
                  to any mesh/host/edge device) + NetworkModel
  store           region-homed ObjectStore (S3 stand-in, real payloads)
  choreographer   the decentralized middleware: two-phase poke/payload
                  protocol, cascading pre-warm + pre-fetch
  prewarm         AOT CompileCache — XLA compilation as the TPU cold start
  prefetch        future-based data pre-fetching + DoubleBuffer pipeline
  shipping        function-shipping placement optimizer (chain DP / DAG)
  timing          learned poke-delay controller (paper §5.5 future work)
  simulator       calibrated discrete-event sim reproducing Figs 4/6/8
"""
from repro.core.workflow import (DataRef, Invocation, StepSpec,  # noqa: F401
                                 WorkflowSpec)
from repro.core.platform import (NetworkModel, Platform, PlatformRegistry,  # noqa: F401
                                 PlatformWrapper, bind_sharding)
from repro.core.store import ObjectStore  # noqa: F401
from repro.core.choreographer import Deployment, Middleware, StepResult  # noqa: F401
from repro.core.prewarm import CompileCache  # noqa: F401
from repro.core.prefetch import DoubleBuffer, Prefetcher  # noqa: F401
from repro.core.shipping import (PlacementCosts, chain_cost,  # noqa: F401
                                 place_chain, place_dag)
from repro.core.timing import PokeTimingController  # noqa: F401
