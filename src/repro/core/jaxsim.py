"""JAX backend for the unified workflow simulator (``backend="jax"``).

One compiled program sweeps (seeds x placements x requests): every ``Dist``
draw is pre-sampled as a device array, the node-major
poke/payload/prepare/start/end recurrence runs as ``jax.lax.scan`` over the
topo order under ``jit``, and the whole thing is ``vmap``-ed twice — over
candidate placements (same graph, different platforms/medians) and over
seeds. That is what lets ``PlacementScorer`` score an entire candidate set
in one jitted call and the benches sweep seeds x placements without a
Python loop.

The model is EXACTLY the numpy-vectorized path's
(``_run_graph_vectorized``), arithmetic mirrored operation for operation in
float64 (``enable_x64`` is scoped to this module's calls; the ambient jax
config stays untouched), so at sigma=0 — where no randomness survives —
all backends agree to 1e-9. With spread, this backend has its own
draw-order contract: ``jax.random.PRNGKey(seed)`` splits into three
streams (cold / fetch / compute), each one ``(n_nodes, n_requests)``
standard-normal block laid out node-major in topo order. The normals —
and the lognormal factors ``exp(sigma * z)`` derived from them, one table
row per distinct sigma — are drawn ONCE per seed and shared by every
placement in the sweep (common random numbers): candidate comparisons are
driven by the placements, not sampling noise, and the per-placement
marginal cost is just the recurrence. Marginals are the same lognormals
as the numpy backends — medians/p99 agree within 1%
(tests/test_jaxsim.py, the jaxsim bench).

Three structural observations make the compiled program fast on a single
core (and they are exactly the levers the numpy path pulls, batched):

- the poke cascade is draw-free and uniform over requests — ``poke[v]``
  is ``t0 + depth(v) * msg_latency`` where ``depth`` is a static
  shortest-hop count through poke-enabled nodes, so it is precomputed on
  the host per placement instead of carried through the scan;
- the lognormal factor ``exp(sigma * z)`` only depends on sigma, and a
  placement set reuses a handful of sigmas, so factors are tabulated per
  (seed, distinct sigma) and gathered per placement — sampling cost is
  per SEED, not per (seed x placement);
- the cold-start recurrence (the one sequential piece) is the
  ``kernels/cold_scan.py`` Pallas kernel on TPU and its log-depth
  GF(2)-affine parallel scan everywhere else, whose ``while_loop`` gate
  exits immediately in regimes where no request's status depends on its
  predecessor — the batched analogue of the numpy scan's candidate list.

Not supported here (use the scalar / numpy backends): ``timing=``
(per-request feedback), ``telemetry=`` (the compiled program is pure), and
graphs reusing one (name, platform) pair across nodes (couples the cold
recurrence across nodes). Drift IS supported: ``DriftSchedule`` scale
arrays are precomputed per platform on the host and applied as masks after
sampling, exactly like the numpy path.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels.cold_scan import cold_scan_parallel
from repro.kernels.ops import cold_scan as cold_scan_kernel


class _Graph(NamedTuple):
    """Structure shared by every placement: topology + drift scale arrays."""

    pred_idx: jax.Array  # (V, maxP) int32 rows into topo order (0-padded)
    pred_mask: jax.Array  # (V, maxP) bool — which slots are real edges
    is_source: jax.Array  # (V,) bool
    is_sink: jax.Array  # (V,) bool
    compute_scale: jax.Array  # (n_platforms, n) drift masks (ones w/o drift)
    transfer_scale: jax.Array  # (n_platforms, n)
    fetch_scale: jax.Array  # (n_platforms, n)


class _Sigmas(NamedTuple):
    """Distinct sigma values across the placement set, one list per draw
    stream; ``_Placement.*_sig`` rows index into the matching factor table."""

    cold: jax.Array  # (Uc,)
    fetch: jax.Array  # (Uf,)
    compute: jax.Array  # (Ux,)


class _Placement(NamedTuple):
    """Per-placement numerics; stacked with a leading axis and vmapped."""

    cold_median: jax.Array  # (V,)
    cold_sig: jax.Array  # (V,) int32 rows into the cold factor table
    keep_warm: jax.Array  # (V,) may be +inf
    fetch_median: jax.Array  # (V,)
    fetch_sig: jax.Array  # (V,)
    compute_median: jax.Array  # (V,)
    compute_sig: jax.Array  # (V,)
    poke_depth: jax.Array  # (V,) hops from a source via poke-enabled nodes
    #   (0.0 at sources, +inf where the cascade never reaches)
    transfer: jax.Array  # (V, maxP) per-edge payload FIRST-byte transfer
    #   (== the whole-object transfer when streaming is off)
    transfer_last: jax.Array  # (V, maxP) per-edge LAST-byte transfer
    #   (only read by the recurrence when use_stream; == transfer otherwise)
    plat_idx: jax.Array  # (V,) int32 rows into the drift scale arrays
    fault_extra: jax.Array  # (V, n) per-(node, request) retry-backoff
    #   seconds from the host-precomputed fault plane ((V, 1) zeros and
    #   never read when use_faults is off — the hash-based plane needs no
    #   device rng, so it rides the scan like the drift masks do)


def _cold_mask(t0s, warm_end, cold_end, keep_warm, use_pallas):
    if use_pallas:
        return cold_scan_kernel(t0s, warm_end[None, :], cold_end[None, :], keep_warm)[0]
    return cold_scan_parallel(t0s, warm_end, cold_end, keep_warm)


def _simulate_one(
    placed, factors, graph, t0s, msg, inv_chunks, prefetch, use_drift,
    use_pallas, use_stream, use_faults, sample_idx=None,
):
    """One (seed, placement) request stream: the node-major recurrence of
    ``_run_graph_vectorized`` as a scan over topo order. ``factors`` are
    the seed's three lognormal tables ``exp(sigma_u * z)``, each (U, V, n).
    Returns the (n,) per-request totals — plus, when ``sample_idx`` (a
    (k,) request-index array) is given, the per-node scan ys at those
    columns (payload, effective cold, fetch, compute, end; each (V, k)) so
    the host can rebuild ``obs`` traces for the sampled requests. The
    gather rides the existing scan outputs: the totals arithmetic is
    untouched, and no extra randomness is drawn."""
    f_cold, f_fetch, f_compute = factors
    V, n = f_cold.shape[1:]
    dtype = t0s.dtype
    rows = jnp.arange(V)

    def draws(table, sig_idx, median):
        # select each node's factor row by its sigma index. The table's U
        # axis is static and tiny (distinct sigmas across the placement
        # set), so an unrolled where-chain beats a general gather — under
        # the double vmap a gather lowers to per-element loads on CPU.
        factor = table[0]
        for u in range(1, table.shape[0]):
            factor = jnp.where((sig_idx == u)[:, None], table[u], factor)
        return median[:, None] * factor  # (V, n)

    cold = draws(f_cold, placed.cold_sig, placed.cold_median)
    fetch = draws(f_fetch, placed.fetch_sig, placed.fetch_median)
    compute = draws(f_compute, placed.compute_sig, placed.compute_median)
    transfer = placed.transfer[:, :, None]  # (V, maxP, 1)
    transfer_last = placed.transfer_last[:, :, None] if use_stream else None
    if use_drift:
        # drift rescales AFTER sampling (the draw-neutral contract); a
        # degraded platform slows every link it terminates (max endpoint)
        compute = compute * graph.compute_scale[placed.plat_idx]
        fetch = fetch * graph.fetch_scale[placed.plat_idx]
        tr_dst = graph.transfer_scale[placed.plat_idx]  # (V, n)
        tr_src = graph.transfer_scale[placed.plat_idx[graph.pred_idx]]
        tr_sc = jnp.maximum(tr_src, tr_dst[:, None, :])
        transfer = transfer * tr_sc
        if use_stream:
            transfer_last = transfer_last * tr_sc

    inf = jnp.array(jnp.inf, dtype)
    xs = (
        rows,
        graph.pred_idx,
        graph.pred_mask,
        graph.is_source,
        graph.is_sink,
        placed.poke_depth,
        placed.keep_warm,
        cold,
        fetch,
        compute,
        jnp.broadcast_to(transfer, (V,) + transfer.shape[1:]),
    )
    if use_stream:
        xs = xs + (
            jnp.broadcast_to(transfer_last, (V,) + transfer_last.shape[1:]),
        )
    if use_faults:
        xs = xs + (placed.fault_extra,)

    def body(end_all, x):
        # use_stream / use_faults are static: the traced program is
        # literally unchanged when they are False (no extra scan inputs,
        # no extra ops) — unpacked in reverse append order
        if use_faults:
            *x, fault_extra_v = x
        if use_stream:
            *x, tr_last_v = x
        (
            v,
            pidx,
            pmask,
            is_src,
            is_sink,
            depth,
            kw,
            cold_v,
            fetch_v,
            compute_v,
            tr_v,
        ) = x
        # payload join (max over in-edges of upstream end + transfer);
        # with streaming the join gates on FIRST bytes and the last bytes
        # bound the compute tail below
        arrivals = jnp.where(pmask[:, None], end_all[pidx] + tr_v, -inf)
        payload = jnp.where(is_src, t0s + msg / 2, jnp.max(arrivals, axis=0))
        if use_stream:
            arrivals_last = jnp.where(
                pmask[:, None], end_all[pidx] + tr_last_v, -inf
            )
            payload_last = jnp.where(
                is_src, t0s + msg / 2, jnp.max(arrivals_last, axis=0)
            )
        # start/end under both cold hypotheses, then the cold scan
        if prefetch:
            poke_v = t0s + depth * msg
            poked = jnp.isfinite(depth)
            warm_start = jnp.where(
                poked,
                jnp.maximum(payload, poke_v + fetch_v),
                payload + fetch_v,
            )
            cold_start = jnp.where(
                poked,
                jnp.maximum(payload, poke_v + cold_v + fetch_v),
                payload + fetch_v + cold_v,
            )
        else:
            warm_start = payload + fetch_v
            cold_start = warm_start + cold_v
        warm_end = warm_start + compute_v
        cold_end = cold_start + compute_v
        if use_stream:
            # per-chunk pipeline tail (closed form, matching the numpy
            # path); sources have no in-edges, so their tail never binds
            tail = jnp.where(is_src, -inf, payload_last + compute_v * inv_chunks)
            warm_end = jnp.maximum(warm_end, tail)
            cold_end = jnp.maximum(cold_end, tail)
        if use_faults:
            # retry backoffs delay the node under both hypotheses, after
            # the streaming tail and before the cold scan — the exact
            # ordering of the scalar and numpy paths. Exhausted budgets
            # are applied HOST-side to the totals (inf would poison the
            # cold recurrence), so the compiled sweep stays finite.
            warm_end = warm_end + fault_extra_v
            cold_end = cold_end + fault_extra_v
        mask = _cold_mask(t0s, warm_end, cold_end, kw, use_pallas)
        end_v = jnp.where(mask, cold_end, warm_end)
        sink_row = jnp.where(is_sink, end_v, -inf)
        if sample_idx is not None:
            cold_eff = jnp.where(mask, cold_v, jnp.zeros_like(cold_v))
            sampled = (
                payload[sample_idx],
                cold_eff[sample_idx],
                fetch_v[sample_idx],
                compute_v[sample_idx],
                end_v[sample_idx],
            )
            return end_all.at[v].set(end_v), (sink_row, sampled)
        return end_all.at[v].set(end_v), sink_row

    _, ys = jax.lax.scan(body, jnp.zeros((V, n), dtype), xs)
    if sample_idx is not None:
        sink_ends, sampled = ys
        return jnp.max(sink_ends, axis=0) - t0s, sampled
    return jnp.max(ys, axis=0) - t0s


@partial(
    jax.jit,
    static_argnames=(
        "prefetch", "use_drift", "use_pallas", "use_stream", "use_faults",
    ),
)
def _sweep(
    keys, placed, sigmas, graph, t0s, msg, inv_chunks, sample_idx=None,
    *, prefetch, use_drift, use_pallas, use_stream, use_faults,
):
    """(seeds, placements, requests) totals in one compiled program. With
    ``sample_idx``, also the sampled per-node ys pytree (leaves gain the
    (seeds, placements) leading axes)."""
    V = graph.pred_idx.shape[0]
    n = t0s.shape[0]
    f32 = jnp.float32

    def per_seed(key):
        # one normal block per stream per seed; exp(sigma_u * z) tabulated
        # per distinct sigma and shared by every placement (CRN). In f32 —
        # exact at sigma=0 (exp(0) == 1), statistically indistinguishable
        # otherwise — the recurrence itself stays in t0s' dtype.
        key_cold, key_fetch, key_compute = jax.random.split(key, 3)

        def table(k, sig_u):
            z = jax.random.normal(k, (V, n), f32)
            return jnp.exp(sig_u.astype(f32)[:, None, None] * z).astype(t0s.dtype)

        factors = (
            table(key_cold, sigmas.cold),
            table(key_fetch, sigmas.fetch),
            table(key_compute, sigmas.compute),
        )
        return jax.vmap(
            lambda p: _simulate_one(p, factors, graph, t0s, msg, inv_chunks,
                                    prefetch, use_drift, use_pallas,
                                    use_stream, use_faults, sample_idx)
        )(placed)

    return jax.vmap(per_seed)(keys)


def _poke_depths(order, steps, preds):
    """Hop count of each node's poke through poke-enabled nodes (the whole
    cascade is ``t0 + depth * msg``: draw-free and uniform over requests,
    so it folds to one static constant per node). Sources are poked at t0
    (depth 0); a node with ``prefetch=False`` — or reachable only through
    one — is never poked (+inf)."""
    depth = {}
    for v in order:
        if not preds[v]:
            depth[v] = 0.0
        elif steps[v].prefetch:
            depth[v] = min(depth[u] for u in preds[v]) + 1.0
        else:
            depth[v] = math.inf
    return np.array([depth[v] for v in order])


def _build(
    sim, order, step_sets, preds, succs, t0s, drift, dtype, stream=None,
    faults=None, retry=None,
):
    """Host-side array construction (numpy). The transfer model is
    evaluated through ``sim._transfer_s`` — or ``sim._transfer_fl`` when a
    StreamConfig is given — so subclasses that override the whole-object
    model (e.g. the scorer's cost-model simulator) feed this backend
    unchanged.

    With a ``FaultSchedule``, each placement also gets its (V, n)
    retry-backoff plane (``_Placement.fault_extra``, a scan input like the
    drift masks) and a (n,) request-failed mask; the planes come from the
    same hash-based ``FaultSchedule.plane`` the scalar and numpy backends
    price, so all three agree bit-for-bit. Returns ``(placed, sigmas,
    graph, fault_failed)`` with ``fault_failed`` a (P, n) bool array (all
    False when no schedule is active)."""
    f64 = dtype
    V = len(order)
    n = len(t0s)
    max_p = max([1] + [len(preds[v]) for v in order])
    idx_of = {v: i for i, v in enumerate(order)}
    pred_idx = np.zeros((V, max_p), np.int32)
    pred_mask = np.zeros((V, max_p), bool)
    for i, v in enumerate(order):
        for j, u in enumerate(preds[v]):
            pred_idx[i, j] = idx_of[u]
            pred_mask[i, j] = True
    is_source = np.array([not preds[v] for v in order])
    is_sink = np.array([not succs[v] for v in order])

    plat_names = list(sim.platforms)
    plat_row = {name: i for i, name in enumerate(plat_names)}
    scales = np.ones((3, len(plat_names), n), f64)
    if drift is not None:
        ks = np.arange(n)
        for name in plat_names:
            scales[:, plat_row[name], :] = drift.scale_arrays(ks, name)

    faults_on = faults is not None and bool(faults)
    request_ks = np.arange(n)

    def placement_arrays(steps):
        row = {
            "cold_median": np.empty(V, f64),
            "cold_sigma": np.empty(V, f64),
            "keep_warm": np.empty(V, f64),
            "fetch_median": np.empty(V, f64),
            "fetch_sigma": np.empty(V, f64),
            "compute_median": np.empty(V, f64),
            "compute_sigma": np.empty(V, f64),
            "poke_depth": _poke_depths(order, steps, preds).astype(f64),
            "transfer": np.zeros((V, max_p), f64),
            "transfer_last": np.zeros((V, max_p), f64),
            "plat_idx": np.zeros(V, np.int32),
            "fault_extra": np.zeros((V, n if faults_on else 1), f64),
            "fault_failed": np.zeros(n, bool),
        }
        for i, v in enumerate(order):
            step = steps[v]
            plat = sim.platforms[step.platform]
            if faults_on:
                fp = faults.plane(
                    step.name, step.platform, request_ks, retry,
                    region=plat.region,
                )
                row["fault_extra"][i] = fp.extra_s
                row["fault_failed"] |= fp.failed
            row["cold_median"][i] = plat.cold_start.median
            row["cold_sigma"][i] = plat.cold_start.sigma
            row["keep_warm"][i] = plat.keep_warm_s
            row["fetch_median"][i] = step.fetch.median
            row["fetch_sigma"][i] = step.fetch.sigma
            row["compute_median"][i] = step.compute.median
            row["compute_sigma"][i] = step.compute.sigma
            row["plat_idx"][i] = plat_row[step.platform]
            for j, u in enumerate(preds[v]):
                # routes through the table-aware per-edge resolver, so a
                # calibrated transfer_table is honored on this backend too
                first, last = sim._pair_transfer_fl(steps[u], step)
                row["transfer"][i, j] = first
                row["transfer_last"][i, j] = last
        return row

    # _transfer_fl reads sim.stream; pin it to THIS call's config for the
    # duration of the host-side build (spec-level overrides), then restore
    saved_stream = sim.stream
    sim.stream = stream
    try:
        all_rows = [placement_arrays(steps) for steps in step_sets]
    finally:
        sim.stream = saved_stream

    def dedup_sigmas(name):
        """Distinct sigma values across ALL placements for one stream +
        per-placement (V,) index rows into them. A degenerate dist
        (median <= 0) contributes nothing to the draw, so its sigma is
        remapped to the first entry rather than widening the table."""
        stack = np.stack([r[name + "_sigma"] for r in all_rows])
        med = np.stack([r[name + "_median"] for r in all_rows])
        stack = np.where(med > 0, stack, stack.flat[0])
        uniq, inv = np.unique(stack, return_inverse=True)
        return uniq, inv.reshape(stack.shape).astype(np.int32)

    cold_u, cold_i = dedup_sigmas("cold")
    fetch_u, fetch_i = dedup_sigmas("fetch")
    comp_u, comp_i = dedup_sigmas("compute")
    # leaves stay host-side numpy: the jitted _sweep transfers them in one
    # batched device_put instead of thirty individual dispatches
    sigmas = _Sigmas(cold_u, fetch_u, comp_u)
    placed = _Placement(
        cold_median=np.stack([r["cold_median"] for r in all_rows]),
        cold_sig=cold_i,
        keep_warm=np.stack([r["keep_warm"] for r in all_rows]),
        fetch_median=np.stack([r["fetch_median"] for r in all_rows]),
        fetch_sig=fetch_i,
        compute_median=np.stack([r["compute_median"] for r in all_rows]),
        compute_sig=comp_i,
        poke_depth=np.stack([r["poke_depth"] for r in all_rows]),
        transfer=np.stack([r["transfer"] for r in all_rows]),
        transfer_last=np.stack([r["transfer_last"] for r in all_rows]),
        plat_idx=np.stack([r["plat_idx"] for r in all_rows]),
        fault_extra=np.stack([r["fault_extra"] for r in all_rows]),
    )
    fault_failed = np.stack([r["fault_failed"] for r in all_rows])
    graph = _Graph(
        pred_idx,
        pred_mask,
        is_source,
        is_sink,
        compute_scale=scales[0],
        transfer_scale=scales[1],
        fetch_scale=scales[2],
    )
    return placed, sigmas, graph, fault_failed


def run_batched(sim, order, step_sets, preds, succs, t0s, prefetch, seeds,
                drift=None, dtype=np.float64, sample_idx=None, stream=None,
                faults=None, retry=None):
    """The jax backend's one entry point: simulate every (seed, placement)
    pair of one workflow graph in a single compiled call.

    ``sim`` is the host ``WorkflowSimulator`` (platforms, msg latency,
    transfer model); ``step_sets`` is a list of ``{node_id: SimStep}``
    placements sharing (order, preds, succs); ``seeds`` the integer seed
    axis; ``drift`` overrides ``sim.drift`` when given. Returns a
    ``(len(seeds), len(step_sets), len(t0s))`` ``dtype`` numpy array of
    per-request totals.

    ``dtype``: float64 (default) reproduces the numpy backend bit-for-bit
    at sigma=0 (the equivalence gates run on it); float32 halves the
    memory traffic of the compiled sweep — the recurrence is
    memory-bound — and is statistically indistinguishable (the medians
    the scorer and benches consume move by ~1e-7 relative), so bulk
    candidate scoring uses it.

    ``sample_idx``: optional (k,) request indices. When given, the return
    value becomes ``(totals, sampled)`` where ``sampled`` is a 5-tuple of
    ``(seeds, placements, V, k)`` numpy arrays (payload, effective cold,
    fetch, compute, end at the sampled requests) for host-side ``obs``
    trace reconstruction. The totals are computed by the identical
    arithmetic either way.

    ``stream``: optional ``StreamConfig``. Splits every edge into a
    (first_byte, last_byte) transfer pair host-side and — when chunks > 1
    — adds the per-chunk pipeline tail to the recurrence (a static branch:
    with ``stream=None`` the compiled program is unchanged). ``chunks=1``
    keeps the whole-object recurrence, so totals stay bit-for-bit.

    ``faults`` / ``retry``: optional ``FaultSchedule`` / ``RetryPolicy``.
    The hash-based fault plane is precomputed host-side per placement —
    another plane riding the scan next to the cold-start inputs (a static
    ``use_faults`` branch, program unchanged when off) — and exhausted
    retry budgets turn the affected requests' totals into ``inf`` after
    the sweep (the compiled recurrence itself stays finite). The fault
    outcomes are shared with the scalar/numpy backends bit-for-bit, and
    are identical across every placement's SHARED (step, platform) cells
    (a moved step gets the moved cell's plane — what lets the scorer judge
    failover candidates under live outages).
    """
    if drift is None:
        drift = sim.drift
    if sim.timing is not None:
        raise ValueError(
            "backend='jax' does not support timing=: the poke controller "
            "learns from per-request feedback; use backend='scalar'"
        )
    for steps in step_sets:
        keys = [(steps[v].name, steps[v].platform) for v in order]
        if len(set(keys)) != len(keys):
            raise ValueError(
                "backend='jax' needs a unique (name, platform) per node — "
                "a duplicated pair couples the cold-start recurrence "
                "across nodes; use backend='scalar'"
            )
    seeds = [int(s) for s in seeds]
    n = len(t0s)
    if n == 0 or not step_sets or not seeds:
        empty = np.empty((len(seeds), len(step_sets), n))
        if sample_idx is not None:
            V = len(order)
            z = np.empty((len(seeds), len(step_sets), V, 0))
            return empty, (z, z, z, z, z)
        return empty
    dtype = np.dtype(dtype).type
    # the recurrence only changes when first != last bytes is possible;
    # chunks=1 (even with P2P rerouting the transfer VALUES) keeps the
    # whole-object scan — first == last there, so the tail never binds
    use_stream = stream is not None and stream.chunks > 1
    use_faults = faults is not None and bool(faults)
    with enable_x64():
        placed, sigmas, graph, fault_failed = _build(
            sim, order, step_sets, preds, succs, t0s, drift, dtype,
            stream=stream, faults=faults, retry=retry,
        )
        # raw threefry key layout ([hi, lo] uint32 words of the seed) —
        # identical to stacking jax.random.PRNGKey(s), minus S dispatches
        sarr = np.asarray([s & 0xFFFFFFFFFFFFFFFF for s in seeds], np.uint64)
        keys = np.stack(
            [sarr >> np.uint64(32), sarr & np.uint64(0xFFFFFFFF)], axis=-1
        ).astype(np.uint32)
        out = _sweep(
            keys,
            placed,
            sigmas,
            graph,
            jnp.asarray(np.asarray(t0s, dtype)),
            jnp.asarray(dtype(sim.msg)),
            jnp.asarray(dtype(1.0 / stream.chunks) if use_stream else dtype(1.0)),
            jnp.asarray(np.asarray(sample_idx, np.int32))
            if sample_idx is not None
            else None,
            prefetch=bool(prefetch),
            use_drift=drift is not None,
            use_pallas=jax.default_backend() == "tpu",
            use_stream=use_stream,
            use_faults=use_faults,
        )

        def mark_failed(totals):
            # dead requests are priced as-if-completed inside the sweep
            # (the cold recurrence must stay finite and backend-identical)
            # but reported as never finishing — same post-step the numpy
            # backend applies
            if use_faults and fault_failed.any():
                return np.where(fault_failed[None, :, :], np.inf, totals)
            return totals

        if sample_idx is not None:
            totals, sampled = out
            return (
                mark_failed(np.asarray(totals)),
                tuple(np.asarray(a) for a in sampled),
            )
        return mark_failed(np.asarray(out))
