"""Unified discrete-event simulator for the paper's experiments (§4.2–§4.4).

Public-cloud latencies cannot be measured in this container, so the three
paper experiments are reproduced here: per-component latency distributions
(cold start, object GET/PUT by size, inter-region RTT, compute) are
calibrated so the BASELINE medians match the paper's; the pre-fetching /
shipping deltas then EMERGE from the same two-phase protocol the real
middleware executes (poke cascade -> prepare || predecessor compute ->
payload -> handler). Nothing about the improvement is hard-coded.

ONE recurrence serves chains and DAGs (mirroring the runtime, where the
chain deployer is a facade over the dataflow engine). Per request, with
``u`` ranging over the predecessors of node ``v``:

    poke[v]    = min over u of poke[u] + msg_latency + delay(u->v)
                 (cascade; sources are poked at t0; delay(u->v) is the
                 per-edge learned poke delay, 0 when no controller is set)
    prepare[v] = poke[v] + cold_v + fetch_v              (prefetch on)
    payload[v] = max over u of end[u] + transfer(u -> v) (fan-in join)
    start[v]   = max(payload[v], prepare[v])             (prefetch on)
               = payload[v] + cold_v + fetch_v           (baseline)
    end[v]     = start[v] + compute_v
    total      = max over sinks of end[sink] - t0

With a ``StreamConfig`` attached (the streaming data plane), each edge's
transfer splits into a (first_byte, last_byte) pair: ``payload[v]`` —
and therefore ``start[v]`` — gates on first bytes, while the last bytes
bound the compute tail:

    end[v] = max(start[v] + compute_v,
                 payload_last[v] + compute_v / chunks)

which is the closed form of the per-chunk pipeline (chunk i usable only
after it arrives AND the previous chunk is processed, with the join's
chunk arrivals evenly spaced between first and last byte) — the chunk
inner loop is algebra, not a Python loop, so it vectorizes for free. At
``chunks=1`` first == last and the recurrence is bit-for-bit the one
above.

``run_request`` executes this on the degenerate chain graph — positionally,
so the sampled trace is draw-for-draw what the pre-unification chain
simulator produced. ``run_dag_request`` executes it on an explicit edge
list.

Experiments are described by an ``ExperimentSpec`` (steps, edges,
request stream, seeds, drift, telemetry) and executed by ONE entry point,
``WorkflowSimulator.simulate(spec, backend=...)``, with three backends:

``backend="scalar"``   the per-request loop above — the reference
                       semantics, and the only backend that supports
                       ``timing=`` (per-request poke-delay feedback).
``backend="numpy"``    the request axis vectorized: every per-request
                       scalar becomes a ``(n_requests,)`` numpy array and
                       the graph is walked once, node-major in topo
                       order. The only genuinely sequential piece — the
                       cold-start ``_last_use`` recurrence — collapses to
                       a tight per-(step, platform) scan over the few
                       requests that can possibly be cold (see
                       ``_cold_scan``). Its draw-order contract (per node
                       in topo order: ``n`` cold draws, then ``n`` fetch,
                       then ``n`` compute) is pinned by frozen-reference
                       tests and agrees with the scalar path
                       statistically (medians/p99 within 1%,
                       ``tests/test_vecsim.py``).
``backend="jax"``      the whole (seeds x placements x requests) sweep as
                       one jitted program (``repro.core.jaxsim``):
                       ``lax.scan`` over topo order, ``vmap`` over seeds
                       and candidate placements, the cold scan as a
                       Pallas kernel on TPU and a log-depth parallel scan
                       elsewhere. Bit-equal to ``numpy`` at sigma=0; its
                       own (jax.random) draw contract with spread, within
                       1% on medians/p99 (``tests/test_jaxsim.py``).
                       ``simulate_placements`` exposes the placement axis
                       — ``PlacementScorer`` scores an entire candidate
                       set in one call.

``run_experiment`` / ``run_dag_experiment`` / ``run_experiment_many`` are
thin wrappers over ``simulate`` (the legacy ``vectorized=`` flag is a
deprecation shim that maps True/False to ``backend="numpy"``/"scalar").

Double-billing per node (prefetch on) is start - prepare clipped at 0
— the instance is up and idle (paper §5.5); pass a ``PokeTimingController``
as ``timing=`` to shrink it: each edge's poke is delayed by the learned
slack, and the controller is fed per-edge slack observations (relative to
the undelayed poke) plus per-step compute/prepare EWMAs.

Two optional taps serve ``repro.adapt``: ``telemetry=`` feeds a
``TelemetryHub`` the same observation classes the real engine records
(per-(step, platform) compute, per-(key, region) fetch, per-region-pair
transfer, cold/warm counts), and ``drift=`` attaches a ``DriftSchedule``
that rescales a platform's compute/transfer/fetch draws from request k on
(mid-run condition changes). Both are draw-neutral: scaling happens after
sampling, so with them disabled the trace is bit-for-bit the undrifted one.
"""

from __future__ import annotations

import bisect
import math
import warnings
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.faults import (  # noqa: F401  (re-export: fault injection
    FaultEvent,  # lives next to DriftSchedule on the simulator's surface,
    FaultSchedule,  # and the engine's FaultInjector raises from the same
    OutageEvent,  # schedule — one fault model on both sides of sim/real)
    RetryPolicy,
)
from repro.core.graph import graph_views
from repro.core.store import StreamConfig  # noqa: F401  (re-export: the
#   streaming data plane config is part of the simulator's surface too)


# ---------------------------------------------------------------------------
# latency model pieces
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Dist:
    """Lognormal around a median with multiplicative spread sigma."""

    median: float
    sigma: float = 0.12

    def sample(self, rng: np.random.Generator) -> float:
        if self.median <= 0:
            return 0.0
        return float(self.median * math.exp(rng.normal(0.0, self.sigma)))

    def sample_many(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """``n`` draws in one rng call (the vectorized path). Mirrors
        ``sample``: a degenerate distribution consumes no randomness."""
        if self.median <= 0:
            return np.zeros(n)
        return self.median * np.exp(rng.normal(0.0, self.sigma, n))


@dataclass(frozen=True)
class SimPlatform:
    name: str
    region: str
    native_prefetch: bool = False
    allows_sync: bool = True
    cold_start: Dist = Dist(0.8, 0.3)
    keep_warm_s: float = 900.0


@dataclass(frozen=True)
class SimStep:
    name: str
    platform: str
    compute: Dist
    fetch: Dist = Dist(0.0)  # external data download at the step's region
    prefetch: bool = True
    fetch_key: str = ""  # telemetry key for fetch draws ("" -> step name);
    #   set it to the DataRef key of the matching DagSpec step so simulated
    #   fetch observations are reachable by adapt.costs.observed_costs
    #   (which looks fetches up per dep key, like the real prefetcher)


@dataclass
class RequestTrace:
    total_s: float
    start: list
    end: list
    prepare: list
    payload: list
    double_billed_s: float
    exposed_fetch_s: float


@dataclass
class DagTrace:
    total_s: float
    start: dict
    end: dict
    prepare: dict
    payload: dict
    double_billed_s: float
    exposed_fetch_s: float


@dataclass(frozen=True)
class DriftEvent:
    """From request ``at_request`` on, rescale one platform's draws.

    Models the integer-factor latency drift public clouds exhibit over
    hours (Kulkarni et al., 2025): compute draws on the platform are
    multiplied by ``compute_scale``, transfers touching the platform by
    ``transfer_scale``, external-data fetches at the platform by
    ``fetch_scale``. Scales compose multiplicatively across events."""

    at_request: int
    platform: str
    compute_scale: float = 1.0
    transfer_scale: float = 1.0
    fetch_scale: float = 1.0


class DriftSchedule:
    """Mid-run drift injection for the simulator: a list of ``DriftEvent``.

    The simulator consults ``scales(k, platform)`` with its running request
    index; with no schedule attached (or no event in range) the draw stream
    is bit-for-bit what the un-drifted simulator produces (scaling happens
    AFTER sampling, so rng consumption never changes — the frozen-reference
    tests in tests/test_unified_core.py pin this)."""

    def __init__(self, events=()):
        self.events = tuple(events)
        # scales(k, p) is piecewise constant in k: it only changes when k
        # crosses one of p's event boundaries, so memoize per (platform,
        # segment) — O(1) amortized, cache bounded by events + 1 segments
        # per platform (it used to be O(events) per call, and the scalar
        # simulator calls it per node AND per edge endpoint per request)
        self._thresholds: dict = {}  # platform -> sorted at_request list
        self._segments: dict = {}  # (platform, segment) -> (c, t, f)

    def scales(self, request_k: int, platform: str) -> tuple:
        """(compute_scale, transfer_scale, fetch_scale) at request_k."""
        th = self._thresholds.get(platform)
        if th is None:
            th = self._thresholds[platform] = sorted(
                {e.at_request for e in self.events if e.platform == platform}
            )
        key = (platform, bisect.bisect_right(th, request_k))
        hit = self._segments.get(key)
        if hit is None:
            c = t = f = 1.0
            for e in self.events:
                if e.platform == platform and request_k >= e.at_request:
                    c *= e.compute_scale
                    t *= e.transfer_scale
                    f *= e.fetch_scale
            hit = self._segments[key] = (c, t, f)
        return hit

    def scale_arrays(self, request_ks: np.ndarray, platform: str) -> tuple:
        """``scales`` over a whole request axis at once: three
        ``(n_requests,)`` arrays (compute, transfer, fetch) built from
        boolean masks over the event boundaries (the vectorized path)."""
        n = len(request_ks)
        c, t, f = np.ones(n), np.ones(n), np.ones(n)
        for e in self.events:
            if e.platform != platform:
                continue
            m = request_ks >= e.at_request
            c[m] *= e.compute_scale
            t[m] *= e.transfer_scale
            f[m] *= e.fetch_scale
        return c, t, f


class ObjectLatency:
    """Object-store GET/PUT between regions: fixed per-op overhead + size/bw.
    Captures the paper's §4.4 observation that even a 256 KB cross-provider
    S3 GET costs ~0.8 s (TLS + cross-region + S3 service latency).

    ``p2p_overhead_*`` price the direct peer-to-peer payload path (one
    function streaming to another over a socket, no store round-trip): the
    per-op overhead drops to connection setup, the bandwidth terms stay."""

    def __init__(
        self,
        overhead_same=0.03,
        overhead_cross=0.35,
        bw_same=50e6,
        bw_cross=8e6,
        p2p_overhead_same=0.004,
        p2p_overhead_cross=0.12,
    ):
        self.overhead_same = overhead_same
        self.overhead_cross = overhead_cross
        self.bw_same = bw_same
        self.bw_cross = bw_cross
        self.p2p_overhead_same = p2p_overhead_same
        self.p2p_overhead_cross = p2p_overhead_cross

    def op_s(self, src_region, dst_region, size_bytes):
        same = src_region == dst_region
        oh = self.overhead_same if same else self.overhead_cross
        bw = self.bw_same if same else self.bw_cross
        return oh + size_bytes / bw

    def stream_pair_s(self, src_region, dst_region, size_bytes, chunks: int):
        """(first_byte_s, last_byte_s) of a chunked store round-trip
        (PUT src->dst + GET within dst). The first byte pays both hops'
        per-op overheads on one chunk; the residual chunks then pipeline
        through the bottleneck hop, so last = first + (chunks-1) * chunk /
        min(bw). At ``chunks=1`` both components are exactly the
        whole-object round-trip (same expression, same bits)."""
        if chunks <= 1:
            whole = self.op_s(src_region, dst_region, size_bytes) + self.op_s(
                dst_region, dst_region, size_bytes
            )
            return whole, whole
        chunk = size_bytes / chunks
        first = self.op_s(src_region, dst_region, chunk) + self.op_s(
            dst_region, dst_region, chunk
        )
        bw_hop1 = self.bw_same if src_region == dst_region else self.bw_cross
        last = first + (chunks - 1) * chunk / min(bw_hop1, self.bw_same)
        return first, last

    def p2p_pair_s(self, src_region, dst_region, size_bytes, chunks: int):
        """(first_byte_s, last_byte_s) of the direct peer-to-peer path:
        one hop, connection-setup overhead instead of two store ops."""
        same = src_region == dst_region
        oh = self.p2p_overhead_same if same else self.p2p_overhead_cross
        bw = self.bw_same if same else self.bw_cross
        if chunks <= 1:
            whole = oh + size_bytes / bw
            return whole, whole
        chunk = size_bytes / chunks
        first = oh + chunk / bw
        return first, first + (chunks - 1) * chunk / bw


def _graph(steps, edges):
    """Predecessors, successors, and a deterministic topo order (ties broken
    by ``steps`` order) for an edge-list DAG over named steps."""
    return graph_views([s.name for s in steps], edges)


def serialize_chain(steps, edges):
    """The chain serialization of a DAG: its steps in topological order,
    executed as a linear workflow (the baseline a DAG schedule beats)."""
    _, _, order = _graph(steps, edges)
    by_name = {s.name: s for s in steps}
    return [by_name[n] for n in order]


@dataclass(frozen=True)
class ExperimentSpec:
    """Everything that defines one workflow experiment, independent of how
    it is executed. ``steps`` is the placed workflow (a sequence of
    ``SimStep``); ``edges`` is None for a linear chain or a list of
    ``(src_name, dst_name)`` pairs for a DAG. The request stream is
    ``n_requests`` arrivals spaced ``interarrival_s`` apart. ``seeds`` is
    None for a single run on the simulator's own rng stream, or a sequence
    of seeds for a replicated sweep (one fresh stream per seed — rows of
    the result). ``drift`` / ``telemetry`` / ``tracer`` / ``stream``
    override the simulator's attached ``DriftSchedule`` /
    ``TelemetryHub`` / ``obs.Tracer`` / ``StreamConfig`` for this
    experiment only (None inherits); so do ``faults`` / ``retry`` for the
    attached ``FaultSchedule`` / ``RetryPolicy``. Execute with
    ``WorkflowSimulator.simulate(spec, backend=...)``."""

    steps: tuple
    edges: Optional[tuple] = None
    n_requests: int = 1800
    interarrival_s: float = 1.0
    prefetch: bool = True
    seeds: Optional[tuple] = None
    drift: Optional[DriftSchedule] = None
    telemetry: object = None
    tracer: object = None
    stream: Optional[StreamConfig] = None  # chunked data plane (None = off)
    faults: Optional[FaultSchedule] = None  # fault injection (None = off)
    retry: Optional[RetryPolicy] = None  # retry budget (None = one attempt)

    def __post_init__(self):
        object.__setattr__(self, "steps", tuple(self.steps))
        if self.edges is not None:
            object.__setattr__(self, "edges", tuple(self.edges))
        if self.seeds is not None:
            object.__setattr__(self, "seeds", tuple(self.seeds))


def _spec_graph(steps, edges):
    """The one chain-vs-DAG dispatch: node ids, step map and adjacency for
    either workflow shape. Chains are keyed positionally (duplicate step
    names allowed), DAGs by step name (the edge vocabulary)."""
    if edges is None:
        ids = list(range(len(steps)))
        smap = dict(enumerate(steps))
        preds = {i: ([] if i == 0 else [i - 1]) for i in ids}
        succs = {i: ([i + 1] if i + 1 < len(steps) else []) for i in ids}
        return ids, smap, preds, succs
    smap = {s.name: s for s in steps}
    preds, succs, order = _graph(steps, edges)
    return order, smap, preds, succs


_BACKENDS = ("scalar", "numpy", "jax")

# sentinel: distinguishes "caller did not pass vectorized=" from any value
_VECTORIZED_UNSET = object()


class WorkflowSimulator:
    """One simulator for chains and DAGs: same platforms, latencies,
    cold-start bookkeeping and rng, so results are directly comparable."""

    def __init__(
        self,
        platforms,
        msg_latency_s: float = 0.045,
        object_latency: Optional[ObjectLatency] = None,
        payload_size_bytes: float = 1.5e6,
        seed: int = 0,
        timing=None,
        telemetry=None,
        drift: Optional[DriftSchedule] = None,
        stream: Optional[StreamConfig] = None,
        transfer_table: Optional[dict] = None,
        faults: Optional[FaultSchedule] = None,
        retry: Optional[RetryPolicy] = None,
    ):
        self.platforms = {p.name: p for p in platforms}
        self.msg = msg_latency_s
        self.obj = object_latency or ObjectLatency()
        self.payload_size = payload_size_bytes
        self.seed = seed  # kept for backends that sample per-seed (jax)
        self.rng = np.random.default_rng(seed)
        self.timing = timing  # optional PokeTimingController (per-edge)
        self.telemetry = telemetry  # optional TelemetryHub (repro.adapt)
        self.drift = drift  # optional DriftSchedule (mid-run injection)
        self.stream = stream  # optional StreamConfig (chunked data plane)
        self.faults = faults  # optional FaultSchedule (injected failures)
        self.retry = retry  # optional RetryPolicy (prices retry backoffs)
        # optional {(src_step_name, dst_step_name): seconds} override of the
        # platform transfer model per edge — the calibration entry point
        # (obs.profiler / scripts/trace_diff pin observed per-edge costs)
        self.transfer_table = transfer_table
        self.tracer = None  # optional obs.Tracer (per-request span trees)
        self._req_k = 0  # running request index (feeds the drift schedule)
        self._last_use: dict = {}

    # -- transfer of the inter-step payload ------------------------------------
    def _transfer_s(self, src: SimPlatform, dst: SimPlatform) -> float:
        if dst.native_prefetch and dst.allows_sync and src.region == dst.region:
            return self.msg * 0.1  # direct local call (tinyFaaS)
        # public-cloud path: buffer via object store (PUT at src + GET at dst)
        return self.obj.op_s(src.region, dst.region, self.payload_size) + self.obj.op_s(
            dst.region, dst.region, self.payload_size
        )

    def _transfer_fl(self, src: SimPlatform, dst: SimPlatform) -> tuple:
        """(first_byte_s, last_byte_s) for one edge under the attached
        ``StreamConfig`` (callers check ``self.stream is not None``).
        Direct local calls and whole-object edges (chunks=1, no P2P hit)
        delegate to ``_transfer_s`` — preserving both bit-for-bit equality
        and any scorer subclass override of the whole-object model."""
        stream = self.stream
        local = dst.native_prefetch and dst.allows_sync and src.region == dst.region
        if (
            not local
            and stream.p2p_threshold_bytes > 0
            and self.payload_size <= stream.p2p_threshold_bytes
        ):
            return self.obj.p2p_pair_s(
                src.region, dst.region, self.payload_size, stream.chunks
            )
        if local or stream.chunks <= 1:
            t = self._transfer_s(src, dst)
            return t, t
        return self.obj.stream_pair_s(
            src.region, dst.region, self.payload_size, stream.chunks
        )

    def _cold(self, step: SimStep, t: float) -> float:
        plat = self.platforms[step.platform]
        key = (step.name, step.platform)
        last = self._last_use.get(key, -math.inf)
        cold = (t - last) > plat.keep_warm_s
        return plat.cold_start.sample(self.rng) if cold else 0.0

    # -- drift injection (mid-run condition changes) ---------------------------
    def _scales(self, platform: str) -> tuple:
        if self.drift is None:
            return (1.0, 1.0, 1.0)
        return self.drift.scales(self._req_k, platform)

    def _pair_transfer_fl(self, src_step: SimStep, dst_step: SimStep) -> tuple:
        """Base (first_byte, last_byte) transfer for one edge, BEFORE drift
        — the single resolution point every backend routes through. A
        ``transfer_table`` hit (keyed by step names) overrides the platform
        model with an observed per-edge cost, treated as unsplittable: this
        is how trace-calibrated simulators (``obs.profiler``,
        ``scripts/trace_diff``) pin measured transfers onto the model.
        Without a table the platform model applies unchanged (bit-for-bit:
        whole-object when no ``StreamConfig`` is attached, first/last split
        otherwise)."""
        if self.transfer_table is not None:
            hit = self.transfer_table.get((src_step.name, dst_step.name))
            if hit is not None:
                return hit, hit
        src = self.platforms[src_step.platform]
        dst = self.platforms[dst_step.platform]
        if self.stream is None:
            t = self._transfer_s(src, dst)
            return t, t
        return self._transfer_fl(src, dst)

    def _edge_transfer_s(self, src_step: SimStep, dst_step: SimStep) -> float:
        """Payload transfer for one edge (whole-object view), with drift
        applied: a degraded platform slows every link it terminates (max of
        the two endpoint scales — rescaling AFTER the model keeps rng
        consumption fixed)."""
        if self.transfer_table is not None:
            tr = self.transfer_table.get((src_step.name, dst_step.name))
        else:
            tr = None
        if tr is None:
            tr = self._transfer_s(
                self.platforms[src_step.platform], self.platforms[dst_step.platform]
            )
        if self.drift is not None:
            tr *= max(
                self._scales(src_step.platform)[1],
                self._scales(dst_step.platform)[1],
            )
        return tr

    def _edge_transfer_fl(self, src_step: SimStep, dst_step: SimStep) -> tuple:
        """``_edge_transfer_s`` split into (first_byte, last_byte): the
        payload join gates on the first component, the compute tail on the
        last. With no ``StreamConfig`` both components are the whole-object
        transfer (the exact value ``_edge_transfer_s`` returns)."""
        first, last = self._pair_transfer_fl(src_step, dst_step)
        if self.drift is not None:
            sc = max(
                self._scales(src_step.platform)[1],
                self._scales(dst_step.platform)[1],
            )
            first *= sc
            last *= sc
        return first, last

    # -- the one dataflow recurrence -------------------------------------------
    def _run_graph(
        self, order, steps, preds, succs, t0: float, prefetch: bool, trace: bool = True
    ):
        """``order``: topo-sorted node ids; ``steps``: {id: SimStep};
        ``preds``/``succs``: {id: [ids]}. Ids are arbitrary hashables so the
        chain path can key positionally (duplicate step names allowed).

        When a ``tracer`` is attached (and ``trace`` is True — the stream
        path samples), the request is also emitted as an ``obs`` trace in
        the same span schema the real engine produces. Trace assembly reads
        the recurrence variables AFTER the loop and consumes no randomness,
        so tracing on/off never changes the draw stream (pinned by test)."""
        poke = {v: math.inf for v in order}
        poke0 = {v: math.inf for v in order}  # the undelayed (eager) cascade
        if prefetch:
            for v in order:
                if not preds[v]:
                    poke[v] = poke0[v] = t0
                elif steps[v].prefetch:
                    poke0[v] = min(poke0[u] for u in preds[v]) + self.msg
                    best = math.inf
                    for u in preds[v]:
                        d = 0.0
                        if self.timing is not None:
                            d = self.timing.poke_delay(steps[u].name, steps[v].name)
                        best = min(best, poke[u] + self.msg + d)
                    poke[v] = best

        prepare = {v: 0.0 for v in order}
        payload, start, end = {}, {}, {}
        double_billed = 0.0
        exposed_fetch = 0.0
        tracing = trace and self.tracer is not None
        draws: dict = {}  # v -> (cold, fetch, compute, edge_tr) when tracing
        faults_on = self.faults is not None and bool(self.faults)
        failed = dict.fromkeys(order, False)  # node dead or upstream dead
        fault_rec: dict = {}  # v -> (n_failures, dead) when faults active
        for v in order:
            step = steps[v]
            cold = self._cold(step, t0)
            fetch = step.fetch.sample(self.rng)
            compute = step.compute.sample(self.rng)
            if self.drift is not None:
                csc, _, fsc = self._scales(step.platform)
                compute *= csc
                fetch *= fsc
            # one transfer evaluation per edge per request, shared by the
            # payload join, the telemetry tap, and the timing feedback
            # (deterministic given the endpoints, so reuse is exact);
            # streaming splits it into a (first_byte, last_byte) pair —
            # identical components when no StreamConfig is attached
            edge_fl = {u: self._edge_transfer_fl(steps[u], step) for u in preds[v]}
            if tracing:
                draws[v] = (cold, fetch, compute, edge_fl)
            if not preds[v]:
                payload[v] = payload_last_v = t0 + self.msg / 2
            else:
                payload[v] = max(end[u] + edge_fl[u][0] for u in preds[v])
                payload_last_v = max(end[u] + edge_fl[u][1] for u in preds[v])
            if prefetch and poke[v] < math.inf:
                prepare[v] = poke[v] + cold + fetch
                start[v] = max(payload[v], prepare[v])
                double_billed += max(0.0, start[v] - prepare[v])
                exposed_fetch += max(0.0, prepare[v] - payload[v])
            else:
                start[v] = payload[v] + cold + fetch
                exposed_fetch += fetch
            end[v] = start[v] + compute
            if self.stream is not None and preds[v]:
                # per-chunk pipeline, closed form: the last chunk needs its
                # arrival plus one chunk's compute; never binds at chunks=1
                # (payload_last == payload <= start, so tail <= end). The
                # reciprocal multiply matches the numpy/jax backends' ops.
                tail = payload_last_v + compute * (1.0 / self.stream.chunks)
                if tail > end[v]:
                    end[v] = tail
            fault_up = fault_dead = False
            fault_nf = 0
            if faults_on:
                # fault pricing is a pure hash of (seed, node, request,
                # attempt) — no rng consumed, so the draw stream above is
                # bit-for-bit the fault-free one. Failed attempts delay the
                # node by their backoffs (applied after the streaming tail,
                # before _last_use, so the cold recurrence prices the
                # as-if-completed timeline on every backend identically);
                # an exhausted budget marks the request failed instead of
                # poisoning the recurrence with inf.
                fp = self.faults.plane(
                    step.name,
                    step.platform,
                    self._req_k,
                    self.retry,
                    region=self.platforms[step.platform].region,
                )
                end[v] += float(fp.extra_s[0])
                fault_nf = int(fp.n_failures[0])
                fault_dead = bool(fp.failed[0])
                fault_up = any(failed[u] for u in preds[v])
                failed[v] = fault_up or fault_dead
                if tracing:
                    fault_rec[v] = (fault_nf, fault_dead)
            self._last_use[(step.name, step.platform)] = end[v]
            if self.telemetry is not None and not fault_up:
                # an upstream-dead node never ran: no observations at all.
                # A node that ran records one error per failed attempt; its
                # success-side observations only land when it completed.
                region = self.platforms[step.platform].region
                if fault_nf:
                    self.telemetry.record_error(step.name, step.platform, fault_nf)
                if not fault_dead:
                    self.telemetry.record_compute(step.name, step.platform, compute)
                    if step.fetch.median > 0:
                        # the step's aggregate external fetch at its
                        # platform's region, keyed by fetch_key (default:
                        # the step name)
                        self.telemetry.record_fetch(
                            step.fetch_key or step.name, region, fetch
                        )
                    for u in preds[v]:
                        self.telemetry.record_transfer(
                            self.platforms[steps[u].platform].region,
                            region,
                            self.payload_size,
                            edge_fl[u][1],  # last byte: the whole transfer
                        )
                    if cold > 0:
                        self.telemetry.record_cold_start(
                            step.name, step.platform, cold
                        )
                    else:
                        self.telemetry.record_warm_hit(step.name, step.platform)
            if self.timing is not None and prefetch:
                self.timing.record_prepare(step.name, cold + fetch)
                self.timing.record_compute(step.name, end[v] - start[v])
                if preds[v] and poke[v] < math.inf:
                    # slack relative to the UNDELAYED cascade (poke0): the
                    # observation must not depend on the applied delays, or
                    # the EWMA chases its own feedback (on a fan-in, the
                    # delay embedded in prepare[v] is the argmin edge's,
                    # not each recorded edge's)
                    prepare0 = poke0[v] + cold + fetch
                    for u in preds[v]:
                        arrival = end[u] + edge_fl[u][1]
                        self.timing.record_slack(
                            steps[u].name, steps[v].name, arrival - prepare0
                        )
        total = max(end[v] for v in order if not succs[v]) - t0
        if tracing:
            self._emit_trace(
                order, steps, preds, t0, prefetch, poke, prepare, payload,
                start, end, draws, total, fault_rec=fault_rec,
            )
        if faults_on and any(failed.values()):
            # a dead node makes some sink unreachable: the request never
            # completes (availability accounting reads these as inf)
            total = math.inf
        return prepare, payload, start, end, total, double_billed, exposed_fetch

    def _emit_trace(
        self, order, steps, preds, t0, prefetch, poke, prepare, payload,
        start, end, draws, total, fault_rec=None,
    ):
        """Assemble one finished request into the obs span schema (sim
        clock). Chains may invoke the same step twice — positional ids get
        ``name@id`` labels then, so node names stay unique per trace.

        ``fault_rec`` ({v: (n_failures, dead)}, fault injection active):
        every failed attempt becomes a ``retry`` span event on the node
        span — the same schema the real engine emits — and an exhausted
        budget marks the span (and the root) ``failed``."""
        names = [steps[v].name for v in order]
        dup = len(set(names)) != len(names)

        def label(v):
            return f"{steps[v].name}@{v}" if dup else steps[v].name

        tr = self.tracer
        trace = tr.begin(
            name="sim-request",
            t0=t0,
            attrs={"backend": "scalar", "request_k": self._req_k},
        )
        for v in order:
            step = steps[v]
            cold, fetch, compute, edge_fl = draws[v]
            poked = prefetch and poke[v] < math.inf
            p0 = poke[v] if poked else payload[v]
            p1 = prepare[v] if poked else (payload[v] + cold + fetch)
            payload_t = {label(u): end[u] + edge_fl[u][0] for u in preds[v]}
            transfer_s = {label(u): edge_fl[u][0] for u in preds[v]}
            attrs = {
                "node": label(v),
                "platform": step.platform,
                "preds": [label(u) for u in preds[v]],
                "poke_t": poke[v] if poked else None,
                "prepare_t0": p0,
                "prepare_t1": p1,
                "cold_s": cold,
                "fetch_s": fetch,
                "compute_t0": start[v],
                "compute_s": compute,
                "payload_t": payload_t,
                "transfer_s": transfer_s,
            }
            if self.stream is not None:
                # exposed last-byte time: the compute tail past start+compute
                attrs["stream_wait_t0"] = start[v] + compute
                attrs["stream_wait_t1"] = end[v]
            node_span = trace.span(
                label(v),
                "node",
                t_start=min(p0, payload[v]),
                attrs=attrs,
            )
            if fault_rec and v in fault_rec:
                nf, dead = fault_rec[v]
                for a in range(nf):
                    node_span.add_event(
                        "retry",
                        {
                            "attempt": a + 1,
                            "node": label(v),
                            "platform": step.platform,
                            "injected": True,
                        },
                        t=start[v],
                    )
                if dead:
                    node_span.attrs["failed"] = True
                    trace.root.attrs["failed"] = True
            node_span.end(end[v])
            phases = [
                ("warm", p0, p0 + cold),
                ("fetch", p0 + cold, p1),
                ("compute", start[v], start[v] + compute),
            ]
            if self.stream is not None and end[v] > start[v] + compute:
                phases.append(("stream_wait", start[v] + compute, end[v]))
            for phase, a, b in phases:
                ps = trace.span(
                    f"{phase}:{label(v)}",
                    phase,
                    parent=node_span,
                    t_start=a,
                    attrs={"node": label(v), "platform": step.platform},
                )
                ps.end(b)
            for u in preds[v]:
                ts = trace.span(
                    f"transfer:{label(u)}->{label(v)}",
                    "transfer",
                    t_start=end[u],
                    attrs={"src": label(u), "dst": label(v), "platform": step.platform},
                )
                ts.end(end[u] + edge_fl[u][0])
        tr.finish(trace, t_end=t0 + total)

    # -- the batched fast path (request axis vectorized) -----------------------
    def _cold_scan(
        self,
        t0s: np.ndarray,
        warm_end: np.ndarray,
        cold_end: np.ndarray,
        keep_warm_s: float,
    ) -> np.ndarray:
        """Boolean cold mask for one (step, platform) node: the ``_last_use``
        recurrence, request-major. ``warm_end``/``cold_end`` are the node's
        end times under the warm / cold hypothesis (``cold_end >= warm_end``
        since the cold draw is nonnegative).

        A request k can only be cold if even the EARLIEST possible previous
        end — the warm one — left a gap past ``keep_warm_s``; everything
        else is warm by construction. So the scan walks just those
        candidates (for the paper's 1 req/s streams that is request 0 and
        nothing else), resolving each against the actual previous end
        (cold or warm per the mask built so far). Exact, and O(candidates)
        instead of O(n_requests)."""
        n = len(t0s)
        mask = np.zeros(n, dtype=bool)
        if n == 0:
            return mask
        # request 0 measures against _last_use = -inf (fresh experiment)
        mask[0] = math.inf > keep_warm_s
        cand = np.nonzero(t0s[1:] - warm_end[:-1] > keep_warm_s)[0] + 1
        for k in cand:
            last = cold_end[k - 1] if mask[k - 1] else warm_end[k - 1]
            mask[k] = (t0s[k] - last) > keep_warm_s
        return mask

    def _run_graph_vectorized(
        self, order, steps, preds, succs, t0s: np.ndarray, prefetch: bool
    ) -> np.ndarray:
        """``_run_graph`` with the request axis vectorized: one pass over
        the nodes in topo order, every recurrence variable a ``(n,)`` array.
        Returns the per-request totals.

        Draw-order contract (pinned by tests/test_vecsim.py): per node in
        topo order, ``n`` cold-start draws, then ``n`` fetch draws, then
        ``n`` compute draws — so the stream differs from the scalar path's
        request-major interleaving but every marginal distribution is
        identical (cold draws are masked by the ``_cold_scan`` result
        instead of being conditionally consumed). Telemetry is fed one
        aggregate observation batch per node/edge rather than n singles.

        Not supported here (use the scalar path): ``timing=`` (the learned
        poke delay is per-request feedback, inherently sequential) and
        graphs where one (name, platform) pair spans several nodes (its
        cold recurrence couples nodes across requests)."""
        if self.timing is not None:
            raise ValueError(
                "vectorized experiments do not support timing=: the poke "
                "controller learns from per-request feedback; use the "
                "scalar backend (backend='scalar')"
            )
        keys = [(steps[v].name, steps[v].platform) for v in order]
        if len(set(keys)) != len(keys):
            raise ValueError(
                "vectorized experiments need a unique (name, platform) per "
                "node — a duplicated pair couples the cold-start recurrence "
                "across nodes; use the scalar backend (backend='scalar')"
            )
        n = len(t0s)
        if n == 0:
            self._req_k = 0
            return np.empty(0)
        request_ks = np.arange(n)
        scale_cache: dict = {}

        def scales_for(platform: str) -> tuple:
            arrs = scale_cache.get(platform)
            if arrs is None:
                arrs = scale_cache[platform] = self.drift.scale_arrays(
                    request_ks, platform
                )
            return arrs

        inf = np.full(n, math.inf)
        tel = self.telemetry
        tracing = self.tracer is not None
        rec: dict = {}  # v -> per-request arrays, retained only when tracing
        poke: dict = {}
        end: dict = {}
        total = np.full(n, -math.inf)
        faults_on = self.faults is not None and bool(self.faults)
        failed_by_node: dict = {}  # v -> (n,) bool, own-dead OR upstream-dead
        failed_any = np.zeros(n, dtype=bool)
        fault_rec: dict = {}  # v -> (n_failures, node_failed) when tracing
        for v in order:
            step = steps[v]
            plat = self.platforms[step.platform]
            cold_draw = plat.cold_start.sample_many(self.rng, n)
            fetch = step.fetch.sample_many(self.rng, n)
            compute = step.compute.sample_many(self.rng, n)
            if self.drift is not None:
                csc, _, fsc = scales_for(step.platform)
                compute = compute * csc
                fetch = fetch * fsc
            fp = None
            node_ok = None  # rows whose success-side telemetry should land
            if faults_on:
                # the fault plane is hash-based (no rng) — draws above are
                # bit-for-bit the fault-free stream; see _run_graph
                fp = self.faults.plane(
                    step.name, step.platform, request_ks, self.retry,
                    region=plat.region,
                )
                up = np.zeros(n, dtype=bool)
                for u in preds[v]:
                    up |= failed_by_node[u]
                node_failed = up | fp.failed
                failed_by_node[v] = node_failed
                failed_any |= fp.failed
                node_ok = ~node_failed
                if tel is not None:
                    # one error per failed attempt of every node that RAN
                    # (upstream-dead nodes never launched their attempts)
                    n_err = int(fp.n_failures[~up].sum())
                    if n_err:
                        tel.record_error_batch(step.name, step.platform, n_err)
                if tracing:
                    fault_rec[v] = (fp.n_failures, node_failed)
            # poke cascade (min over in-edges; structural, uniform over k)
            if not prefetch:
                poke_v = inf
            elif not preds[v]:
                poke_v = t0s
            elif step.prefetch:
                poke_v = np.minimum.reduce([poke[u] for u in preds[v]]) + self.msg
            else:
                poke_v = inf
            poke[v] = poke_v
            # payload join (max over in-edges of upstream end + transfer);
            # streaming gates it on first bytes and tracks last bytes too
            stream_on = self.stream is not None
            edge_tr: dict = {}
            payload_last = None
            if not preds[v]:
                payload = t0s + self.msg / 2
                if stream_on:
                    payload_last = payload
            else:
                arrivals = []
                arrivals_last = []
                for u in preds[v]:
                    first, last = self._pair_transfer_fl(steps[u], step)
                    if self.drift is not None:
                        sc = np.maximum(
                            scales_for(steps[u].platform)[1],
                            scales_for(step.platform)[1],
                        )
                        first = first * sc
                        last = last * sc if stream_on else first
                    arrivals.append(end[u] + first)
                    if stream_on:
                        arrivals_last.append(end[u] + last)
                    if tracing:
                        edge_tr[u] = np.broadcast_to(np.asarray(first, float), (n,))
                    if tel is not None:
                        last_rows = np.broadcast_to(last, (n,))
                        if node_ok is not None:
                            last_rows = last_rows[node_ok]
                        tel.record_transfer_batch(
                            self.platforms[steps[u].platform].region,
                            plat.region,
                            self.payload_size,
                            last_rows,
                        )
                payload = np.maximum.reduce(arrivals)
                if stream_on:
                    payload_last = np.maximum.reduce(arrivals_last)
            # start/end under both cold hypotheses, then the cold scan
            if prefetch and not math.isinf(poke_v[0]):
                warm_start = np.maximum(payload, poke_v + fetch)
                cold_start = np.maximum(payload, poke_v + cold_draw + fetch)
            else:
                warm_start = payload + fetch
                cold_start = warm_start + cold_draw
            warm_end = warm_start + compute
            cold_end = cold_start + compute
            if stream_on and preds[v]:
                # per-chunk pipeline tail (closed form; see _run_graph) —
                # applied to both hypotheses, so cold_end >= warm_end holds
                tail = payload_last + compute * (1.0 / self.stream.chunks)
                warm_end = np.maximum(warm_end, tail)
                cold_end = np.maximum(cold_end, tail)
            if fp is not None:
                # retry backoffs delay the node under BOTH hypotheses (the
                # offset preserves cold_end >= warm_end), after the
                # streaming tail and before the cold scan — matching the
                # scalar path's end[v] += extra ordering exactly
                warm_end = warm_end + fp.extra_s
                cold_end = cold_end + fp.extra_s
            mask = self._cold_scan(t0s, warm_end, cold_end, plat.keep_warm_s)
            end_v = np.where(mask, cold_end, warm_end)
            end[v] = end_v
            if tracing:
                rec[v] = (
                    poke_v, payload, mask, cold_draw, fetch, compute, edge_tr,
                    payload_last,
                )
            self._last_use[(step.name, step.platform)] = float(end_v[-1])
            if tel is not None:
                ok = node_ok if node_ok is not None else slice(None)
                tel.record_compute_batch(step.name, step.platform, compute[ok])
                if step.fetch.median > 0:
                    tel.record_fetch_batch(
                        step.fetch_key or step.name, plat.region, fetch[ok]
                    )
                ok_mask = mask if node_ok is None else (mask & node_ok)
                n_cold = int(ok_mask.sum())
                n_seen = n if node_ok is None else int(node_ok.sum())
                tel.record_cold_start_batch(
                    step.name,
                    step.platform,
                    n_cold,
                    n_seen - n_cold,
                    cold_draw[ok_mask],
                )
            if not succs[v]:
                total = np.maximum(total, end_v)
        if tracing:
            self._emit_traces_vectorized(
                order, steps, preds, prefetch, t0s, rec, end,
                fault_rec=fault_rec if faults_on else None,
            )
        self._req_k = n
        totals = total - t0s
        if faults_on and failed_any.any():
            # dead requests are priced as-if-completed inside the
            # recurrence (cold bookkeeping stays backend-identical) but
            # REPORTED as never finishing
            totals = np.where(failed_any, math.inf, totals)
        return totals

    def _emit_traces_vectorized(
        self, order, steps, preds, prefetch, t0s, rec, end, fault_rec=None
    ):
        """Sampled per-request traces from the retained vectorized arrays:
        ``tracer.sample`` evenly spaced requests become ``obs`` traces in
        the same schema as the scalar path — pure array indexing after the
        fact, so the draw stream is untouched. ``fault_rec`` ({v:
        (n_failures, node_failed) arrays}) adds the scalar path's ``retry``
        span events / ``failed`` marks to the sampled requests."""
        names = [steps[v].name for v in order]
        dup = len(set(names)) != len(names)

        def label(v):
            return f"{steps[v].name}@{v}" if dup else steps[v].name

        tr = self.tracer
        for k in self._trace_sample_idx(len(t0s)).tolist():
            t0 = float(t0s[k])
            trace = tr.begin(
                name="sim-request",
                t0=t0,
                attrs={"backend": "numpy", "request_k": k},
            )
            t_sink = t0
            for v in order:
                step = steps[v]
                (
                    poke_v, payload, mask, cold_draw, fetch, compute, edge_tr,
                    payload_last,
                ) = rec[v]
                poked = prefetch and not math.isinf(float(poke_v[k]))
                cold = float(cold_draw[k]) if mask[k] else 0.0
                fetch_k = float(fetch[k])
                compute_k = float(compute[k])
                end_k = float(end[v][k])
                pay_k = float(payload[k])
                p0 = float(poke_v[k]) if poked else pay_k
                p1 = p0 + cold + fetch_k
                if payload_last is None:
                    start_k = end_k - compute_k
                else:
                    # end may carry a streaming tail past start + compute,
                    # so recompute start from the gating quantities
                    start_k = max(pay_k, p1) if poked else p1
                payload_t = {
                    label(u): float(end[u][k]) + float(edge_tr[u][k])
                    for u in preds[v]
                }
                transfer_s = {label(u): float(edge_tr[u][k]) for u in preds[v]}
                attrs = {
                    "node": label(v),
                    "platform": step.platform,
                    "preds": [label(u) for u in preds[v]],
                    "poke_t": p0 if poked else None,
                    "prepare_t0": p0,
                    "prepare_t1": p1,
                    "cold_s": cold,
                    "fetch_s": fetch_k,
                    "compute_t0": start_k,
                    "compute_s": compute_k,
                    "payload_t": payload_t,
                    "transfer_s": transfer_s,
                }
                if payload_last is not None:
                    attrs["stream_wait_t0"] = start_k + compute_k
                    attrs["stream_wait_t1"] = end_k
                node_span = trace.span(
                    label(v),
                    "node",
                    t_start=min(p0, pay_k),
                    attrs=attrs,
                )
                if fault_rec is not None and v in fault_rec:
                    nf_a, dead_a = fault_rec[v]
                    for a in range(int(nf_a[k])):
                        node_span.add_event(
                            "retry",
                            {
                                "attempt": a + 1,
                                "node": label(v),
                                "platform": step.platform,
                                "injected": True,
                            },
                            t=start_k,
                        )
                    if bool(dead_a[k]):
                        node_span.attrs["failed"] = True
                        trace.root.attrs["failed"] = True
                node_span.end(end_k)
                t_sink = max(t_sink, end_k)
            tr.finish(trace, t_end=t_sink)

    # -- one chain request (degenerate DAG, positional keys) -------------------
    def run_request(self, steps, t0: float, prefetch: bool) -> RequestTrace:
        ids = list(range(len(steps)))
        smap = dict(enumerate(steps))
        preds = {i: ([] if i == 0 else [i - 1]) for i in ids}
        succs = {i: ([i + 1] if i + 1 < len(steps) else []) for i in ids}
        prepare, payload, start, end, total, db, ef = self._run_graph(
            ids, smap, preds, succs, t0, prefetch
        )
        self._req_k += 1
        return RequestTrace(
            total,
            [start[i] for i in ids],
            [end[i] for i in ids],
            [prepare[i] for i in ids],
            [payload[i] for i in ids],
            db,
            ef,
        )

    # -- one DAG request (explicit edge list, name keys) -----------------------
    def run_dag_request(self, steps, edges, t0: float, prefetch: bool) -> DagTrace:
        smap = {s.name: s for s in steps}
        preds, succs, order = _graph(steps, edges)
        prepare, payload, start, end, total, db, ef = self._run_graph(
            order, smap, preds, succs, t0, prefetch
        )
        self._req_k += 1
        return DagTrace(total, start, end, prepare, payload, db, ef)

    # -- the one experiment entry point -----------------------------------------
    def simulate(self, spec: ExperimentSpec, backend: str = "numpy") -> np.ndarray:
        """Run one experiment described by ``spec`` on the chosen backend
        (``"scalar"``, ``"numpy"`` or ``"jax"`` — see the module docstring
        for the matrix). Returns per-request totals: shape
        ``(n_requests,)`` when ``spec.seeds`` is None, else
        ``(len(seeds), n_requests)`` with one fresh rng stream per seed
        (the simulator's own rng is restored afterwards), so
        ``np.median(out, axis=1)`` gives the per-seed medians error bars
        are built from.

        ``backend="scalar"`` is the per-request reference loop (the only
        one that supports ``timing=``); ``"numpy"`` vectorizes the request
        axis; ``"jax"`` compiles the whole sweep (its draws come from
        ``jax.random``, so it matches the others statistically, and
        bit-exactly at sigma=0; with ``spec.seeds=None`` it runs the
        simulator's construction seed rather than continuing the numpy
        stream)."""
        if backend == "jax":
            tracer = spec.tracer if spec.tracer is not None else self.tracer
            totals = self.simulate_placements(spec, [spec.steps], _tracer=tracer)[
                :, 0, :
            ]
            return totals if spec.seeds is not None else totals[0]
        if backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}: expected one of {_BACKENDS}"
            )
        saved_drift, saved_tel = self.drift, self.telemetry
        saved_tracer, saved_stream = self.tracer, self.stream
        saved_faults, saved_retry = self.faults, self.retry
        if spec.drift is not None:
            self.drift = spec.drift
        if spec.telemetry is not None:
            self.telemetry = spec.telemetry
        if spec.tracer is not None:
            self.tracer = spec.tracer
        if spec.stream is not None:
            self.stream = spec.stream
        if spec.faults is not None:
            self.faults = spec.faults
        if spec.retry is not None:
            self.retry = spec.retry
        try:
            order, smap, preds, succs = _spec_graph(spec.steps, spec.edges)
            t0s = np.arange(spec.n_requests) * spec.interarrival_s
            if spec.seeds is None:
                return self._run_stream(
                    order, smap, preds, succs, t0s, spec.prefetch, backend
                )
            out = np.empty((len(spec.seeds), spec.n_requests))
            saved_rng = self.rng
            try:
                for i, seed in enumerate(spec.seeds):
                    self.rng = np.random.default_rng(seed)
                    out[i] = self._run_stream(
                        order, smap, preds, succs, t0s, spec.prefetch, backend
                    )
            finally:
                self.rng = saved_rng
            return out
        finally:
            self.drift, self.telemetry = saved_drift, saved_tel
            self.tracer, self.stream = saved_tracer, saved_stream
            self.faults, self.retry = saved_faults, saved_retry

    def _trace_sample_idx(self, n: int) -> np.ndarray:
        """Which request indices of an n-request stream get a trace:
        ``tracer.sample`` evenly spaced requests, chosen deterministically
        (never from the experiment rng — sampling stays draw-neutral)."""
        k = getattr(self.tracer, "sample", 8) or 0
        if n == 0 or k <= 0:
            return np.empty(0, dtype=int)
        return np.unique(np.linspace(0, n - 1, min(k, n)).round().astype(int))

    def _run_stream(self, order, smap, preds, succs, t0s, prefetch, backend):
        """One request stream on the current rng: the scalar loop or the
        vectorized pass, from a fresh experiment (cold containers, drift
        indexed from request 0)."""
        self._last_use = {}
        self._req_k = 0
        if backend == "numpy":
            return self._run_graph_vectorized(order, smap, preds, succs, t0s, prefetch)
        sampled = (
            frozenset(self._trace_sample_idx(len(t0s)).tolist())
            if self.tracer is not None
            else frozenset()
        )
        out = np.empty(len(t0s))
        for k, t0 in enumerate(t0s):
            out[k] = self._run_graph(
                order, smap, preds, succs, float(t0), prefetch, trace=k in sampled
            )[4]
            self._req_k += 1
        return out

    def simulate_placements(
        self, spec: ExperimentSpec, placements, dtype=np.float64, _tracer=None
    ) -> np.ndarray:
        """Score a whole candidate placement set under common random
        numbers in ONE jitted jax call: ``placements`` is a sequence of
        step-sequences, each shaped like ``spec.steps`` (same length for a
        chain, same step names for a DAG — only the platform assignments
        and per-step distributions differ). Returns totals of shape
        ``(n_seeds, n_placements, n_requests)``; seeds default to the
        simulator's construction seed. Every placement sees the same
        per-seed draws, so differences between rows are placement effects,
        not sampling noise (the scorer's CRN property). ``dtype=np.float32``
        halves memory traffic for big sweeps at ~1e-7 relative cost.

        ``_tracer`` is the private hand-off from ``simulate(backend="jax",
        tracer=...)``: sampled per-request ``obs`` traces are rebuilt
        host-side for the FIRST seed and FIRST placement (the spec's own
        steps when called through ``simulate``). Public placement-scoring
        callers never pass it, so the scorer path stays pure."""
        from repro.core import jaxsim  # deferred: jax pays init cost

        telemetry = spec.telemetry if spec.telemetry is not None else self.telemetry
        if telemetry is not None:
            raise ValueError(
                "backend='jax' does not support telemetry=: observations "
                "are per-request side effects; use backend='numpy'"
            )
        placements = [tuple(p) for p in placements]
        if not placements:
            raise ValueError("placements must be non-empty")
        order, _, preds, succs = _spec_graph(placements[0], spec.edges)
        if spec.edges is None:
            step_sets = [dict(enumerate(p)) for p in placements]
        else:
            step_sets = [{s.name: s for s in p} for p in placements]
        seeds = spec.seeds if spec.seeds is not None else (self.seed,)
        drift = spec.drift if spec.drift is not None else self.drift
        stream = spec.stream if spec.stream is not None else self.stream
        faults = spec.faults if spec.faults is not None else self.faults
        retry = spec.retry if spec.retry is not None else self.retry
        t0s = np.arange(spec.n_requests) * spec.interarrival_s
        if _tracer is None:
            return jaxsim.run_batched(
                self, order, step_sets, preds, succs, t0s, spec.prefetch,
                list(seeds), drift=drift, dtype=dtype, stream=stream,
                faults=faults, retry=retry,
            )
        sample_idx = np.unique(
            np.linspace(
                0,
                max(spec.n_requests - 1, 0),
                min(getattr(_tracer, "sample", 8) or 0, spec.n_requests),
            )
            .round()
            .astype(int)
        )
        totals, sampled = jaxsim.run_batched(
            self, order, step_sets, preds, succs, t0s, spec.prefetch,
            list(seeds), drift=drift, dtype=dtype, sample_idx=sample_idx,
            stream=stream, faults=faults, retry=retry,
        )
        self._emit_traces_jax(
            order,
            step_sets[0],
            preds,
            spec.prefetch,
            t0s,
            sample_idx,
            tuple(a[0, 0] for a in sampled),  # first seed, first placement
            drift,
            _tracer,
            seed=seeds[0],
            stream=stream,
        )
        return totals

    def _emit_traces_jax(
        self, order, steps, preds, prefetch, t0s, sample_idx, sampled,
        drift, tracer, seed, stream=None,
    ):
        """Rebuild ``obs`` traces from the jax sweep's sampled scan ys
        (payload / effective cold / fetch / compute / end, each (V, k)).
        The draw-free pieces are recomputed host-side: the poke cascade is
        ``t0 + depth * msg`` (static hop depths) and the transfer model is
        deterministic given the endpoints (+ drift scales at the sampled
        request index) — the exact arrays ``jaxsim._build`` feeds the
        device."""
        from repro.core import jaxsim

        payload_a, cold_a, fetch_a, compute_a, end_a = sampled
        saved_stream = self.stream
        self.stream = stream  # _transfer_fl reads it (restored in finally)
        try:
            self._emit_traces_jax_inner(
                jaxsim, order, steps, preds, prefetch, t0s, sample_idx,
                payload_a, cold_a, fetch_a, compute_a, end_a, drift, tracer,
                seed, stream,
            )
        finally:
            self.stream = saved_stream

    def _emit_traces_jax_inner(
        self, jaxsim, order, steps, preds, prefetch, t0s, sample_idx,
        payload_a, cold_a, fetch_a, compute_a, end_a, drift, tracer, seed,
        stream,
    ):
        depth = jaxsim._poke_depths(order, steps, preds)
        idx = {v: i for i, v in enumerate(order)}
        names = [steps[v].name for v in order]
        dup = len(set(names)) != len(names)

        def label(v):
            return f"{steps[v].name}@{v}" if dup else steps[v].name

        for j, k in enumerate(np.asarray(sample_idx).tolist()):
            t0 = float(t0s[k])
            trace = tracer.begin(
                name="sim-request",
                t0=t0,
                attrs={"backend": "jax", "request_k": int(k), "seed": int(seed)},
            )
            t_sink = t0
            for i, v in enumerate(order):
                step = steps[v]
                poked = prefetch and math.isfinite(depth[i])
                poke_t = t0 + depth[i] * self.msg if poked else None
                cold = float(cold_a[i, j])
                fetch = float(fetch_a[i, j])
                compute = float(compute_a[i, j])
                end_k = float(end_a[i, j])
                pay_k = float(payload_a[i, j])
                p0 = poke_t if poked else pay_k
                p1 = p0 + cold + fetch
                if stream is None:
                    start_k = end_k - compute
                else:
                    # end may carry a streaming tail past start + compute
                    start_k = max(pay_k, p1) if poked else p1
                payload_t, transfer_s = {}, {}
                for u in preds[v]:
                    tr = self._pair_transfer_fl(steps[u], step)[0]
                    if drift is not None:
                        tr *= max(
                            drift.scales(k, steps[u].platform)[1],
                            drift.scales(k, step.platform)[1],
                        )
                    payload_t[label(u)] = float(end_a[idx[u], j]) + tr
                    transfer_s[label(u)] = tr
                attrs = {
                    "node": label(v),
                    "platform": step.platform,
                    "preds": [label(u) for u in preds[v]],
                    "poke_t": poke_t,
                    "prepare_t0": p0,
                    "prepare_t1": p1,
                    "cold_s": cold,
                    "fetch_s": fetch,
                    "compute_t0": start_k,
                    "compute_s": compute,
                    "payload_t": payload_t,
                    "transfer_s": transfer_s,
                }
                if stream is not None:
                    attrs["stream_wait_t0"] = start_k + compute
                    attrs["stream_wait_t1"] = end_k
                node_span = trace.span(
                    label(v),
                    "node",
                    t_start=min(p0, pay_k),
                    attrs=attrs,
                )
                node_span.end(end_k)
                t_sink = max(t_sink, end_k)
            tracer.finish(trace, t_end=t_sink)

    # -- legacy wrappers (paper: 1 req/s for 30 min) ----------------------------
    def _shim_backend(self, vectorized, backend, default):
        if vectorized is not _VECTORIZED_UNSET:
            warnings.warn(
                "vectorized= is deprecated; pass backend='numpy' "
                "(vectorized=True) or backend='scalar' (vectorized=False)",
                DeprecationWarning,
                stacklevel=3,
            )
            if backend is not None:
                raise TypeError(
                    "pass either backend= or the deprecated vectorized=, "
                    "not both"
                )
            return "numpy" if vectorized else "scalar"
        return backend if backend is not None else default

    def run_experiment(
        self,
        steps,
        n_requests: int = 1800,
        interarrival_s: float = 1.0,
        prefetch: bool = True,
        vectorized=_VECTORIZED_UNSET,
        *,
        backend: Optional[str] = None,
    ) -> np.ndarray:
        backend = self._shim_backend(vectorized, backend, "scalar")
        return self.simulate(
            ExperimentSpec(
                steps,
                n_requests=n_requests,
                interarrival_s=interarrival_s,
                prefetch=prefetch,
            ),
            backend=backend,
        )

    def run_dag_experiment(
        self,
        steps,
        edges,
        n_requests: int = 1800,
        interarrival_s: float = 1.0,
        prefetch: bool = True,
        vectorized=_VECTORIZED_UNSET,
        *,
        backend: Optional[str] = None,
    ) -> np.ndarray:
        backend = self._shim_backend(vectorized, backend, "scalar")
        return self.simulate(
            ExperimentSpec(
                steps,
                edges=edges,
                n_requests=n_requests,
                interarrival_s=interarrival_s,
                prefetch=prefetch,
            ),
            backend=backend,
        )

    def run_experiment_many(
        self,
        steps,
        seeds,
        n_requests: int = 1800,
        interarrival_s: float = 1.0,
        prefetch: bool = True,
        edges=None,
        vectorized=_VECTORIZED_UNSET,
        *,
        backend: Optional[str] = None,
    ) -> np.ndarray:
        """Seed sweep, ``(len(seeds), n_requests)`` — see ``simulate``."""
        backend = self._shim_backend(vectorized, backend, "numpy")
        return self.simulate(
            ExperimentSpec(
                steps,
                edges=edges,
                n_requests=n_requests,
                interarrival_s=interarrival_s,
                prefetch=prefetch,
                seeds=tuple(seeds),
            ),
            backend=backend,
        )


def median(xs) -> float:
    return float(np.median(np.asarray(xs)))


# ---------------------------------------------------------------------------
# calibrated setups for the three paper experiments
# ---------------------------------------------------------------------------
def paper_platforms():
    return [
        SimPlatform(
            "tinyfaas-edge",
            "europe-west10",
            native_prefetch=True,
            allows_sync=True,
            cold_start=Dist(0.35, 0.3),
        ),
        SimPlatform("gcf", "europe-west10", cold_start=Dist(2.2, 0.4)),
        SimPlatform("lambda-us-east-1", "us-east-1", cold_start=Dist(1.1, 0.4)),
        SimPlatform("lambda-eu-central-1", "eu-central-1", cold_start=Dist(1.1, 0.4)),
    ]


def document_workflow_fig4():
    """§4.2: check (edge) -> virus (GCF) -> ocr (Lambda us) -> e_mail
    (Lambda us); all but the first step download data. Calibrated so the
    BASELINE median lands at the paper's 4.65 s."""
    return [
        SimStep("check", "tinyfaas-edge", compute=Dist(0.22)),
        SimStep("virus", "gcf", compute=Dist(0.30), fetch=Dist(0.32)),
        SimStep("ocr", "lambda-us-east-1", compute=Dist(0.45), fetch=Dist(1.45)),
        SimStep("e_mail", "lambda-us-east-1", compute=Dist(0.20), fetch=Dist(0.85)),
    ]


def shipping_workflow_fig6(ocr_platform: str):
    """§4.3: check+virus on the edge node, e_mail in us-east-1; only OCR
    fetches (large scanned documents; the data lives in us-east-1).
    ocr_platform is 'lambda-eu-central-1' (far) or 'lambda-us-east-1'
    (close). Both variants pre-fetch."""
    fetch = Dist(3.6) if ocr_platform == "lambda-eu-central-1" else Dist(0.9)
    return [
        SimStep("check", "tinyfaas-edge", compute=Dist(0.25)),
        SimStep("virus", "tinyfaas-edge", compute=Dist(0.40)),
        SimStep("ocr", ocr_platform, compute=Dist(5.85), fetch=fetch),
        SimStep("e_mail", "lambda-us-east-1", compute=Dist(0.35)),
    ]


def native_prefetch_workflow_fig8():
    """§4.4: two functions on the same edge node; A computes 5 s, B fetches
    256 KB from cross-provider object storage."""
    return [
        SimStep("func_a", "tinyfaas-edge", compute=Dist(5.0, 0.02)),
        SimStep("func_b", "tinyfaas-edge", compute=Dist(0.06), fetch=Dist(0.78)),
    ]
