"""Discrete-event simulator for the paper's experiments (§4.2–§4.4).

Public-cloud latencies cannot be measured in this container, so the three
paper experiments are reproduced here: per-component latency distributions
(cold start, object GET/PUT by size, inter-region RTT, compute) are
calibrated so the BASELINE medians match the paper's; the pre-fetching /
shipping deltas then EMERGE from the same two-phase protocol the real
middleware executes (poke cascade -> prepare || predecessor compute ->
payload -> handler). Nothing about the improvement is hard-coded.

Timeline recurrence per request (chain workflows):
    poke[i+1]    = poke[i] + msg_latency            (cascade)
    prepare[i]   = poke[i] + cold_i + fetch_i       (prefetch on)
    payload[i]   = end[i-1] + transfer_{i-1 -> i}
    start[i]     = max(payload[i], prepare[i])      (prefetch on)
                 = payload[i] + cold_i + fetch_i    (baseline)
    end[i]       = start[i] + compute_i

Double-billing per step (prefetch on): start[i] - prepare[i] clipped at 0 —
the instance is up and idle (paper §5.5); the learned timing controller
(core/timing.py) shrinks it by delaying the poke.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np


# ---------------------------------------------------------------------------
# latency model pieces
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Dist:
    """Lognormal around a median with multiplicative spread sigma."""
    median: float
    sigma: float = 0.12

    def sample(self, rng: np.random.Generator) -> float:
        if self.median <= 0:
            return 0.0
        return float(self.median * math.exp(rng.normal(0.0, self.sigma)))


@dataclass(frozen=True)
class SimPlatform:
    name: str
    region: str
    native_prefetch: bool = False
    allows_sync: bool = True
    cold_start: Dist = Dist(0.8, 0.3)
    keep_warm_s: float = 900.0


@dataclass(frozen=True)
class SimStep:
    name: str
    platform: str
    compute: Dist
    fetch: Dist = Dist(0.0)      # external data download at the step's region
    prefetch: bool = True


@dataclass
class RequestTrace:
    total_s: float
    start: list
    end: list
    prepare: list
    payload: list
    double_billed_s: float
    exposed_fetch_s: float


class ObjectLatency:
    """Object-store GET/PUT between regions: fixed per-op overhead + size/bw.
    Captures the paper's §4.4 observation that even a 256 KB cross-provider
    S3 GET costs ~0.8 s (TLS + cross-region + S3 service latency)."""

    def __init__(self, overhead_same=0.03, overhead_cross=0.35,
                 bw_same=50e6, bw_cross=8e6):
        self.overhead_same = overhead_same
        self.overhead_cross = overhead_cross
        self.bw_same = bw_same
        self.bw_cross = bw_cross

    def op_s(self, src_region, dst_region, size_bytes):
        same = src_region == dst_region
        oh = self.overhead_same if same else self.overhead_cross
        bw = self.bw_same if same else self.bw_cross
        return oh + size_bytes / bw


class WorkflowSimulator:
    def __init__(self, platforms, msg_latency_s: float = 0.045,
                 object_latency: Optional[ObjectLatency] = None,
                 payload_size_bytes: float = 1.5e6, seed: int = 0):
        self.platforms = {p.name: p for p in platforms}
        self.msg = msg_latency_s
        self.obj = object_latency or ObjectLatency()
        self.payload_size = payload_size_bytes
        self.rng = np.random.default_rng(seed)
        self._last_use: dict = {}

    # -- transfer of the inter-step payload ------------------------------------
    def _transfer_s(self, src: SimPlatform, dst: SimPlatform) -> float:
        if dst.native_prefetch and dst.allows_sync \
                and src.region == dst.region:
            return self.msg * 0.1        # direct local call (tinyFaaS)
        # public-cloud path: buffer via object store (PUT at src + GET at dst)
        return (self.obj.op_s(src.region, dst.region, self.payload_size)
                + self.obj.op_s(dst.region, dst.region, self.payload_size))

    def _cold(self, step: SimStep, t: float) -> float:
        plat = self.platforms[step.platform]
        key = (step.name, step.platform)
        last = self._last_use.get(key, -math.inf)
        cold = (t - last) > plat.keep_warm_s
        return plat.cold_start.sample(self.rng) if cold else 0.0

    # -- one request -------------------------------------------------------------
    def run_request(self, steps, t0: float, prefetch: bool) -> RequestTrace:
        n = len(steps)
        poke = [math.inf] * n
        prepare = [0.0] * n
        payload = [0.0] * n
        start = [0.0] * n
        end = [0.0] * n
        double_billed = 0.0
        exposed_fetch = 0.0

        if prefetch:
            poke[0] = t0
            for i in range(1, n):
                poke[i] = poke[i - 1] + self.msg if steps[i].prefetch \
                    else math.inf

        payload[0] = t0 + self.msg / 2
        for i, step in enumerate(steps):
            cold = self._cold(step, t0)
            fetch = step.fetch.sample(self.rng)
            if prefetch and poke[i] < math.inf:
                prepare[i] = poke[i] + cold + fetch
                start[i] = max(payload[i], prepare[i])
                double_billed += max(0.0, start[i] - prepare[i])
                exposed_fetch += max(0.0, prepare[i] - payload[i])
            else:
                start[i] = payload[i] + cold + fetch
                exposed_fetch += fetch
            end[i] = start[i] + step.compute.sample(self.rng)
            self._last_use[(step.name, step.platform)] = end[i]
            if i + 1 < n:
                src = self.platforms[step.platform]
                dst = self.platforms[steps[i + 1].platform]
                payload[i + 1] = end[i] + self._transfer_s(src, dst)
        return RequestTrace(end[-1] - t0, start, end, prepare, payload,
                            double_billed, exposed_fetch)

    # -- an experiment (paper: 1 req/s for 30 min) --------------------------------
    def run_experiment(self, steps, n_requests: int = 1800,
                       interarrival_s: float = 1.0,
                       prefetch: bool = True) -> np.ndarray:
        self._last_use = {}
        out = np.empty(n_requests)
        for k in range(n_requests):
            out[k] = self.run_request(steps, k * interarrival_s,
                                      prefetch).total_s
        return out


def median(xs) -> float:
    return float(np.median(np.asarray(xs)))


# ---------------------------------------------------------------------------
# calibrated setups for the three paper experiments
# ---------------------------------------------------------------------------
def paper_platforms():
    return [
        SimPlatform("tinyfaas-edge", "europe-west10", native_prefetch=True,
                    allows_sync=True, cold_start=Dist(0.35, 0.3)),
        SimPlatform("gcf", "europe-west10", cold_start=Dist(2.2, 0.4)),
        SimPlatform("lambda-us-east-1", "us-east-1", cold_start=Dist(1.1, 0.4)),
        SimPlatform("lambda-eu-central-1", "eu-central-1",
                    cold_start=Dist(1.1, 0.4)),
    ]


def document_workflow_fig4():
    """§4.2: check (edge) -> virus (GCF) -> ocr (Lambda us) -> e_mail
    (Lambda us); all but the first step download data. Calibrated so the
    BASELINE median lands at the paper's 4.65 s."""
    return [
        SimStep("check", "tinyfaas-edge", compute=Dist(0.22)),
        SimStep("virus", "gcf", compute=Dist(0.30), fetch=Dist(0.32)),
        SimStep("ocr", "lambda-us-east-1", compute=Dist(0.45),
                fetch=Dist(1.45)),
        SimStep("e_mail", "lambda-us-east-1", compute=Dist(0.20),
                fetch=Dist(0.85)),
    ]


def shipping_workflow_fig6(ocr_platform: str):
    """§4.3: check+virus on the edge node, e_mail in us-east-1; only OCR
    fetches (large scanned documents; the data lives in us-east-1).
    ocr_platform is 'lambda-eu-central-1' (far) or 'lambda-us-east-1'
    (close). Both variants pre-fetch."""
    fetch = Dist(3.6) if ocr_platform == "lambda-eu-central-1" else Dist(0.9)
    return [
        SimStep("check", "tinyfaas-edge", compute=Dist(0.25)),
        SimStep("virus", "tinyfaas-edge", compute=Dist(0.40)),
        SimStep("ocr", ocr_platform, compute=Dist(5.85), fetch=fetch),
        SimStep("e_mail", "lambda-us-east-1", compute=Dist(0.35)),
    ]


def native_prefetch_workflow_fig8():
    """§4.4: two functions on the same edge node; A computes 5 s, B fetches
    256 KB from cross-provider object storage."""
    return [
        SimStep("func_a", "tinyfaas-edge", compute=Dist(5.0, 0.02)),
        SimStep("func_b", "tinyfaas-edge", compute=Dist(0.06),
                fetch=Dist(0.78)),
    ]
