"""Fault model shared by the simulator and the real engine (durable jobs).

Nothing in this repro could fail until now — every engine call succeeded
and every simulator draw completed. This module makes failures first-class
on BOTH sides of the sim/real split, from one schedule:

  ``FaultEvent``     per-(step, platform) transient error probability over
                     a request-index window — invocation errors, 429
                     throttling, the flaky-but-alive platform
  ``OutageEvent``    a platform (or a whole region) hard-down over a
                     request-index window — every attempt fails until the
                     window closes, the regional-failover scenario
  ``FaultSchedule``  the container, sitting next to ``DriftSchedule`` in
                     the simulator's surface; the engine's
                     ``FaultInjector`` raises from the same schedule
  ``RetryPolicy``    per-step retry budget: exponential backoff with
                     seeded jitter, plus the optional hedge knob the
                     engine's straggler duplication reads
  ``InjectedFault``  what the engine raises for an injected failure

Determinism contract: fault outcomes are a PURE FUNCTION of
``(schedule seed, step, platform, request index, attempt index)`` — a
counter-based splitmix64 hash, never the experiment rng. That buys three
properties at once: the scalar, numpy and jax simulator backends price the
identical fault plane bit-for-bit (the plane is precomputed host-side and
fed to the compiled sweep like the drift masks); an empty schedule draws
nothing, so disabled faults are bit-for-bit the fault-free run; and the
real engine's injector agrees with the simulator about WHICH request
fails, not just how many.

Pricing model (``FaultSchedule.plane``): attempt ``a`` of a node fails
when the platform is in an outage window or the attempt's hash uniform
falls under the composed transient probability. Each failed attempt with a
remaining budget pays its backoff delay (transient invocation errors
surface fast — throttling, 4xx — so the backoff IS the retry cost); a
node whose every attempt fails marks the request FAILED (the simulators
report ``inf`` for it, the engine dead-letters the job). Failed requests
are still priced as-if-completed inside the recurrence so the cold-start
bookkeeping stays identical across backends — only the reported total and
the telemetry change.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import NamedTuple, Optional

import numpy as np

_MASK64 = 0xFFFFFFFFFFFFFFFF
_GOLD = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
# distinct hash streams per consumer (failure draw vs backoff jitter)
_STREAM_FAIL = 0x51AB
_STREAM_JITTER = 0x7E57


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized over uint64 arrays)."""
    x = (x ^ (x >> np.uint64(30))) * _MIX1
    x = (x ^ (x >> np.uint64(27))) * _MIX2
    return x ^ (x >> np.uint64(31))


def hash_u01(seed: int, salt: int, attempt: int, stream: int, ks) -> np.ndarray:
    """Deterministic uniforms in [0, 1) from a counter-based hash — one
    value per request index in ``ks``. Order-independent and rng-free, so
    every backend (and the real engine) evaluates the same outcome for the
    same (seed, node, request, attempt) without consuming anyone's draw
    stream."""
    # uint64 wraparound is the point of splitmix64 — silence the scalar
    # overflow warning numpy raises on intentionally-modular multiplies
    with np.errstate(over="ignore"):
        x = np.asarray(ks, dtype=np.uint64) + _GOLD
        x = _mix64(x * _GOLD + np.uint64(seed & _MASK64))
        x = _mix64(x ^ np.uint64(salt & _MASK64))
        x = _mix64(x + np.uint64(((attempt << 16) ^ stream) & _MASK64) * _GOLD)
        return (x >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


def _node_salt(step: str, platform: str) -> int:
    """Stable 64-bit salt for a (step, platform) cell — shared by the
    simulator backends and the engine injector."""
    digest = hashlib.sha256(f"{step}@{platform}".encode()).digest()
    return int.from_bytes(digest[:8], "big")


@dataclass(frozen=True)
class RetryPolicy:
    """Per-step retry budget with exponential backoff + seeded jitter.

    Attempt ``a`` (0-based) that fails with a next attempt remaining waits
    ``backoff_base_s * backoff_multiplier**a * (1 + jitter * u)`` where
    ``u`` is the deterministic hash uniform for (step, platform, request,
    attempt) — seeded jitter, not wall-clock randomness, so simulated and
    real retries de-synchronize identically and reproducibly.

    ``hedge_after_s`` is read by the ENGINE only: when an attempt has not
    returned after that many seconds, a duplicate is launched and the
    first finisher wins (the loser is cancelled and counted). The
    simulator prices retries/outages but not hedges (stragglers there are
    just draws)."""

    max_attempts: int = 3
    backoff_base_s: float = 0.05
    backoff_multiplier: float = 2.0
    jitter: float = 0.5
    hedge_after_s: Optional[float] = None
    seed: int = 0

    def backoff_s(
        self, attempt: int, step: str = "", platform: str = "", request_k: int = 0
    ) -> float:
        u = float(
            hash_u01(
                self.seed,
                _node_salt(step, platform),
                attempt,
                _STREAM_JITTER,
                np.asarray([request_k]),
            )[0]
        )
        return (
            self.backoff_base_s
            * self.backoff_multiplier**attempt
            * (1.0 + self.jitter * u)
        )

    def backoff_arrays(
        self, attempt: int, step: str, platform: str, ks: np.ndarray
    ) -> np.ndarray:
        """``backoff_s`` over a whole request axis (the vectorized plane)."""
        u = hash_u01(self.seed, _node_salt(step, platform), attempt, _STREAM_JITTER, ks)
        return (
            self.backoff_base_s
            * self.backoff_multiplier**attempt
            * (1.0 + self.jitter * u)
        )


@dataclass(frozen=True)
class FaultEvent:
    """Transient per-attempt error probability on a platform (optionally
    one step of it) over the request-index window ``[from_request,
    to_request)`` (``to_request=None``: open-ended). Probabilities of
    overlapping events compose as independent failure sources."""

    platform: str
    p_error: float
    step: str = ""  # "" = every step on the platform
    from_request: int = 0
    to_request: Optional[int] = None


@dataclass(frozen=True)
class OutageEvent:
    """Hard platform (or whole-region) outage over ``[from_request,
    to_request)`` (``to_request=None``: open-ended): every attempt on a
    matching cell fails regardless of retry budget — the scenario the
    outage trigger must route around."""

    from_request: int
    to_request: Optional[int]
    platform: str = ""
    region: str = ""  # set instead of platform for a regional failover

    def __post_init__(self):
        if not self.platform and not self.region:
            raise ValueError("OutageEvent needs a platform or a region")


class FaultPlane(NamedTuple):
    """Per-(node, request-axis) fault pricing: seconds of retry backoff
    added to the node's end time, how many attempts failed, and whether
    the whole budget was exhausted (the request dead-letters)."""

    extra_s: np.ndarray  # (n,) float
    n_failures: np.ndarray  # (n,) int
    failed: np.ndarray  # (n,) bool


class FaultSchedule:
    """Fault injection for the simulator AND the engine: a list of
    ``FaultEvent`` / ``OutageEvent`` (mixed freely), keyed by request
    index like ``DriftSchedule``.

    With no events attached the schedule is falsy and every consumer
    short-circuits — bit-for-bit the fault-free behavior (outcomes come
    from a counter hash, never the experiment rng, so even an ACTIVE
    schedule leaves the latency draw stream untouched)."""

    def __init__(self, events=(), seed: int = 0):
        self.events = tuple(events)
        self.seed = seed
        self.faults = tuple(e for e in self.events if isinstance(e, FaultEvent))
        self.outages = tuple(e for e in self.events if isinstance(e, OutageEvent))
        unknown = [
            e for e in self.events if not isinstance(e, (FaultEvent, OutageEvent))
        ]
        if unknown:
            raise TypeError(f"not FaultEvent/OutageEvent: {unknown!r}")
        self._salts: dict = {}

    def __bool__(self) -> bool:
        return bool(self.events)

    def _salt(self, step: str, platform: str) -> int:
        key = (step, platform)
        s = self._salts.get(key)
        if s is None:
            s = self._salts[key] = _node_salt(step, platform)
        return s

    # -- per-(cell, request-axis) composition ---------------------------------
    def p_error_arrays(self, ks: np.ndarray, step: str, platform: str) -> np.ndarray:
        """Composed per-attempt transient error probability over the
        request axis: overlapping events fail independently, so
        ``p = 1 - prod(1 - p_i)`` over the events covering each index."""
        ks = np.asarray(ks)
        ok = np.ones(ks.shape, dtype=np.float64)
        for e in self.faults:
            if e.platform != platform or (e.step and e.step != step):
                continue
            m = ks >= e.from_request
            if e.to_request is not None:
                m &= ks < e.to_request
            ok = np.where(m, ok * (1.0 - e.p_error), ok)
        return 1.0 - ok

    def outage_arrays(
        self, ks: np.ndarray, platform: str, region: str = ""
    ) -> np.ndarray:
        """Boolean outage mask over the request axis for one platform (a
        region-scoped event downs every platform in the region)."""
        ks = np.asarray(ks)
        out = np.zeros(ks.shape, dtype=bool)
        for e in self.outages:
            hit = (e.platform and e.platform == platform) or (
                e.region and region and e.region == region
            )
            if not hit:
                continue
            m = ks >= e.from_request
            if e.to_request is not None:
                m &= ks < e.to_request
            out |= m
        return out

    def plane(
        self,
        step: str,
        platform: str,
        ks,
        retry: Optional[RetryPolicy] = None,
        region: str = "",
    ) -> FaultPlane:
        """The fault plane for one (step, platform) node over a request
        axis — the ONLY pricing routine, shared verbatim by the scalar
        loop (1-element axis), the numpy pass, and the jax backend's
        host-side build, so all three agree bit-for-bit.

        Attempt ``a`` fails when the cell is in an outage window or its
        hash uniform falls under the composed transient probability; the
        failure streak stops at the first success. Each failed attempt
        with budget remaining adds its seeded backoff to ``extra_s``;
        exhausting the budget sets ``failed``."""
        ks = np.atleast_1d(np.asarray(ks, dtype=np.int64))
        n = len(ks)
        max_attempts = retry.max_attempts if retry is not None else 1
        if not self.events:
            return FaultPlane(
                np.zeros(n), np.zeros(n, dtype=np.int64), np.zeros(n, dtype=bool)
            )
        p = self.p_error_arrays(ks, step, platform)
        out = self.outage_arrays(ks, platform, region)
        salt = self._salt(step, platform)
        streak = np.ones(n, dtype=bool)  # attempts so far ALL failed
        n_fail = np.zeros(n, dtype=np.int64)
        extra = np.zeros(n)
        for a in range(max_attempts):
            u = hash_u01(self.seed, salt, a, _STREAM_FAIL, ks)
            fail_a = (out | (u < p)) & streak
            n_fail += fail_a
            if retry is not None and a < max_attempts - 1:
                backoff = retry.backoff_arrays(a, step, platform, ks)
                extra = np.where(fail_a, extra + backoff, extra)
            streak = fail_a
        return FaultPlane(extra, n_fail, streak)

    # -- the engine-side single-attempt check ---------------------------------
    def attempt_outcome(
        self, step: str, platform: str, request_k: int, attempt: int, region: str = ""
    ) -> Optional[str]:
        """Does attempt ``attempt`` of (step, platform) at request
        ``request_k`` fail? Returns ``"outage"`` / ``"transient"`` / None —
        the engine's ``FaultInjector`` raises on non-None. Evaluates the
        exact hash the simulator's plane uses, so sim and engine disagree
        about nothing."""
        if not self.events:
            return None
        ks = np.asarray([request_k], dtype=np.int64)
        if bool(self.outage_arrays(ks, platform, region)[0]):
            return "outage"
        p = float(self.p_error_arrays(ks, step, platform)[0])
        if p <= 0.0:
            return None
        u = float(hash_u01(self.seed, self._salt(step, platform), attempt,
                           _STREAM_FAIL, ks)[0])
        return "transient" if u < p else None


class InjectedFault(RuntimeError):
    """An injected failure (transient error or platform outage) raised by
    the engine's ``FaultInjector`` inside ``_run_node``."""

    def __init__(self, kind: str, step: str, platform: str, request_k: int,
                 attempt: int):
        self.kind = kind
        self.step = step
        self.platform = platform
        self.request_k = request_k
        self.attempt = attempt
        super().__init__(
            f"injected {kind} fault: {step}@{platform} request={request_k} "
            f"attempt={attempt}"
        )


def availability(totals: np.ndarray) -> float:
    """Fraction of requests that completed (finite totals) — failed
    requests are reported as ``inf`` by every simulator backend."""
    totals = np.asarray(totals)
    if totals.size == 0:
        return 1.0
    return float(np.isfinite(totals).mean())


def _chain_failed(plane_failed_by_node) -> np.ndarray:
    """Request failed iff ANY node exhausted its budget (every node in a
    DAG is an ancestor of some sink, so one dead node kills the request)."""
    failed = None
    for f in plane_failed_by_node:
        failed = f if failed is None else (failed | f)
    return failed if failed is not None else np.zeros(0, dtype=bool)


INF = math.inf
