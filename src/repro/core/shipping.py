"""Function shipping + automated placement (paper §4.3, §5.3).

GeoFF can move a function to the platform where its data lives instead of
moving the data ("shipping functions to data"). The paper does this manually
(§4.3) and lists automation as future work (§5.3) — implemented here for
general DAG workflows:

``dag_cost`` is the modeled end-to-end (critical-path) cost of a placed
DAG under the pre-fetch overlap model; on a chain it telescopes to
``chain_cost`` exactly.

``place_dag`` minimizes ``dag_cost`` EXACTLY: a dynamic program over the
series-parallel decomposition of the graph (series/parallel reductions
carry Pareto tables of (path-cost, prepare-window) per terminal placement),
with an exhaustive fallback for small graphs that are not two-terminal
series-parallel, and the greedy topological scorer (``place_dag_greedy``,
the pre-DP baseline) only for graphs too large to enumerate.

``place_chain`` delegates to ``place_dag`` — a chain is series-parallel, so
the old chain DP's optimality (O(steps x platforms^2)) is preserved while
the duplicated scoring logic is gone.

The TPU-pod analogue: a serving step whose KV cache / checkpoint shards live
on pod A is shipped to pod A rather than streaming the state over DCN —
serving/disagg.py uses the same optimizer with state residency as data_deps.
"""

from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.graph import graph_views
from repro.core.workflow import StepSpec, WorkflowSpec

# exhaustive-search budget: max candidate assignments scored before place_dag
# falls back to the greedy (only reachable for large non-series-parallel
# graphs; chains and diamonds always take the exact series-parallel DP)
_EXHAUSTIVE_LIMIT = 20_000


@dataclass(frozen=True)
class PlacementCosts:
    """Cost model callbacks — wired to NetworkModel/ObjectLatency (sim) or
    measured EWMA stats (runtime, core/timing.py).

    ``transfer_fl`` (optional) splits an edge into latency + bandwidth
    terms: ``(platform_a, platform_b, size_bytes) -> (first_byte_s,
    last_byte_s)``. When set, the cost recurrence and the placement DP
    price pipelined edges — a successor starts on the first byte, and the
    last byte only bounds the tail ``compute / chunks``. When None, both
    components are ``transfer_s`` and every cost is exactly the
    whole-object model's."""

    fetch_s: Callable  # (step_name, platform, data_deps) -> seconds
    compute_s: Callable  # (step_name, platform) -> seconds
    transfer_s: Callable  # (platform_a, platform_b, size_bytes) -> seconds
    payload_size: float = 1.5e6
    transfer_fl: Optional[Callable] = None  # (a, b, size) -> (first, last)
    chunks: int = 1  # wire chunks per edge (the streaming data plane)


def exposed_fetch(fetch_s: float, window_s: float, prefetch: bool) -> float:
    """Fetch time visible on the critical path given an overlap window."""
    if not prefetch:
        return fetch_s
    return max(0.0, fetch_s - window_s)


def _edge_fl(costs: PlacementCosts, a, b) -> tuple:
    """(first_byte_s, last_byte_s) of one placed edge — collapsing to the
    whole-object transfer twice when no split model is attached."""
    if costs.transfer_fl is not None:
        return costs.transfer_fl(a, b, costs.payload_size)
    t = costs.transfer_s(a, b, costs.payload_size)
    return t, t


def _inv_chunks(costs: PlacementCosts) -> float:
    return 1.0 / max(1, costs.chunks)


def _topo(nodes, edges):
    """Predecessor lists + deterministic topological order (ties broken by
    ``nodes`` insertion order)."""
    pred, _, order = graph_views(nodes, edges)
    return pred, order


def _dag_cost_views(nodes, pred, order, placement, costs, prefetch):
    """The critical-path recurrence over precomputed graph views (hoisted
    out of ``dag_cost`` so the exhaustive search sorts the graph once)."""
    inv = _inv_chunks(costs)
    finish = {}
    total = 0.0
    for v in order:
        p = placement[v]
        s = nodes[v]
        f = costs.fetch_s(v, p, s.data_deps)
        c = costs.compute_s(v, p)
        ready = 0.0  # first-byte join: gates prepare + start
        ready_last = 0.0  # last-byte join: bounds the compute tail
        window = 0.0
        for u in pred[v]:
            first, last = _edge_fl(costs, placement[u], p)
            ready = max(ready, finish[u] + first)
            ready_last = max(ready_last, finish[u] + last)
            window = max(window, costs.compute_s(u, placement[u]) + first)
        finish[v] = ready + exposed_fetch(f, window, prefetch) + c
        if pred[v]:
            # per-chunk pipeline tail (never binds when first == last and
            # chunks == 1: ready_last + c <= finish[v])
            finish[v] = max(finish[v], ready_last + c * inv)
        total = max(total, finish[v])
    return total


def dag_cost(nodes, edges, placement, costs: PlacementCosts, prefetch=True) -> float:
    """Modeled end-to-end cost of a placed DAG: the critical-path recurrence

        ready[v]  = max over preds u of finish[u] + first_byte(p_u, p_v)
        window[v] = max over preds u of compute_u + first_byte(p_u, p_v)
        finish[v] = max(ready[v] + exposed_fetch(fetch_v, window[v])
                                 + compute_v,
                        ready_last[v] + compute_v / chunks)

    where ``ready_last`` joins last bytes. Without ``transfer_fl`` both
    byte marks are ``transfer_s`` and the tail never binds, so this is
    exactly the whole-object recurrence. The window is the guaranteed
    poke-to-payload overlap for ``v``'s pre-fetch (the cascade makes the
    true window larger, so this is the same conservative criterion the
    chain DP used). ``chain_cost`` is this recurrence on the degenerate
    chain graph."""
    pred, order = _topo(nodes, edges)
    return _dag_cost_views(nodes, pred, order, placement, costs, prefetch)


# ---------------------------------------------------------------------------
# exact placement: series-parallel DP with exhaustive fallback
# ---------------------------------------------------------------------------
# A table maps (source_platform, sink_platform) -> Pareto list of
# (D, W, R, placement): D = max over s->t paths of FIRST-byte transfers +
# INTERNAL node costs (terminal node costs excluded; internal windows are
# fully determined inside the subgraph), W = max over t's in-edges of
# compute_u + first-byte transfer (t's prepare window contribution), R =
# the same path max as D but joining LAST bytes into t (it bounds t's
# compute tail under streaming; R == D whenever transfer_fl is unset),
# placement = internal node assignments. The final cost is increasing in D
# and R and nonincreasing in W, so an entry is dominated iff another has
# D' <= D, R' <= R and W' >= W.


def _pareto(entries):
    # dominance sweep: after sorting by (D, R, -W), an entry can only be
    # dominated by an already-kept one (later entries never have both a
    # smaller-or-equal D and R without sorting earlier)
    entries.sort(key=lambda e: (e[0], e[2], -e[1]))
    kept = []
    for d, w, r, pl in entries:
        if any(kd <= d and kr <= r and kw >= w for kd, kw, kr, _ in kept):
            continue
        kept.append((d, w, r, pl))
    return kept


def _node_cost(n, p, window, nodes, costs, prefetch):
    f = costs.fetch_s(n, p, nodes[n].data_deps)
    return exposed_fetch(f, window, prefetch) + costs.compute_s(n, p)


def _base_table(u, v, cand, costs):
    t = {}
    for pu in cand[u]:
        cu = costs.compute_s(u, pu)
        for pv in cand[v]:
            first, last = _edge_fl(costs, pu, pv)
            t[(pu, pv)] = [(first, cu + first, last, {})]
    return t


def _series(t1, t2, m, nodes, costs, prefetch):
    """Compose in-table ``t1`` (u->m) and out-table ``t2`` (m->w) over the
    eliminated internal node ``m``; m's finish — the max of the prepared
    start plus compute (window from t1's W) and the last-byte tail — joins
    both path terms. With R == D and chunks == 1 the tail never binds and
    this is the classic 2-component fold."""
    inv = _inv_chunks(costs)
    out = defaultdict(list)
    by_pm = defaultdict(list)
    for (pm, pw), entries in t2.items():
        by_pm[pm].append((pw, entries))
    for (pu, pm), e1 in t1.items():
        for pw, e2 in by_pm.get(pm, ()):
            for d1, w1, r1, pl1 in e1:
                cm = _node_cost(m, pm, w1, nodes, costs, prefetch)
                fin = max(d1 + cm, r1 + costs.compute_s(m, pm) * inv)
                for d2, w2, r2, pl2 in e2:
                    out[(pu, pw)].append(
                        (fin + d2, w2, fin + r2, {**pl1, **pl2, m: pm})
                    )
    return {k: _pareto(v) for k, v in out.items()}


def _parallel(t1, t2):
    """Merge two tables between the same terminals: paths (both byte
    marks) and window contributions all combine by max (branches are
    disjoint, and D/R are offsets from the same source finish)."""
    out = {}
    for key in t1.keys() & t2.keys():
        entries = [
            (max(d1, d2), max(w1, w2), max(r1, r2), {**pl1, **pl2})
            for d1, w1, r1, pl1 in t1[key]
            for d2, w2, r2, pl2 in t2[key]
        ]
        out[key] = _pareto(entries)
    return out


def _sp_reduce(edges, tables, source, sink, nodes, costs, prefetch):
    """Run series/parallel reductions to a single (source, sink) edge.
    Returns its DP table, or None when the graph is not two-terminal
    series-parallel."""
    elist = [[a, b, t] for (a, b), t in zip(edges, tables)]
    while len(elist) > 1:
        # parallel reduction: merge duplicate (u, v) edges
        merged = {}
        order = []
        changed = False
        for e in elist:
            key = (e[0], e[1])
            if key in merged:
                merged[key][2] = _parallel(merged[key][2], e[2])
                changed = True
            else:
                merged[key] = e
                order.append(key)
        elist = [merged[k] for k in order]
        # series reduction: one internal node with in-degree = out-degree = 1
        indeg = defaultdict(list)
        outdeg = defaultdict(list)
        for e in elist:
            outdeg[e[0]].append(e)
            indeg[e[1]].append(e)
        reduced = False
        for m in list(indeg):
            if m in (source, sink):
                continue
            if len(indeg[m]) == 1 and len(outdeg[m]) == 1:
                e1, e2 = indeg[m][0], outdeg[m][0]
                new = [
                    e1[0],
                    e2[1],
                    _series(e1[2], e2[2], m, nodes, costs, prefetch),
                ]
                elist = [e for e in elist if e is not e1 and e is not e2]
                elist.append(new)
                reduced = True
                break
        if not (reduced or changed):
            return None  # stuck: not two-terminal series-parallel
    e = elist[0]
    if e[0] == source and e[1] == sink:
        return e[2]
    return None


def place_dag_greedy(
    nodes, edges, candidates, costs: PlacementCosts, prefetch: bool = True
) -> dict:
    """Greedy topological placement — the pre-DP baseline, kept for
    benchmarking (``benchmarks/placement_bench.py``) and as the fallback
    for graphs too large to solve exactly. Scores each node myopically:
    incoming transfers + exposed fetch + compute, predecessors fixed."""
    pred, order = _topo(nodes, edges)
    placement: dict = {}
    for u in order:
        s = nodes[u]
        options = candidates.get(u, [s.platform])

        def score(p):
            f = costs.fetch_s(u, p, s.data_deps)
            c = costs.compute_s(u, p)
            tin = sum(
                costs.transfer_s(placement[q], p, costs.payload_size)
                for q in pred[u]
                if q in placement
            )
            window = max(
                (costs.compute_s(q, placement[q]) for q in pred[u] if q in placement),
                default=0.0,
            )
            return tin + exposed_fetch(f, window, prefetch) + c

        placement[u] = min(options, key=score)
    return placement


def place_dag(
    nodes, edges, candidates, costs: PlacementCosts, prefetch: bool = True
) -> dict:
    """Exact placement minimizing ``dag_cost``.

    nodes: {name: StepSpec}; edges: [(src, dst)]. Returns {name: platform}.
    Two-terminal series-parallel graphs (chains, diamonds, nested fan-outs)
    solve by the reduction DP; small non-SP graphs enumerate; only large
    non-SP graphs fall back to the greedy."""
    cand = {n: list(candidates.get(n, [nodes[n].platform])) for n in nodes}
    touched = {a for a, _ in edges} | {b for _, b in edges}
    # isolated nodes are their own critical path: place independently
    placement = {
        n: min(
            cand[n],
            key=lambda p, n=n: _node_cost(n, p, 0.0, nodes, costs, prefetch),
        )
        for n in nodes
        if n not in touched
    }
    if not touched:
        return placement
    graph_nodes = {n: nodes[n] for n in nodes if n in touched}
    pred, order = _topo(graph_nodes, edges)
    sources = [n for n in order if not pred[n]]
    sinks = [n for n in order if all(n != a for a, _ in edges)]
    if len(sources) == 1 and len(sinks) == 1:
        s, t = sources[0], sinks[0]
        tables = [_base_table(a, b, cand, costs) for a, b in edges]
        table = _sp_reduce(list(edges), tables, s, t, graph_nodes, costs, prefetch)
        if table is not None:
            inv = _inv_chunks(costs)
            best = None
            for (ps, pt), entries in table.items():
                head = _node_cost(s, ps, 0.0, graph_nodes, costs, prefetch)
                for d, w, r, pl in entries:
                    fin_t = max(
                        d + _node_cost(t, pt, w, graph_nodes, costs, prefetch),
                        r + costs.compute_s(t, pt) * inv,
                    )
                    total = head + fin_t
                    if best is None or total < best[0]:
                        best = (total, {**pl, s: ps, t: pt})
            placement.update(best[1])
            return placement
    # exhaustive fallback for small non-series-parallel graphs
    names = list(graph_nodes)
    combos = 1
    for n in names:
        combos *= len(cand[n])
    if combos <= _EXHAUSTIVE_LIMIT:
        best = None
        for assignment in itertools.product(*(cand[n] for n in names)):
            pl = dict(zip(names, assignment))
            c = _dag_cost_views(graph_nodes, pred, order, pl, costs, prefetch)
            if best is None or c < best[0]:
                best = (c, pl)
        placement.update(best[1])
        return placement
    placement.update(place_dag_greedy(graph_nodes, edges, candidates, costs, prefetch))
    return placement


def _chain_graph(spec: WorkflowSpec):
    """The degenerate chain graph, keyed positionally (a chain may invoke
    the same function twice), with the cost callbacks remapped from step
    index back to step name."""
    steps = spec.steps
    ids = list(range(len(steps)))
    nodes = {i: steps[i] for i in ids}
    edges = [(i, i + 1) for i in ids[:-1]]

    def by_name(costs: PlacementCosts) -> PlacementCosts:
        return PlacementCosts(
            fetch_s=lambda i, p, deps: costs.fetch_s(steps[i].name, p, deps),
            compute_s=lambda i, p: costs.compute_s(steps[i].name, p),
            transfer_s=costs.transfer_s,
            payload_size=costs.payload_size,
            transfer_fl=costs.transfer_fl,
            chunks=costs.chunks,
        )

    return nodes, edges, by_name


def place_chain(
    spec: WorkflowSpec, candidates: dict, costs: PlacementCosts, prefetch: bool = True
) -> WorkflowSpec:
    """candidates: {step_name: [platform, ...]} — returns the re-routed spec.
    Delegates to the exact DAG DP on the degenerate chain graph."""
    steps = spec.steps
    nodes, edges, by_name = _chain_graph(spec)
    cand = {i: candidates.get(steps[i].name, [steps[i].platform]) for i in nodes}
    placement = place_dag(nodes, edges, cand, by_name(costs), prefetch)
    new_steps = tuple(
        StepSpec(s.name, placement[i], s.data_deps, s.prefetch, s.sync, s.params)
        for i, s in enumerate(steps)
    )
    return WorkflowSpec(new_steps, spec.workflow_id)


def chain_cost(
    spec: WorkflowSpec, costs: PlacementCosts, prefetch: bool = True
) -> float:
    """Expected serial cost of a fixed route (for reporting / tests): the
    ``dag_cost`` recurrence on the degenerate chain graph — one scoring
    model for every workflow shape."""
    nodes, edges, by_name = _chain_graph(spec)
    placement = {i: s.platform for i, s in nodes.items()}
    return dag_cost(nodes, edges, placement, by_name(costs), prefetch)
