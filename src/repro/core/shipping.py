"""Function shipping + automated placement (paper §4.3, §5.3).

GeoFF can move a function to the platform where its data lives instead of
moving the data ("shipping functions to data"). The paper does this manually
(§4.3) and lists automation as future work (§5.3) — implemented here:

``place_chain`` is a dynamic program over (step x candidate platform): for a
chain workflow it minimizes the expected serial cost

    sum_i [ exposed_fetch_i(p_i)  +  compute_i  +  transfer(p_i -> p_{i+1}) ]

where exposed_fetch accounts for pre-fetch overlap (fetch hidden up to the
predecessor's dwell time). Exact in O(steps x platforms^2) — no heuristic
needed for chains. For DAGs, ``place_dag`` applies the same scoring greedily
in topological order.

The TPU-pod analogue: a serving step whose KV cache / checkpoint shards live
on pod A is shipped to pod A rather than streaming the state over DCN —
serving/disagg.py uses the same optimizer with state residency as data_deps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.core.workflow import StepSpec, WorkflowSpec


@dataclass(frozen=True)
class PlacementCosts:
    """Cost model callbacks — wired to NetworkModel/ObjectLatency (sim) or
    measured EWMA stats (runtime, core/timing.py)."""
    fetch_s: Callable        # (step_name, platform, data_deps) -> seconds
    compute_s: Callable      # (step_name, platform) -> seconds
    transfer_s: Callable     # (platform_a, platform_b, size_bytes) -> seconds
    payload_size: float = 1.5e6


def exposed_fetch(fetch_s: float, window_s: float, prefetch: bool) -> float:
    """Fetch time visible on the critical path given an overlap window."""
    if not prefetch:
        return fetch_s
    return max(0.0, fetch_s - window_s)


def place_chain(spec: WorkflowSpec, candidates: dict,
                costs: PlacementCosts, prefetch: bool = True) -> WorkflowSpec:
    """candidates: {step_name: [platform, ...]} — returns the re-routed spec.

    DP state: best[i][p] = minimal cost of steps 0..i with step i on p.
    The overlap window for step i+1's prefetch is approximated by step i's
    (compute + transfer) — the poke cascade makes the true window larger, so
    this is a conservative (safe) placement criterion.
    """
    steps = spec.steps
    n = len(steps)
    cand = [list(candidates.get(s.name, [s.platform])) for s in steps]
    best = [{p: (float("inf"), None) for p in c} for c in cand]

    for p in cand[0]:
        f = costs.fetch_s(steps[0].name, p, steps[0].data_deps)
        c = costs.compute_s(steps[0].name, p)
        best[0][p] = (exposed_fetch(f, 0.0, prefetch) + c, None)

    for i in range(1, n):
        for p in cand[i]:
            f = costs.fetch_s(steps[i].name, p, steps[i].data_deps)
            c = costs.compute_s(steps[i].name, p)
            for q in cand[i - 1]:
                prev_cost, _ = best[i - 1][q]
                trans = costs.transfer_s(q, p, costs.payload_size)
                window = costs.compute_s(steps[i - 1].name, q) + trans
                total = (prev_cost + trans
                         + exposed_fetch(f, window, prefetch) + c)
                if total < best[i][p][0]:
                    best[i][p] = (total, q)

    # backtrack
    end_p = min(best[-1], key=lambda p: best[-1][p][0])
    route = [end_p]
    for i in range(n - 1, 0, -1):
        route.append(best[i][route[-1]][1])
    route.reverse()

    new_steps = tuple(
        StepSpec(s.name, route[i], s.data_deps, s.prefetch, s.sync, s.params)
        for i, s in enumerate(steps))
    return WorkflowSpec(new_steps, spec.workflow_id)


def chain_cost(spec: WorkflowSpec, costs: PlacementCosts,
               prefetch: bool = True) -> float:
    """Expected serial cost of a fixed route (for reporting / tests)."""
    total, window = 0.0, 0.0
    prev = None
    for i, s in enumerate(spec.steps):
        f = costs.fetch_s(s.name, s.platform, s.data_deps)
        c = costs.compute_s(s.name, s.platform)
        trans = 0.0
        if prev is not None:
            trans = costs.transfer_s(prev.platform, s.platform,
                                     costs.payload_size)
        total += trans + exposed_fetch(f, window + trans, prefetch) + c
        window = c
        prev = s
    return total


def place_dag(nodes, edges, candidates, costs: PlacementCosts,
              prefetch: bool = True) -> dict:
    """Greedy topological placement for fan-out/fan-in workflows.

    nodes: {name: StepSpec}; edges: [(src, dst)]. Returns {name: platform}.
    """
    from collections import defaultdict, deque
    indeg = defaultdict(int)
    succ = defaultdict(list)
    pred = defaultdict(list)
    for a, b in edges:
        indeg[b] += 1
        succ[a].append(b)
        pred[b].append(a)
    order = deque([n for n in nodes if indeg[n] == 0])
    placement: dict = {}
    topo = []
    while order:
        u = order.popleft()
        topo.append(u)
        for v in succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                order.append(v)
    for u in topo:
        s = nodes[u]
        options = candidates.get(u, [s.platform])
        def score(p):
            f = costs.fetch_s(u, p, s.data_deps)
            c = costs.compute_s(u, p)
            tin = sum(costs.transfer_s(placement[q], p, costs.payload_size)
                      for q in pred[u] if q in placement)
            window = max((costs.compute_s(q, placement[q])
                          for q in pred[u] if q in placement), default=0.0)
            return tin + exposed_fetch(f, window, prefetch) + c
        placement[u] = min(options, key=score)
    return placement
