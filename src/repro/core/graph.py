"""Tiny DAG utilities shared by the unified simulator and the placement
optimizer (one Kahn's algorithm instead of per-module copies)."""

from __future__ import annotations


def graph_views(ids, edges):
    """Predecessor/successor lists plus a deterministic topological order
    (ties broken by ``ids`` iteration order) over arbitrary hashable node
    ids. Raises on cycles."""
    ids = list(ids)
    pred = {n: [] for n in ids}
    succ = {n: [] for n in ids}
    for a, b in edges:
        succ[a].append(b)
        pred[b].append(a)
    pos = {n: i for i, n in enumerate(ids)}
    indeg = {n: len(pred[n]) for n in ids}
    ready = sorted((n for n in ids if indeg[n] == 0), key=pos.get)
    order = []
    while ready:
        u = ready.pop(0)
        order.append(u)
        for v in succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
        ready.sort(key=pos.get)
    if len(order) != len(ids):
        raise ValueError("workflow graph has a cycle")
    return pred, succ, tuple(order)
