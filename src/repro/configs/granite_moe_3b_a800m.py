"""granite-moe-3b-a800m — MoE decoder LM, 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512(expert) vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    block_pattern=("global",),
    num_experts=40,
    top_k=8,
    sub_quadratic=False,
)
