"""Architecture configs; see registry.ARCH_IDS / registry.get_config."""
