"""hubert-xlarge — audio encoder-only transformer (w2v2-family backbone).

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified]

The modality frontend (CNN feature extractor) is a STUB: ``input_specs()``
provides precomputed frame embeddings of shape (batch, frames, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447; unverified",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    tie_embeddings=False,
    block_pattern=("global",),
    causal=False,
    supports_decode=False,
    sub_quadratic=False,
    input_kind="frames",
)
