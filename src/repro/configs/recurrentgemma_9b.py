"""recurrentgemma-9b — hybrid RG-LRU + local attention (griffin),
1 attn : 2 recurrent.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427; unverified",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    rope_theta=10_000.0,
    tie_embeddings=True,
    # griffin pattern: (recurrent, recurrent, local-attn) cycled
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
    sub_quadratic=True,  # bounded window + O(1) recurrent state -> runs long_500k
)
