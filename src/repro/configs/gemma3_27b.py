"""gemma3-27b — dense decoder LM, 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt; unverified",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    qk_norm=True,
    rope_theta=10_000.0,          # local layers
    rope_theta_global=1_000_000.0,  # global layers
    tie_embeddings=True,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    local_window=1024,
    # Global layers remain full attention -> not sub-quadratic; skip long_500k.
    sub_quadratic=False,
)
