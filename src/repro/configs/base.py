"""Architecture configuration dataclasses.

Every assigned architecture is expressed as a frozen, hashable ``ArchConfig``
so it can be passed as a static argument to ``jax.jit`` and used as a compile
cache key by the pre-warming middleware (core/prewarm.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    # -- identity -----------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""  # provenance note from the assignment table

    # -- transformer trunk ---------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3 uses a different theta on global layers
    tie_embeddings: bool = True

    # -- per-layer block pattern, cycled over num_layers ----------------------
    # entries: "global" | "local" (sliding window) | "rglru" | "ssd"
    block_pattern: tuple = ("global",)
    local_window: int = 4096

    # -- MoE ------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # -- RG-LRU (griffin / recurrentgemma) ------------------------------------
    lru_width: int = 0

    # -- task shape -----------------------------------------------------------
    causal: bool = True          # False for encoder-only (hubert)
    supports_decode: bool = True  # False for encoder-only
    sub_quadratic: bool = False   # True -> runs the long_500k shape
    input_kind: str = "tokens"    # tokens | frames (audio stub)
                                  # | tokens+patches (vlm stub)
    num_patches: int = 0          # vlm: patch-embedding stub length within the sequence

    # -- numerics / execution -------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    seq_shard_attn: bool = False  # sequence-parallel attention (perf lever)
    seq_shard_resid: bool = False  # Megatron-SP: residual stream seq-sharded
                                   # over `model` (halves the TP all-reduces
                                   # into RS+AG and shards norms/embeds)
    moe_local_scatter: bool = False  # pin MoE dispatch scatter model-local,
                                     # then slice to EP (avoids GSPMD
                                     # all-reducing the dispatch buffer)
    moe_tp_ff: bool = False  # shard expert FFN on d_ff over `model` instead
                             # of EP: every dispatch/combine scatter+gather
                             # becomes model-LOCAL (only a token-sized
                             # partial-sum all-reduce crosses ranks)
    attn_chunk_q: int = 0         # 0 = full-score attention; >0 = flash-style
                                  # q-chunked attention (memory O(chunk*S))
    attn_chunk_unroll: bool = True  # python-unrolled chunks (exact HLO flop
                                    # accounting) vs lax.scan (small HLO)
    ce_chunk: int = 0             # 0 = full logits; >0 = seq-chunked CE loss
    remat: str = "none"           # none | full | dots
    scan_layers: bool = True
    use_pallas: bool = False      # Pallas kernels (interpret on CPU); jnp path default
    logits_softcap: float = 0.0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- derived -------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """Mamba-2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> tuple:
        """The concrete per-layer block kinds, pattern cycled to num_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline term)."""
        from repro.models.model import param_defs
        import math
        defs = param_defs(self)
        import jax
        leaves = jax.tree_util.tree_leaves(
            defs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
        return sum(math.prod(d.shape) for d in leaves)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        total = self.param_count()
        if self.num_experts and self.top_k:
            # expert FFN params: 3 matrices per expert (gate/up/down)
            per_expert = 3 * self.d_model * self.d_ff
            inactive = (self.num_experts - self.top_k) * per_expert * self.num_layers
            return total - inactive
        return total


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ArchConfig) -> tuple:
    """The shape cells that are well-defined for this architecture.

    Skips (recorded in DESIGN.md §Arch-applicability):
      - decode shapes for encoder-only archs (no autoregressive step)
      - long_500k for pure full-attention archs (needs sub-quadratic attention)
    """
    out = []
    for s in ALL_SHAPES:
        if s.kind == "decode" and not cfg.supports_decode:
            continue
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return tuple(out)
