"""llava-next-34b — VLM: Yi-34B-class decoder backbone with anyres patch tiling.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision tower + anyres tiling projector is a STUB: ``input_specs()``
provides precomputed patch embeddings (batch, num_patches, d_model) that are
prepended to the token embeddings (the standard llava-next layout).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    block_pattern=("global",),
    sub_quadratic=False,
    input_kind="tokens+patches",
    num_patches=1152,  # anyres: 1 base tile + 1 grid tile stub at 576 patches each
)
