"""Architecture registry: ``--arch <id>`` -> ArchConfig.

Also provides ``smoke_config`` — a REDUCED same-family config for CPU smoke
tests (small layers/width, few experts, tiny embedding tables), as mandated:
the FULL configs are only exercised via the dry-run (ShapeDtypeStruct).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, SHAPES, ALL_SHAPES,  # noqa: F401
                                applicable_shapes)

_MODULES = {
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config: fits a CPU forward/train step in <~1 s."""
    cfg = get_config(arch)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, 2 * len(cfg.block_pattern)) if len(cfg.block_pattern) > 1
        else 2,
        d_model=64,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        scan_layers=cfg.scan_layers,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2) or 1, head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=2, d_ff=32)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.local_window:
        kw.update(local_window=min(cfg.local_window, 32))
    if cfg.num_patches:
        kw.update(num_patches=8)
    return cfg.replace(**kw)
