"""moonshot-v1-16b-a3b — MoE decoder LM (kimi/moonlight family), 64 experts top-6.

48L d_model=2048 16H (MHA kv=16) d_ff=1408(expert) vocab=163840, MoE 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    rope_theta=50_000.0,
    tie_embeddings=False,
    block_pattern=("global",),
    num_experts=64,
    top_k=6,
    sub_quadratic=False,
)
