"""mamba2-370m — attention-free SSM using SSD (state-space duality).

48L d_model=1024 (attn-free) vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    sub_quadratic=True,  # O(1) decode state -> runs long_500k
)
