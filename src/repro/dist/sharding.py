"""Logical-axis sharding rules: the single GSPMD placement source.

Every tensor in the codebase names its dimensions with LOGICAL axes
("batch", "embed", "act_ff", ...) instead of mesh axes. This module owns the
table that maps logical axes onto the physical mesh ("data"/"model", plus
"pod" across DCN on the multi-pod mesh), and the resolver that turns
(shape, logical axes, rules, mesh) into a concrete ``PartitionSpec``.

Layout strategy (TPU v5e reference, launch/mesh.py):

  params        FSDP over "data" on the embed dim; tensor-parallel over
                "model" on heads / ff / vocab / experts / inner widths.
                The "pod" axis never shards parameters — gradient reduction
                over "pod" is the only cross-pod (DCN) collective.
  activations   batch over "data" (x "pod" when multi-pod); the act_* width
                axes over "model" so block-internal activations stay
                TP-sharded between matmuls.
  levers        seq_shard_attn (sequence-parallel attention scores),
                seq_shard_resid (Megatron-SP residual stream) map the
                relevant seq axes onto "model".

Resolution is defensive by construction — ``pspec_for`` guarantees a VALID
spec for any shape on any mesh:

  * divisibility fallback: a dim that the mapped mesh axes don't divide
    evenly is replicated instead (e.g. 24 heads on a model=16 axis);
  * a mesh axis is never used twice in one spec (first logical axis wins,
    later ones fall back to replication);
  * mesh axes the mesh doesn't have (e.g. "pod" on a single-pod mesh) are
    treated as unavailable and the dim is replicated.

The ambient-context half (``use_sharding`` / ``current_sharding`` /
``shard``) lets model code state constraints without threading mesh+rules
through every call: contexts nest, are thread-local (each simulated GeoFF
platform executor carries its own), and ``shard`` is an exact no-op outside
any context — the single-device path the simulator and smoke tests rely on.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A logical axis maps to one mesh axis, a tuple of mesh axes (consumed
# together, e.g. batch -> ("pod", "data")), or None (always replicated).
AxisSpec = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingRules:
    """An immutable logical-axis -> mesh-axes table.

    ``lookup`` is the only read path (layers.py uses it directly to size the
    MoE batch groups); unknown names resolve to None (replicated) so new
    logical axes degrade safely rather than crash a deployed platform.
    """

    table: Mapping[str, AxisSpec]
    name: str = "custom"

    def lookup(self, logical: Optional[str]) -> AxisSpec:
        if logical is None:
            return None
        return self.table.get(logical)

    def replace(self, **updates: AxisSpec) -> "ShardingRules":
        """A copy with some logical axes remapped (hillclimb lever)."""
        t = dict(self.table)
        t.update(updates)
        return ShardingRules(t, name=self.name + "+")

    def items(self):
        return self.table.items()


# Parameter axes. "layers" is the scan axis (never sharded); "embed" carries
# the FSDP shard; widths carry tensor parallelism.
_PARAM_TABLE: Mapping[str, AxisSpec] = {
    "layers": None,
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "inner": "model",
    "lru": "model",
    "conv": None,
}

# Activation axes common to every workload.
_ACT_TABLE: Mapping[str, AxisSpec] = {
    "act_heads": "model",
    "act_kv": "model",
    "act_embed": None,
    "act_ff": "model",
    "act_vocab": "model",
    "act_expert": "model",
    "act_inner": "model",
}


def train_rules(*, multi_pod: bool = False, seq_shard_attn: bool = False,
                seq_shard_resid: bool = False) -> ShardingRules:
    """Rules for the train (and prefill) programs.

    multi_pod        batch spans ("pod", "data") — grad reduction over "pod"
                     is then the only DCN collective on the step.
    seq_shard_attn   shard the attention q-sequence over "model"
                     (sequence-parallel scores; act_heads then replicates).
    seq_shard_resid  Megatron-SP: the residual-stream seq axis shards over
                     "model" between blocks.
    """
    table = dict(_PARAM_TABLE)
    table.update(_ACT_TABLE)
    table.update({
        "batch": ("pod", "data") if multi_pod else "data",
        "seq": "model" if seq_shard_resid else None,
        "attn_seq": "model" if seq_shard_attn else None,
        "cache_seq": "model",
    })
    return ShardingRules(table, name="train" + ("_mp" if multi_pod else ""))


def decode_rules(*, multi_pod: bool = False) -> ShardingRules:
    """Rules for the decode step: KV caches shard their seq dim over
    "model" (cache memory is the binding constraint at decode); the T=1
    activation seq axes stay replicated."""
    table = dict(_PARAM_TABLE)
    table.update(_ACT_TABLE)
    table.update({
        "batch": ("pod", "data") if multi_pod else "data",
        "seq": None,
        "attn_seq": None,
        "cache_seq": "model",
    })
    return ShardingRules(table, name="decode" + ("_mp" if multi_pod else ""))


def replicated_rules() -> ShardingRules:
    """Everything replicated — edge platforms / single-device simulators."""
    return ShardingRules({}, name="replicated")


def rules_for(kind: str, *, multi_pod: bool = False,
              seq_shard_attn: bool = False,
              seq_shard_resid: bool = False) -> ShardingRules:
    """Rules for a ShapeSpec kind: "train" | "prefill" | "decode"."""
    if kind in ("train", "prefill"):
        return train_rules(multi_pod=multi_pod, seq_shard_attn=seq_shard_attn,
                           seq_shard_resid=seq_shard_resid)
    if kind == "decode":
        return decode_rules(multi_pod=multi_pod)
    raise ValueError(f"unknown workload kind: {kind!r}")


def rules_for_platform(platform_kind: str, workload: str = "decode", *,
                       multi_pod: bool = False) -> ShardingRules:
    """Heterogeneous federation: each GeoFF platform kind gets its own
    placement. Edge nodes are single-device (everything replicated); cloud
    and private platforms run the mesh rules for their workload."""
    if platform_kind == "edge":
        return replicated_rules()
    return rules_for(workload, multi_pod=multi_pod)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------
def _mesh_shape(mesh) -> Mapping[str, int]:
    # jax.sharding.Mesh exposes .shape as an OrderedDict; the tests' FakeMesh
    # provides a plain dict. Both quack the same.
    return mesh.shape


def pspec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
              rules: ShardingRules, mesh) -> P:
    """Resolve logical axes to a PartitionSpec that is always valid.

    Per dim (left to right): look the logical axis up in the rules; keep the
    mapping only if every mesh axis exists, none was already used by an
    earlier dim, and their combined size divides the dim — otherwise the dim
    replicates. All-or-nothing per dim: a ("pod", "data") batch never
    degrades to a bare "data" shard, it replicates (predictability beats
    opportunism; the dry-run flags the replication instead).
    """
    assert len(shape) == len(axes), (tuple(shape), tuple(axes))
    mshape = _mesh_shape(mesh)
    used: set = set()
    parts: list = []
    for dim, logical in zip(shape, axes):
        entry = rules.lookup(logical)
        resolved = None
        if entry is not None:
            mesh_axes = (entry,) if isinstance(entry, str) else tuple(entry)
            ok = all(a in mshape and a not in used for a in mesh_axes)
            if ok:
                total = math.prod(mshape[a] for a in mesh_axes)
                if total > 0 and dim % total == 0:
                    resolved = (mesh_axes[0] if len(mesh_axes) == 1
                                else mesh_axes)
                    used.update(mesh_axes)
        parts.append(resolved)
    return P(*parts)


def validate_rules(rules: ShardingRules, mesh) -> dict:
    """Which logical axes CAN shard on this mesh? {logical: mesh_axes|None}.
    Purely diagnostic — pspec_for already degrades per-tensor."""
    mshape = _mesh_shape(mesh)
    out = {}
    for logical, entry in rules.items():
        if entry is None:
            out[logical] = None
            continue
        mesh_axes = (entry,) if isinstance(entry, str) else tuple(entry)
        out[logical] = entry if all(a in mshape for a in mesh_axes) else None
    return out


def describe(rules: ShardingRules, mesh=None) -> str:
    """Human-readable rule table (README / dry-run logs)."""
    lines = [f"ShardingRules[{rules.name}]"]
    avail = validate_rules(rules, mesh) if mesh is not None else None
    for logical in sorted(rules.table):
        entry = rules.table[logical]
        note = ""
        if avail is not None and entry is not None and avail[logical] is None:
            note = "   (unavailable on this mesh -> replicated)"
        lines.append(f"  {logical:12s} -> {entry!r}{note}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# ambient context
# ---------------------------------------------------------------------------
class _Ambient(threading.local):
    """Per-thread stack of (mesh, rules). Thread-local on purpose: each
    simulated platform runs steps on its own executor threads (see
    core/platform.py), and an edge platform's replicated context must not
    leak into a cloud platform's mesh context."""

    def __init__(self):
        self.stack = []


_AMBIENT = _Ambient()


def current_sharding():
    """(mesh, rules) of the innermost active context, else (None, None)."""
    if _AMBIENT.stack:
        return _AMBIENT.stack[-1]
    return (None, None)


class use_sharding:
    """Context manager binding (mesh, rules) for the current thread.

    Class-based (not a generator) so one instance is reusable AND reentrant
    — the platform wrapper constructs it once per call, the trainer nests it
    inside jit traces.
    """

    def __init__(self, mesh, rules):
        self.mesh = mesh
        self.rules = rules

    def __enter__(self):
        _AMBIENT.stack.append((self.mesh, self.rules))
        return self

    def __exit__(self, *exc):
        _AMBIENT.stack.pop()
        return False


def shard(x, *axes):
    """Constrain ``x`` to the ambient sharding; identity outside a context.

    The no-op path returns ``x`` itself (not a copy): single-device
    platforms and the simulator call model code with no context bound, and
    the constraint must cost nothing there.
    """
    mesh, rules = current_sharding()
    if mesh is None or rules is None:
        return x
    spec = pspec_for(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
