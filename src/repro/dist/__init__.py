"""Distribution layer: logical-axis sharding rules (GSPMD placement).

The subsystem has one module, ``repro.dist.sharding``; this package re-exports
the public surface so call sites can use either
``from repro.dist import sharding as shd`` or ``from repro.dist import shard``.
"""
from repro.dist.sharding import (
    ShardingRules,
    current_sharding,
    decode_rules,
    describe,
    pspec_for,
    replicated_rules,
    rules_for,
    rules_for_platform,
    shard,
    train_rules,
    use_sharding,
    validate_rules,
)

__all__ = [
    "ShardingRules",
    "current_sharding",
    "decode_rules",
    "describe",
    "pspec_for",
    "replicated_rules",
    "rules_for",
    "rules_for_platform",
    "shard",
    "train_rules",
    "use_sharding",
    "validate_rules",
]
