"""repro.jobs — the durability layer over the DAG engine.

One import surface for everything a failure-aware deployment needs: the
job manager (idempotent ids, dead letters, exact submission ledger), the
fault model shared with the simulator, and the engine-side retry/hedge
policy knobs.
"""

from repro.core.faults import (
    FaultEvent,
    FaultSchedule,
    InjectedFault,
    OutageEvent,
    RetryPolicy,
    availability,
)
from repro.dag.engine import FaultInjector

from repro.jobs.manager import DeadLetter, Job, JobManager, job_id

__all__ = [
    "DeadLetter",
    "FaultEvent",
    "FaultInjector",
    "FaultSchedule",
    "InjectedFault",
    "Job",
    "JobManager",
    "OutageEvent",
    "RetryPolicy",
    "availability",
    "job_id",
]
