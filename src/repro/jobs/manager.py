"""Durable jobs over ``DagDeployment``: idempotent submission, dead
lettering, and an exact submission ledger.

The engine executes REQUESTS — fire-and-forget, at-most-once, errors
propagate to whoever called ``run``. A production workflow needs JOBS:
submit the same work twice and get one execution; let a request exhaust
its retry budget and get a durable record of the failure instead of a
lost exception. ``JobManager`` is that layer, modeled on the production
Job -> Stage -> Task controller pattern:

  job identity      SHA256 over the workflow's placement-INDEPENDENT
                    content: sorted (step, function) pairs, the edge set,
                    and the payload repr. Recomposition moves steps across
                    platforms without changing what the job computes, so
                    the id survives a cutover — resubmitting after a
                    failover still dedups.
  dedup             re-submitting a COMPLETED job returns the recorded
                    result (counted in ``deduped``), not a re-execution.
                    Re-submitting a RUNNING job joins the in-flight
                    execution and shares its outcome. Re-submitting a
                    DEAD-LETTERED job re-executes: dead letters are a
                    record, not a tombstone.
  dead letter       a job whose execution raised (e.g. an ``InjectedFault``
                    that survived the engine's per-step retry budget) or
                    timed out (``DagResult(status="timeout")``) lands in
                    ``dead_letters`` with the error and request id, and
                    emits a ``job.dead_letter`` control-plane event on the
                    tracer — same ring as ``recompose.decision``.
  exact ledger      every ``submit`` increments ``submitted`` and exactly
                    one of ``kept`` / ``dead_lettered`` (joiners count by
                    the shared execution's final status), so
                    ``kept + dead_lettered == submitted`` holds exactly,
                    under any number of client threads — the chaos-test
                    invariant.

Retry/backoff/hedging live BELOW this layer, in the engine
(``DagDeployment(retry=...)``): the manager decides what a failure means,
the engine decides how hard to try before calling it one.
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Optional


def job_id(spec, payload) -> str:
    """SHA256 job identity from placement-independent workflow content.

    Two submissions are the same job iff they run the same functions over
    the same DAG shape on the same payload — WHERE each step runs is
    excluded on purpose, so a recomposition (or manual failover) does not
    reset idempotency. The payload participates via ``repr``; callers
    wanting custom identity semantics can pre-hash into the payload.
    """
    ident = (
        sorted((s.name, s.resolved_fn()) for s in spec.steps),
        sorted(spec.edges),
        repr(payload),
    )
    return hashlib.sha256(repr(ident).encode()).hexdigest()[:16]


@dataclass
class Job:
    """One unit of durable work. ``status`` moves running -> completed |
    dead_lettered; ``done`` is set exactly when the status is final."""

    job_id: str
    status: str = "running"
    result: object = None  # DagResult when completed
    error: Optional[str] = None
    attempts: int = 0  # end-to-end executions of this job id
    deduped: int = 0  # submissions served from the record / joined
    done: threading.Event = field(default_factory=threading.Event)


@dataclass(frozen=True)
class DeadLetter:
    """Durable record of one failed execution (budget exhausted, handler
    error, or timeout) — the audit surface the chaos test and the bench
    read back."""

    job_id: str
    error: str
    at: float
    request_id: Optional[str] = None


class JobManager:
    """Idempotent job front-end over a ``DagDeployment`` or
    ``AdaptiveDeployment``.

    With a plain deployment, ``submit(payload, spec=...)`` names the
    workflow per call; with an adaptive deployment the active route-table
    spec is used (identity is placement-independent, so route swaps do not
    fork job ids). ``timeout_s`` bounds every execution, which is what
    keeps ``submit`` a bounded join even for threads that attach to an
    in-flight duplicate.
    """

    def __init__(self, deployment, tracer=None, timeout_s: Optional[float] = 120.0):
        self.deployment = deployment
        self.tracer = tracer if tracer is not None else getattr(
            deployment, "tracer", None
        )
        self.timeout_s = timeout_s
        self._lock = threading.Lock()
        self._jobs: dict = {}  # job_id -> Job (latest execution record)
        self.dead_letters: list = []  # DeadLetter, one per failed execution
        self.stats = {
            "submitted": 0,
            "kept": 0,
            "dead_lettered": 0,
            "deduped": 0,
            "executed": 0,
        }

    def _is_adaptive(self) -> bool:
        return hasattr(self.deployment, "routes")

    def get(self, jid: str) -> Optional[Job]:
        with self._lock:
            return self._jobs.get(jid)

    def submit(self, payload, spec=None, timeout_s: Optional[float] = None) -> Job:
        """Execute (or dedup) one job; blocks until its status is final.

        Exactly one of ``kept``/``dead_lettered`` is incremented per call,
        whichever way the submission resolves — fresh execution, joined
        in-flight duplicate, or recorded result.
        """
        timeout = timeout_s if timeout_s is not None else self.timeout_s
        if self._is_adaptive():
            ident_spec = self.deployment.routes.spec
        elif spec is not None:
            ident_spec = spec
        else:
            raise ValueError("spec is required for a non-adaptive deployment")
        jid = job_id(ident_spec, payload)
        with self._lock:
            self.stats["submitted"] += 1
            job = self._jobs.get(jid)
            if job is not None and job.status == "completed":
                # idempotent replay: the recorded result, not a re-run
                job.deduped += 1
                self.stats["deduped"] += 1
                self.stats["kept"] += 1
                return job
            if job is not None and job.status == "running":
                joined = job
            else:
                # new job, or a dead-lettered one being retried
                joined = None
                job = Job(job_id=jid)
                self._jobs[jid] = job
        if joined is not None:
            # bounded join: the executing thread always finalizes in its
            # ``finally`` and every execution is itself timeout-bounded
            joined.done.wait()
            with self._lock:
                joined.deduped += 1
                self.stats["deduped"] += 1
                if joined.status == "completed":
                    self.stats["kept"] += 1
                else:
                    self.stats["dead_lettered"] += 1
            return joined
        return self._execute(
            job, ident_spec if spec is None else spec, payload, timeout
        )

    def _execute(self, job: Job, spec, payload, timeout) -> Job:
        err: Optional[str] = None
        rid: Optional[str] = None
        result = None
        try:
            if self._is_adaptive():
                result = self.deployment.run(payload, timeout)
            else:
                result = self.deployment.run(spec, payload, timeout)
            rid = result.request_id
            if getattr(result, "status", "ok") != "ok":
                err = result.error or result.status
        except BaseException as exc:
            err = repr(exc)
        finally:
            with self._lock:
                job.attempts += 1
                self.stats["executed"] += 1
                if err is None:
                    job.status = "completed"
                    job.result = result
                    self.stats["kept"] += 1
                else:
                    job.status = "dead_lettered"
                    job.error = err
                    self.stats["dead_lettered"] += 1
                    self.dead_letters.append(
                        DeadLetter(job.job_id, err, time.time(), rid)
                    )
            if err is not None and self.tracer is not None:
                self.tracer.record_event(
                    "job.dead_letter",
                    {"job_id": job.job_id, "error": err, "request_id": rid},
                )
            job.done.set()
        return job

    def snapshot(self) -> dict:
        """Report surface: the ledger plus dead-letter summaries."""
        with self._lock:
            return {
                **self.stats,
                "jobs": len(self._jobs),
                "dead_letters": [
                    {"job_id": d.job_id, "error": d.error, "request_id": d.request_id}
                    for d in self.dead_letters
                ],
            }
