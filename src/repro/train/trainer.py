"""Fault-tolerant training loop, choreographed GeoFF-style.

The loop is a repeating 3-step workflow:
    data_fetch  ->  train_step  ->  (periodic) checkpoint
with GeoFF's overlap rules applied to each edge:
  - batch k+1 is PRE-FETCHED (DoubleBuffer) while step k computes,
  - train_step is PRE-WARMED (AOT compile via CompileCache) before step 0,
  - checkpoints are ASYNC (snapshot, then background write).

Fault tolerance:
  - checkpoint/restart: ``run()`` resumes from the newest complete manifest
    (the data stream is step-addressable, so the token sequence is exact),
  - straggler mitigation: per-step wall times feed an EWMA; a step slower
    than ``straggler_factor`` x the EWMA is recorded and (on real fleets)
    would trigger re-dispatch — here the hook fires a callback, and the
    drill in tests injects a synthetic straggler,
  - elastic re-mesh: ``remesh(new_mesh)`` re-shards params/opt-state onto a
    smaller/larger mesh mid-run (device loss drill: restore-and-continue on
    a different topology, tests/test_trainer.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.core.prewarm import CompileCache
from repro.core.timing import EWMA
from repro.data.pipeline import ShardedLoader, SyntheticCorpus, shard_batch
from repro.core.prefetch import DoubleBuffer
from repro.dist import sharding as shd
from repro.models import model as M
from repro.models import params as prm
from repro.optim import AdamW, AdamWConfig


@dataclass
class TrainerConfig:
    seq_len: int = 256
    global_batch: int = 8
    total_steps: int = 50
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    straggler_factor: float = 3.0
    seed: int = 0
    adamw: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.rules = rules or (shd.train_rules() if mesh else None)
        self.opt = AdamW(tcfg.adamw)
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir)
        self.cache = CompileCache()
        self.step_time = EWMA(0.3)
        self.stragglers: list = []
        self.on_straggler: Optional[Callable] = None
        self.metrics_log: list = []

        self.params = None
        self.opt_state = None
        self.step = 0
        self._step_fn = None

    # -- state -------------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        with self._ctx():
            self.params = M.init_params(self.cfg, key)
            if self.mesh is not None:
                self.params = jax.device_put(self.params, self._shardings(
                    M.param_defs(self.cfg)))
            self.opt_state = self.opt.init(self.params)
        return self

    def _shardings(self, defs):
        return jax.tree_util.tree_map(
            lambda d: NamedSharding(self.mesh, shd.pspec_for(
                d.shape, d.axes, self.rules, self.mesh)),
            defs, is_leaf=lambda x: isinstance(x, prm.ParamDef))

    def _ctx(self):
        if self.mesh is not None:
            return shd.use_sharding(self.mesh, self.rules)
        return _null()

    # -- train step (pre-warmed) ---------------------------------------------------
    def _build_step(self):
        train_step = M.make_train_step(self.cfg, self.opt)

        def fn(params, opt_state, batch, step):
            with self._ctx():
                return train_step(params, opt_state, batch, step)

        self._step_fn = jax.jit(fn, donate_argnums=(0, 1))
        return self._step_fn

    def prewarm(self, example_batch):
        """GeoFF pre-warming: compile before the loop (off critical path)."""
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                           sharding=getattr(x, "sharding",
                                                            None)),
            (self.params, self.opt_state, example_batch,
             jnp.zeros((), jnp.int32)))
        self.cache.warm("train_step", "trainer", self._step_fn or
                        self._build_step(), abstract)

    # -- fault tolerance -----------------------------------------------------------
    def maybe_restore(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        sh = None
        if self.mesh is not None:
            sh = {"params": self._shardings(M.param_defs(self.cfg)),
                  "opt": jax.tree_util.tree_map(
                      lambda x: x.sharding, self.opt_state)}
        restored = self.ckpt.restore(latest, tree, sh)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step = latest
        return True

    def remesh(self, new_mesh, new_rules=None):
        """Elastic re-mesh: reshard live state onto a different topology."""
        self.mesh = new_mesh
        self.rules = new_rules or self.rules
        self.params = jax.device_put(
            self.params, self._shardings(M.param_defs(self.cfg)))
        self.opt_state = {
            "m": jax.device_put(self.opt_state["m"], self._shardings(
                M.param_defs(self.cfg))),
            "v": jax.device_put(self.opt_state["v"], self._shardings(
                M.param_defs(self.cfg))),
            "count": self.opt_state["count"]}
        self._step_fn = None   # re-compile for the new mesh
        return self

    # -- the loop --------------------------------------------------------------------
    def run(self, steps: Optional[int] = None, inject_straggler_at=None):
        steps = steps or self.tcfg.total_steps
        if self.params is None:
            self.init_state()
            self.maybe_restore()
        corpus = SyntheticCorpus(self.cfg.vocab_size, self.tcfg.seq_len,
                                 self.tcfg.seed)
        loader = ShardedLoader(corpus, self.tcfg.global_batch, self.step)
        it = DoubleBuffer(loader, depth=2,
                          transform=lambda b: shard_batch(b, self.mesh,
                                                          self.rules))
        self._build_step()
        end = self.step + steps
        while self.step < end:
            batch = next(it)
            t0 = time.perf_counter()
            if inject_straggler_at is not None and \
                    self.step == inject_straggler_at:
                time.sleep(max(0.2, 10 * (self.step_time.value or 0.02)))
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch,
                jnp.asarray(self.step, jnp.int32))
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if (self.step_time.n > 3
                    and dt > self.tcfg.straggler_factor
                    * self.step_time.value):
                self.stragglers.append((self.step, dt, self.step_time.value))
                if self.on_straggler:
                    self.on_straggler(self.step, dt)
            else:
                self.step_time.update(dt)
            self.metrics_log.append(
                {"step": self.step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]), "dt": dt})
            self.step += 1
            if self.step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt": self.opt_state})
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state}, blocking=True)
        return self.metrics_log


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
