"""Flash attention for TPU (Pallas): online-softmax, GQA, causal + sliding
window.

TPU adaptation (vs. the CUDA algorithm): the kernel is expressed as a 4-D
grid (batch, q_head, q_block, kv_block) whose LAST dimension is sequential
("arbitrary" semantics) — the online-softmax running max / denominator /
accumulator live in VMEM scratch that persists across kv-block steps, and
the MXU sees (block_q x d) @ (d x block_k) tiles with d and block sizes in
multiples of 128. GQA is handled in the BlockSpec index maps (q head h reads
kv head h // G) — no head replication in memory.

Fully-masked kv blocks are skipped with ``pl.when`` (saves MXU issue slots;
the DMA still runs — hiding it needs block-sparse index maps, noted in
EXPERIMENTS.md SPerf as a further step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, block_q, block_k, num_kv_blocks):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # block reachability: any (t, s) with t >= s (causal) and t-s < window?
    reachable = True
    if causal:
        reachable = (q_start + block_q - 1) >= k_start
    if window is not None:
        reachable = jnp.logical_and(
            reachable, (k_start + block_k - 1) > (q_start - window))

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=128, block_k=128, interpret=None):
    """q: (B,T,H,d); k/v: (B,S,K,d), H % K == 0. Returns (B,T,H,d)."""
    B, T, H, d = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    nq, nk = T // block_q, S // block_k
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running denominator
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
