"""RG-LRU linear recurrence for TPU (Pallas).

h_t = a_t * h_{t-1} + b_t, per channel (diagonal). The recurrence is
memory-bound, so the TPU kernel streams (time-chunk x channel-block) tiles
through VMEM: grid (batch, channel-block, time-chunk) with the time
dimension sequential, carrying h in f32 scratch. Within a chunk the scan is
a fori_loop over rows — each step is a (block_w,)-wide VPU vector op, which
is the idiomatic TPU shape for diagonal recurrences (cf. the RecurrentGemma
TPU kernel); the log-depth associative scan used by the jnp oracle would
waste bandwidth re-materializing O(log T) intermediates.

Inputs log_a, b: (B, T, W) float32. Returns (y (B,T,W), h_last (B,W)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _kernel(loga_ref, b_ref, y_ref, hlast_ref, h_scr, *, nchunks, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = jnp.exp(loga_ref[0].astype(jnp.float32))       # (Q, bw)
    b = b_ref[0].astype(jnp.float32)                   # (Q, bw)

    def body(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h

    @pl.when(ic == nchunks - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rglru_scan(log_a, b, *, chunk=256, block_w=None, interpret=None):
    B, T, W = log_a.shape
    Q = min(chunk, T)
    assert T % Q == 0
    nc = T // Q
    bw = block_w or W
    assert W % bw == 0
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_kernel, nchunks=nc, chunk=Q)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(B, W // bw, nc),
        in_specs=[
            pl.BlockSpec((1, Q, bw), lambda bb, w, c: (bb, c, w)),
            pl.BlockSpec((1, Q, bw), lambda bb, w, c: (bb, c, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, bw), lambda bb, w, c: (bb, c, w)),
            pl.BlockSpec((1, bw), lambda bb, w, c: (bb, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b)
    return y, hlast
