"""Pure-jnp oracles for every Pallas kernel.

Each function is the numerical ground truth its kernel is validated against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose). Where the
model already owns the reference implementation (SSD chunked scan, RG-LRU
associative scan) we re-export it so there is exactly ONE source of truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.griffin import lru_scan as rglru_scan_ref  # noqa: F401
from repro.models.ssm import ssd_chunked as ssd_scan_ref     # noqa: F401


def cold_scan_ref(t0, warm_end, cold_end, keep_warm):
    """Ground truth for the simulator's cold-start mask: the sequential
    ``last``-use recurrence, verbatim (mirrors the numpy
    ``WorkflowSimulator._cold_scan`` semantics). ``t0``: (T,);
    ``warm_end``/``cold_end``: (..., T); ``keep_warm``: scalar. Bool (..., T)."""

    def step(last, x):
        t0_k, warm_k, cold_k = x
        mask_k = (t0_k - last) > keep_warm
        return jnp.where(mask_k, cold_k, warm_k), mask_k

    init = jnp.full(warm_end.shape[:-1], -jnp.inf, warm_end.dtype)
    _, mask = jax.lax.scan(
        step,
        init,
        (t0, jnp.moveaxis(warm_end, -1, 0), jnp.moveaxis(cold_end, -1, 0)),
    )
    return jnp.moveaxis(mask, 0, -1)


def rmsnorm_ref(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = (x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,T,H,d), k/v: (B,S,K,d) with H % K == 0 (GQA). Returns (B,T,H,d).

    Positions are 0..T-1 / 0..S-1 aligned at 0 (self-attention)."""
    B, T, H, d = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else d ** -0.5
    qr = q.reshape(B, T, K, G, d)
    scores = jnp.einsum("btkgd,bskd->bkgts", qr, k).astype(jnp.float32)
    scores *= scale
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v)
    return out.reshape(B, T, H, d)
