"""Cold-start scan for the batched workflow simulator (Pallas).

The one genuinely sequential piece of the simulator's request-axis
recurrence: whether request ``k`` finds its (step, platform) instance cold
depends on request ``k-1``'s end time, which depends on whether *that*
request was cold. Given the node's per-request end times under both
hypotheses (``warm_end[k] <= cold_end[k]``, the cold draw is nonnegative),
the mask obeys

    last[-1] = -inf                      (fresh experiment)
    mask[k]  = (t0[k] - last[k-1]) > keep_warm
    last[k]  = cold_end[k] if mask[k] else warm_end[k]

Two device implementations of the same recurrence:

``cold_scan``           the TPU kernel. The recurrence is memory-bound and
                        diagonal across the batch axis (independent rows),
                        so the kernel streams (time-chunk x batch-block)
                        tiles through VMEM — grid (batch-block, time-chunk)
                        with time sequential, carrying ``last`` in f32
                        scratch; within a chunk the scan is a fori_loop over
                        rows, each step a (block_b,)-wide VPU vector op
                        (the rglru/ssd scan shape). Time is the sublane
                        dimension so the per-step store is a full lane row.
                        On non-TPU backends it runs in interpret mode.

``cold_scan_parallel``  the same mask with the sequential dependence
                        factored out, for XLA on any backend: mask[k] is a
                        1-bit affine function of mask[k-1] —
                        ``s = a XOR (b AND s_prev)`` with (a, b) determined
                        by which of the two gaps clears ``keep_warm`` — and
                        affine maps over GF(2) compose associatively, so the
                        whole mask is a log-depth parallel (Hillis–Steele)
                        scan with no per-request loop. The composition runs
                        under ``lax.while_loop`` keyed on ``any(b)``: the
                        "flip" bit ``b`` marks requests whose status depends
                        on the previous one, its true-runs halve every
                        doubling step, and in the paper's regimes
                        (interarrival far from ``keep_warm`` on either side)
                        it is all-false from the start — zero iterations,
                        mirroring the numpy scan's candidate short-circuit.
                        This is what the jax simulator backend uses where
                        Pallas isn't lowered.

The pure-jnp oracle both are validated against is ``ref.cold_scan_ref``
(tests/test_kernels.py, interpret mode on CPU), which mirrors the numpy
``WorkflowSimulator._cold_scan`` semantics exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _kernel(kw_ref, t0_ref, warm_ref, cold_ref, mask_ref, last_scr, *, chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        last_scr[...] = jnp.full_like(last_scr, -jnp.inf)

    kw = kw_ref[0]
    t0 = t0_ref[...]  # (chunk, 1)
    warm = warm_ref[...]  # (chunk, block_b)
    cold = cold_ref[...]  # (chunk, block_b)

    def body(t, last):
        m = (t0[t, 0] - last) > kw  # (block_b,)
        last = jnp.where(m, cold[t], warm[t])
        mask_ref[t, :] = m.astype(mask_ref.dtype)
        return last

    last_scr[...] = jax.lax.fori_loop(0, chunk, body, last_scr[...])


def cold_scan(
    t0, warm_end, cold_end, keep_warm, *, chunk=256, block_b=128, interpret=None
):
    """Boolean cold mask, request-major. ``t0``: (T,) arrival times shared
    by every row; ``warm_end``/``cold_end``: (B, T) per-row end times under
    the warm / cold hypothesis; ``keep_warm``: scalar idle horizon (may be
    +inf: never cold). Returns (B, T) bool. Computed in f32 (TPU-native);
    exact since only comparisons and selects touch the values."""
    B, T = warm_end.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # pad to tile multiples; the scan runs forward so padded time steps
    # never influence real outputs, and padded rows are sliced away
    Tp = -(-T // chunk) * chunk
    Bp = -(-B // block_b) * block_b
    f32 = jnp.float32
    t0p = jnp.zeros((Tp, 1), f32).at[:T, 0].set(t0.astype(f32))
    wp = jnp.zeros((Tp, Bp), f32).at[:T, :B].set(warm_end.astype(f32).T)
    cp = jnp.zeros((Tp, Bp), f32).at[:T, :B].set(cold_end.astype(f32).T)
    kw = jnp.asarray(keep_warm, f32).reshape(1)

    kernel = functools.partial(_kernel, chunk=chunk)
    mask = pl.pallas_call(
        kernel,
        grid=(Bp // block_b, Tp // chunk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((chunk, 1), lambda b, c: (c, 0)),
            pl.BlockSpec((chunk, block_b), lambda b, c: (c, b)),
            pl.BlockSpec((chunk, block_b), lambda b, c: (c, b)),
        ],
        out_specs=pl.BlockSpec((chunk, block_b), lambda b, c: (c, b)),
        out_shape=jax.ShapeDtypeStruct((Tp, Bp), f32),
        scratch_shapes=[pltpu.VMEM((block_b,), f32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(kw, t0p, wp, cp)
    return mask[:T, :B].T > 0.5


def cold_scan_parallel(t0, warm_end, cold_end, keep_warm):
    """The same mask as ``cold_scan`` as a log-depth parallel scan along
    the last axis (no Pallas, any backend, any dtype). ``t0``,
    ``warm_end`` and ``cold_end`` broadcast against each other; the scan
    runs over the trailing (request) axis; ``keep_warm`` is scalar.

    Derivation: request k can be cold regardless of history iff even the
    LATE previous end (cold) left a gap past keep_warm; it is warm
    regardless iff even the EARLY one (warm) did not. In between, the mask
    flips the previous one. All three cases are ``s = a ^ (b & s_prev)``:
    definitely-cold (1, 0), definitely-warm (0, 0), flip (1, 1) — affine
    over GF(2), hence associative under composition. The Hillis–Steele
    doubling runs under ``while_loop`` gated on ``any(b)``: once no flip
    bit survives, ``a`` IS the mask and the loop exits — zero iterations
    in regimes where every request is decidable from its own gap (the
    batched analogue of the numpy scan walking only its candidate list).
    Under ``vmap`` the gate becomes "any lane still flipping", so batch
    members that converge early ride along for free."""
    t0, warm_end, cold_end = jnp.broadcast_arrays(t0, warm_end, cold_end)
    warm_gap = t0[..., 1:] - warm_end[..., :-1] > keep_warm
    cold_gap = t0[..., 1:] - cold_end[..., :-1] > keep_warm
    # request 0 measures against last = -inf: cold unless keep_warm is inf
    first = jnp.broadcast_to(keep_warm < jnp.inf, t0[..., :1].shape)
    a = jnp.concatenate([first, warm_gap], axis=-1)
    b = jnp.concatenate([jnp.zeros_like(first), warm_gap & ~cold_gap], axis=-1)
    n = a.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def keep_going(state):
        _, b, d = state
        return jnp.any(b) & (d < n)

    def double(state):
        a, b, d = state
        # compose each element with the affine map d steps back (elements
        # with no predecessor that far compose with identity (0, 0))
        behind = idx >= d
        a_s = jnp.where(behind, jnp.roll(a, d, axis=-1), False)
        b_s = jnp.where(behind, jnp.roll(b, d, axis=-1), False)
        return a ^ (b & a_s), b & b_s, d * 2

    a, _, _ = jax.lax.while_loop(keep_going, double, (a, b, jnp.int32(1)))
    return a
