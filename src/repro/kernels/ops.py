"""jit'd public wrappers for the Pallas kernels.

The model layer calls these (``cfg.use_pallas=True``); on non-TPU backends
they run the kernel bodies in interpret mode (Python on CPU) so correctness
is exercised everywhere, while the lowered TPU path uses the real kernels.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.cold_scan import cold_scan as _cold_scan
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128, block_k=128):
    return _flash(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k
    )


@functools.partial(jax.jit, static_argnames=("chunk", "block_h"))
def ssd_scan(x, dt, A_log, B_mat, C_mat, chunk, block_h=None):
    return _ssd(x, dt, A_log, B_mat, C_mat, chunk, block_h=block_h)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w"))
def rglru_scan(log_a, b, chunk=256, block_w=None):
    return _rglru(log_a, b, chunk=chunk, block_w=block_w)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, eps=1e-6, block_rows=128):
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows)


@functools.partial(jax.jit, static_argnames=("chunk", "block_b"))
def cold_scan(t0, warm_end, cold_end, keep_warm, chunk=256, block_b=128):
    return _cold_scan(t0, warm_end, cold_end, keep_warm, chunk=chunk, block_b=block_b)
