"""Mamba-2 SSD chunk scan for TPU (Pallas).

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): grid is
(batch, head-block, chunk) with the CHUNK dimension sequential — the
inter-chunk recurrent state (heads_blk, P, N) lives in f32 VMEM scratch and
is carried across chunk steps, while the intra-chunk quadratic term runs on
the MXU as (Q x N)(N x Q) and (Q x Q)(Q x P) tiles. This replaces the GPU
formulation's separate state-passing kernel + atomics with grid-sequential
scratch carry, which is the idiomatic TPU pattern.

Shapes match models/ssm.ssd_chunked (the oracle): x (B,L,H,P), dt (B,L,H),
A_log (H,), B/C (B,L,N) -> y (B,L,H,P), final_state (B,H,P,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref,
            h_scr, *, nchunks, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, bh, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, bh)
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))   # (bh,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    dA = dt * a[None, :]                      # (Q, bh)
    cum = jnp.cumsum(dA, axis=0)              # (Q, bh)

    # intra-chunk: y[t] = sum_{s<=t} CB[t,s] * exp(cum[t]-cum[s]) dt[s] x[s]
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    diff = cum[:, None, :] - cum[None, :, :]                      # (Q,Q,bh)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask before exp (t<s diffs are positive and can overflow)
    L = jnp.exp(jnp.where(tri[:, :, None], diff, -1e30))          # (Q,Q,bh)
    G = CB[:, :, None] * L * dt[None, :, :]                       # (Q,Q,bh)
    y = jnp.einsum("tsh,shp->thp", G, x)                          # (Q,bh,P)

    # inter-chunk: y[t] += C[t] . (h_prev * exp(cum[t]))
    h_prev = h_scr[...]                                           # (bh,P,N)
    y = y + jnp.einsum("tn,hpn,th->thp", Cm, h_prev, jnp.exp(cum))

    # state update: h = h_prev * exp(cum[-1]) + sum_s w_end[s] B[s] x[s]
    w_end = jnp.exp(cum[-1][None, :] - cum) * dt                  # (Q,bh)
    S_c = jnp.einsum("sh,sn,shp->hpn", w_end, Bm, x)
    h_new = h_prev * jnp.exp(cum[-1])[:, None, None] + S_c
    h_scr[...] = h_new

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nchunks - 1)
    def _final():
        state_ref[0] = h_new.astype(state_ref.dtype)


def ssd_scan(x, dt, A_log, B_mat, C_mat, chunk, *, block_h=None,
             interpret=None):
    """Pallas SSD. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    Bb, L, H, P = x.shape
    N = B_mat.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q
    bh = block_h or H
    assert H % bh == 0
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_kernel, nchunks=nc, chunk=Q)
    grid = (Bb, H // bh, nc)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, bh, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, bh), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((bh,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, bh, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bh, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A_log, B_mat, C_mat)
    return y, state
