"""Fused RMSNorm for TPU (Pallas): one pass, f32 statistics in-register.

Grid over row blocks; each step normalizes a (block_rows x D) tile — the
reduction and the scale apply fuse into one VMEM-resident pass instead of
the 3 HBM round-trips the unfused jnp version costs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps))
                  * (1.0 + w[None, :])).astype(o_ref.dtype)


def rmsnorm(x, w, eps=1e-6, block_rows=128, interpret=None):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    br = min(block_rows, N)
    # pad rows to a block multiple
    pad = (-N) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((N + pad) // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + pad, D), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out[:N].reshape(orig_shape)
