"""GeoFF reproduction: federated serverless workflows over sharded JAX."""
