"""Online workflow recomposition: re-run the exact placement DP against
measured costs and hot-swap routes while requests are in flight.

This is the paper's ad-hoc recomposition claim made *online*. Because a
``DagSpec`` is immutable per-request data (not a deployment artifact),
re-placing a workflow is just publishing a new spec version — no redeploy,
no handler restart, and in-flight requests keep executing the spec they
captured at entry. Three pieces:

  ``RouteTable``             versioned holder of the active spec. ``swap``
                             publishes a new version atomically; readers
                             grab ``(version, spec)`` in one lock hop.
  ``RecompositionController`` the policy: every ``every_n`` completed
                             requests — or as soon as the observed cost of
                             the ACTIVE placement drifts past
                             ``drift_ratio`` x its cost when placed — pull
                             ``observed_costs`` from the telemetry hub and
                             re-run ``place_dag`` (the same exact DP static
                             placement uses; DFlow-style: invocation
                             decisions track observed state).
  ``AdaptiveDeployment``     wraps a ``DagDeployment``: wires the telemetry
                             hooks, runs every request on the current route
                             version, ticks the controller, and on a
                             placement change pre-warms the moved steps'
                             compile caches on their NEW platforms before
                             cutover — the swap lands warm.

The controller is engine-agnostic: it speaks ``DagSpec`` and placement
dicts, so the simulator benches (``benchmarks/adapt_bench.py``) drive the
identical decide loop against simulated telemetry.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional

from repro.core.shipping import PlacementCosts, dag_cost, place_dag
from repro.dag.spec import DagSpec

from repro.adapt.costs import observed_costs, regions_of
from repro.adapt.telemetry import TelemetryHub, attach


class RouteTable:
    """Versioned route publication. Requests capture ``(version, spec)``
    once at entry; ``swap`` never mutates a published spec (DagSpec is
    frozen), so in-flight requests finish on the routes they started with
    and the swap is atomic for new arrivals."""

    def __init__(self, spec: DagSpec, history_len: int = 64):
        self._lock = threading.Lock()
        self._version = 0
        self._spec = spec
        # recent published (version, spec) pairs — bounded: a long-lived
        # deployment swapping for days must not retain every old spec
        self.history = deque([(0, spec)], maxlen=history_len)

    def current(self) -> tuple:
        with self._lock:
            return self._version, self._spec

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    @property
    def spec(self) -> DagSpec:
        with self._lock:
            return self._spec

    def swap(self, new_spec: DagSpec) -> int:
        with self._lock:
            self._version += 1
            self._spec = new_spec
            self.history.append((self._version, new_spec))
            return self._version


class RecompositionController:
    """Decides WHEN to re-place and WHAT the new placement is.

    ``tick(spec)`` is called once per completed request with the currently
    active spec; it returns a placement dict ``{step: platform}`` when the
    DP found a strictly different placement, else None. Cheap per-tick work
    is one ``dag_cost`` evaluation (linear in the graph); the DP itself
    runs only on the every-N boundary or on a drift trigger.

    Hysteresis (both default off, so the bare controller is the PR-4 one):
    ``cooldown_requests`` suppresses every recompute for that many ticks
    after a swap, and ``min_improvement`` demands the proposed placement
    beat the active one by that fraction before swapping — together they
    stop an alternating drift from thrashing the route table. The
    improvement is judged on ``dag_cost`` point estimates, or — when a
    ``scorer`` (``adapt.scorer.PlacementScorer``) is given — on simulated
    latency *distributions* of both placements under the observed costs,
    compared at the scorer's quantile (a placement that only wins on the
    mean but loses the tail does not get swapped in).

    SLO trigger: with an ``obs.SloTracker`` wired (``slo=``), a burn-rate
    alert forces a recompute on the next tick — the user-facing objective
    can demand a re-placement even when mean costs have not drifted (tail
    degradation is invisible to the drift ratio). Latched on the
    tracker's ``alerts`` counter: one forced recompute per breach
    episode, not one per burning request, and the latch survives a
    cooldown window (the episode is handled when the recompute actually
    runs). Decision events carry ``trigger="slo"`` and the SLO name.

    Outage trigger (PR 10): the controller diffs the hub's error counts
    each tick; a cell with fresh failures whose error-rate EWMA is at or
    above ``outage_threshold`` is marked dead for ``outage_ttl`` ticks —
    while marked, ``observed_costs`` prices it ``inf`` so ``place_dag``
    must route around it, and if the ACTIVE placement sits on a dead cell
    a recompute fires immediately with ``trigger="outage"``. When a mark
    expires the controller forgets the cell's error history
    (``hub.reset_errors``) and forces one more recompute: an optimistic
    probe that fails back if the platform recovered — and re-marks within
    a few requests if it has not (fresh errors re-trip the threshold).
    Trigger precedence: slo > outage > drift > boundary. Detection and
    recovery land in the tracer ring as ``outage.detected`` /
    ``outage.cleared`` instants, next to ``recompose.decision``.
    """

    def __init__(
        self,
        hub: TelemetryHub,
        fallback: PlacementCosts,
        candidates: dict,
        regions: Optional[dict] = None,
        every_n: int = 16,
        drift_ratio: float = 1.5,
        min_samples: int = 2,
        prefetch: bool = True,
        cooldown_requests: int = 0,
        min_improvement: float = 0.0,
        scorer=None,
        tracer=None,
        slo=None,
        outage_threshold: float = 0.5,
        outage_ttl: int = 24,
    ):
        self.hub = hub
        self.fallback = fallback
        self.candidates = dict(candidates)
        self.regions = regions
        self.every_n = every_n
        self.drift_ratio = drift_ratio
        self.min_samples = min_samples
        self.prefetch = prefetch
        self.cooldown_requests = cooldown_requests
        self.min_improvement = min_improvement
        self.scorer = scorer
        self.outage_threshold = outage_threshold
        self.outage_ttl = outage_ttl
        self.slo = slo  # duck-typed obs.SloTracker (alerts counter + spec)
        # duck-typed obs.Tracer: every recompute decision (trigger, old/new
        # placement, predicted vs. current cost, outcome) lands in its
        # control-plane event ring — adapt behavior becomes auditable
        self.tracer = tracer
        self._lock = threading.Lock()
        self._n = 0
        self._cooldown_until = 0  # tick count before which recomputes pause
        self._placed_cost: Optional[float] = None  # active placement's cost
        #   under the observations that selected it (the drift reference)
        self._slo_handled = 0  # alerts count at the last slo-forced recompute
        self._outage_marks: dict = {}  # (step, platform) -> expiry tick
        self._err_seen: dict = {}  # (step, platform) -> error count last tick
        self.last_trigger: Optional[str] = None  # what caused the last swap
        self.stats = {
            "ticks": 0,
            "drift_triggers": 0,
            "slo_triggers": 0,
            "outage_triggers": 0,
            "recomputes": 0,
            "swaps": 0,
            "cooldown_skips": 0,
            "improvement_vetoes": 0,
        }

    def costs(self, outages=None) -> PlacementCosts:
        return observed_costs(
            self.hub, self.fallback, self.regions, self.min_samples, outages=outages
        )

    def outages(self) -> set:
        """The (step, platform) cells currently marked dead."""
        with self._lock:
            return set(self._outage_marks)

    def _update_outages(self, n: int) -> tuple:
        """Advance the outage state machine one tick. Returns ``(live,
        cleared)``: the set of cells currently marked dead, and whether any
        mark expired this tick (which forces a fail-back probe recompute).
        """
        counts = self.hub.error_counts()
        detected, cleared = [], []
        with self._lock:
            for cell, total in counts.items():
                fresh = total - self._err_seen.get(cell, 0)
                self._err_seen[cell] = total
                if fresh <= 0:
                    continue
                rate = self.hub.error_rate(*cell)
                if rate is not None and rate >= self.outage_threshold:
                    if cell not in self._outage_marks:
                        detected.append((cell, rate))
                    # fresh failures extend a live mark: the TTL counts
                    # from the LAST observed failure, not the first
                    self._outage_marks[cell] = n + self.outage_ttl
            for cell, until in list(self._outage_marks.items()):
                if until <= n:
                    del self._outage_marks[cell]
                    cleared.append(cell)
            live = set(self._outage_marks)
        for cell in cleared:
            # optimistic probe: drop the cell's failure history so the
            # recompute below can price it normally again; a still-dead
            # platform re-marks within a few requests
            self.hub.reset_errors(*cell)
        if self.tracer is not None:
            for (step, platform), rate in detected:
                self.tracer.record_event(
                    "outage.detected",
                    {
                        "step": step,
                        "platform": platform,
                        "error_rate": rate,
                        "tick": n,
                        "until_tick": n + self.outage_ttl,
                    },
                )
            for step, platform in cleared:
                self.tracer.record_event(
                    "outage.cleared", {"step": step, "platform": platform, "tick": n}
                )
        return live, bool(cleared)

    def tick(self, spec: DagSpec) -> Optional[dict]:
        with self._lock:
            self._n += 1
            n = self._n
            self.stats["ticks"] += 1
            placed_cost = self._placed_cost
            if n < self._cooldown_until:
                self.stats["cooldown_skips"] += 1
                return None
        nodes = {s.name: s for s in spec.steps}
        edges = list(spec.edges)
        placement = {s.name: s.platform for s in spec.steps}
        # a burn-rate alert since the last slo-forced recompute? (checked
        # after the cooldown gate, so the latch survives a cooldown and
        # fires on the first eligible tick)
        slo_fired = self.slo is not None and self.slo.alerts > self._slo_handled
        # outage state machine: dead cells price inf below; an active
        # placement sitting on one (or a mark expiring — the fail-back
        # probe) forces a recompute right now
        live_outages, outage_cleared = self._update_outages(n)
        outage_fired = outage_cleared or any(
            cell in live_outages for cell in placement.items()
        )
        costs = self.costs(outages=live_outages)
        current_cost = None
        drifted = False
        if placed_cost is not None:
            current_cost = dag_cost(nodes, edges, placement, costs, self.prefetch)
            drifted = current_cost > self.drift_ratio * placed_cost
        if (
            not slo_fired
            and not outage_fired
            and not drifted
            and n % self.every_n != 0
        ):
            return None
        with self._lock:
            if slo_fired:
                self.stats["slo_triggers"] += 1
                self._slo_handled = self.slo.alerts
            elif outage_fired:
                self.stats["outage_triggers"] += 1
            elif drifted:
                self.stats["drift_triggers"] += 1
            self.stats["recomputes"] += 1
        trigger = (
            "slo"
            if slo_fired
            else ("outage" if outage_fired else ("drift" if drifted else "boundary"))
        )
        new_placement = place_dag(nodes, edges, self.candidates, costs, self.prefetch)
        new_cost = dag_cost(nodes, edges, new_placement, costs, self.prefetch)
        if new_placement == placement:
            with self._lock:
                self._placed_cost = new_cost
            self._record(
                trigger, n, "no_change", placement, None, new_cost, current_cost
            )
            return None
        if current_cost is None:
            current_cost = dag_cost(nodes, edges, placement, costs, self.prefetch)
        if not self._improves(
            nodes, edges, new_placement, placement, new_cost, current_cost, costs
        ):
            # not worth the churn: keep the active placement, refresh the
            # drift reference so the same near-tie doesn't retrigger
            with self._lock:
                self.stats["improvement_vetoes"] += 1
                self._placed_cost = current_cost
            self._record(
                trigger, n, "veto", placement, new_placement, new_cost, current_cost
            )
            return None
        with self._lock:
            self._placed_cost = new_cost
            self.stats["swaps"] += 1
            self._cooldown_until = n + self.cooldown_requests
            self.last_trigger = trigger
        self._record(
            trigger, n, "swap", placement, new_placement, new_cost, current_cost
        )
        return new_placement

    def _record(
        self, trigger, n, outcome, placement, new_placement, new_cost, current_cost
    ):
        """Mirror one recompute decision into the tracer's event ring."""
        if self.tracer is None:
            return
        attrs = {
            "trigger": trigger,
            "tick": n,
            "outcome": outcome,
            "placement": dict(placement),
            "new_placement": dict(new_placement) if new_placement else None,
            "predicted_cost_s": new_cost,
            "current_cost_s": current_cost,
        }
        if trigger == "slo" and self.slo is not None:
            attrs["slo"] = self.slo.spec.name
        self.tracer.record_event("recompose.decision", attrs)

    def _improves(
        self, nodes, edges, new_placement, placement, new_cost, current_cost, costs
    ) -> bool:
        """Is ``new_placement`` enough better than the active one to swap?
        Point costs by default; simulated distributions when a scorer is
        wired (both placements under the same observed costs and common
        random numbers, compared at the scorer's quantile)."""
        if self.scorer is not None:
            q_new, q_cur = self.scorer.quantiles(
                nodes, edges, [new_placement, placement], costs, self.prefetch
            )
            return q_new < (1.0 - self.min_improvement) * q_cur
        return new_cost < (1.0 - self.min_improvement) * current_cost


class AdaptiveDeployment:
    """A ``DagDeployment`` that re-places itself against live telemetry.

    Wraps an existing deployment and ONE workflow spec (the workflow being
    served): every ``run(payload)`` executes on the current route version;
    after each request the controller ticks, and a placement change is cut
    over via ``RouteTable.swap`` — validated against the deployment's
    platform set, moved steps pre-warmed on their new platforms first.

    ``candidates`` maps step name -> list of platforms the step MAY move
    to; every candidate must actually have the step's function deployed
    (checked eagerly, so a recomposition can never route onto a platform
    that would 404).
    """

    def __init__(
        self,
        deployment,
        spec: DagSpec,
        candidates: dict,
        fallback_costs: PlacementCosts,
        hub: Optional[TelemetryHub] = None,
        every_n: int = 16,
        drift_ratio: float = 1.5,
        min_samples: int = 2,
        prewarm: bool = True,
        cooldown_requests: int = 0,
        min_improvement: float = 0.0,
        scorer=None,
        tracer=None,
        slo=None,
        outage_threshold: float = 0.5,
        outage_ttl: int = 24,
    ):
        self.deployment = deployment
        self.hub = attach(deployment, hub)
        self.tracer = tracer
        if tracer is not None:
            # same duck-typed hook pattern as telemetry.attach: request
            # traces come from the wrapped deployment, decision events from
            # the controller below
            from repro.obs import instrument

            instrument(deployment, tracer)
        # duck-typed obs.SloTracker: fed every request's end-to-end latency
        # (wall clock, same clock the engine's spans use) so burn-rate
        # breaches can force a re-placement through the controller
        self.slo = slo
        if slo is not None and tracer is not None and slo.tracer is None:
            slo.tracer = tracer  # slo.burn lands in the same event ring
        self.prewarm = prewarm
        for step in spec.steps:  # fail fast: candidates must be deployed
            for platform in candidates.get(step.name, ()):
                fn = step.resolved_fn()
                if (fn, platform) not in deployment._functions:
                    raise ValueError(
                        f"candidate platform {platform!r} for step "
                        f"{step.name!r} has no deployment of {fn!r}"
                    )
        self.controller = RecompositionController(
            self.hub,
            fallback_costs,
            candidates,
            regions=regions_of(deployment.registry),
            every_n=every_n,
            drift_ratio=drift_ratio,
            min_samples=min_samples,
            cooldown_requests=cooldown_requests,
            min_improvement=min_improvement,
            scorer=scorer,
            tracer=tracer,
            slo=slo,
            outage_threshold=outage_threshold,
            outage_ttl=outage_ttl,
        )
        self.routes = RouteTable(spec)
        self._cut_lock = threading.Lock()
        self.swaps = deque(maxlen=256)  # bounded audit log of cutovers

    # -- client ----------------------------------------------------------------
    def run(self, payload, timeout_s: Optional[float] = 120.0):
        version, spec = self.routes.current()
        try:
            result = self.deployment.run(spec, payload, timeout_s)
        except BaseException:
            # a request that DIES is exactly when the outage trigger must
            # still get its tick: the engine already fed record_error, so
            # let the controller fail over before the error propagates —
            # otherwise a platform that kills every request could never be
            # routed around
            placement = self.controller.tick(self.routes.spec)
            if placement is not None:
                self._cutover(placement, trigger=self.controller.last_trigger)
            raise
        if self.slo is not None:
            self.slo.record(result.total_s, now=time.perf_counter())
        placement = self.controller.tick(self.routes.spec)
        if placement is not None:
            self._cutover(placement, trigger=self.controller.last_trigger)
        return result

    # -- cutover ---------------------------------------------------------------
    def _cutover(self, placement: dict, trigger: Optional[str] = None) -> int:
        """Publish a new route version: validate, pre-warm, swap."""
        with self._cut_lock:
            _, spec = self.routes.current()
            new_spec = spec.apply_placement(
                placement, platforms=self.deployment.registry.names()
            )
            moved = {
                s.name: (spec.node(s.name).platform, s.platform)
                for s in new_spec.steps
                if s.platform != spec.node(s.name).platform
            }
            if not moved:
                return self.routes.version
            if self.prewarm:
                for name, (_, platform) in moved.items():
                    step = new_spec.node(name)
                    fn = self.deployment._resolve(step.resolved_fn(), platform)
                    if fn.compile_fn is not None and fn.abstract_args is not None:
                        self.deployment.cache.warm(
                            fn.name, platform, fn.compile_fn, fn.abstract_args
                        )
            version = self.routes.swap(new_spec)
            # which SLO fired is part of the audit record: a cutover forced
            # by an objective breach must be attributable to that objective
            slo_name = (
                self.slo.spec.name
                if trigger == "slo" and self.slo is not None
                else None
            )
            self.swaps.append(
                {
                    "version": version,
                    "moved": moved,
                    "at": time.time(),
                    "trigger": trigger,
                    "slo": slo_name,
                }
            )
            if self.tracer is not None:
                self.tracer.record_event(
                    "recompose.cutover",
                    {
                        "version": version,
                        "moved": moved,
                        "trigger": trigger,
                        "slo": slo_name,
                    },
                )
            return version

    # -- reporting / lifecycle -------------------------------------------------
    def report(self) -> dict:
        out = self.deployment.report()
        out["adapt"] = {
            "route_version": self.routes.version,
            "swaps": list(self.swaps),
            "controller": dict(self.controller.stats),
        }
        if self.slo is not None:
            out["adapt"]["slo"] = self.slo.snapshot()
        return out

    def shutdown(self):
        self.deployment.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
