"""Online telemetry: the measured-EWMA side of ``shipping.PlacementCosts``.

GeoFF's headline claim is ad-hoc recomposition, but a placement can only be
*re*-composed against live conditions if something measures them. The
``TelemetryHub`` is that something: a thread-safe registry of EWMA
observations, fed by small duck-typed hooks in the runtime —

  dag/engine.py      per-(step, platform) handler compute seconds
  core/prewarm.py    cold-start / warm-hit counts and compile seconds
                     per (step, platform)
  core/prefetch.py   per-(key, region) fetch seconds
  core/store.py      per-(src_region, dst_region) transfer seconds + bytes

— and by the unified simulator (``WorkflowSimulator(telemetry=...)``), so
simulated experiments exercise the same observe → estimate → re-place loop
the real engine runs. The hub never *pushes* anything: ``adapt.costs.
observed_costs`` pulls a ``PlacementCosts`` view from it on demand, falling
back to modeled costs for cells with too few samples (Kulkarni et al. 2025
show public-cloud latencies drift by integer factors over hours — the EWMA
tracks that drift; the fallback keeps ``place_dag`` total before any
traffic has flowed).

Producers call ``record_*``; they hold the hub lock only long enough to
update one EWMA, so instrumentation stays off the critical path.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Optional

import numpy as np

from repro.core.timing import EWMA

# per-pair (bytes, seconds) sample window for the latency+bandwidth fit:
# big enough to span the byte spread chunked + whole transfers produce,
# small enough that the fit tracks drift
_FIT_WINDOW = 64


class TelemetryHub:
    """Thread-safe EWMA store for every observation class the placement
    cost model consumes. All ``record_*`` methods are safe to call from any
    executor thread; ``snapshot`` returns a plain-dict copy for reports."""

    def __init__(self, alpha: float = 0.25):
        self.alpha = alpha
        self._lock = threading.Lock()
        self._compute: dict = {}  # (step, platform) -> EWMA seconds
        self._fetch: dict = {}  # (key, region) -> EWMA seconds
        self._transfer_s: dict = {}  # (src_region, dst_region) -> EWMA s
        self._transfer_b: dict = {}  # (src_region, dst_region) -> EWMA bytes
        self._cold: dict = {}  # (step, platform) -> cold-start count
        self._warm: dict = {}  # (step, platform) -> warm-hit count
        self._cold_s: dict = {}  # (step, platform) -> EWMA cold seconds
        self._transfer_pts: dict = {}  # pair -> deque[(bytes, seconds)]
        self._edge_b: dict = {}  # (src_step, dst_step) -> EWMA payload bytes
        self._err: dict = {}  # (step, platform) -> EWMA error indicator
        self._err_n: dict = {}  # (step, platform) -> total error count

    def _ewma(self, table: dict, key) -> EWMA:
        # callers hold self._lock
        e = table.get(key)
        if e is None:
            e = table[key] = EWMA(self.alpha)
        return e

    # -- producers (instrumentation hooks call these) --------------------------
    def record_compute(self, step: str, platform: str, seconds: float):
        with self._lock:
            self._ewma(self._compute, (step, platform)).update(seconds)
            # a completed handler is a success observation for the error
            # rate — without it the EWMA would never decay after recovery
            self._ewma(self._err, (step, platform)).update(0.0)

    def record_error(self, step: str, platform: str, n: int = 1):
        """Count ``n`` failed attempts on (step, platform): bumps the error
        count and feeds 1.0-valued observations into the error-rate EWMA
        (successes feed 0.0 via ``record_compute``, so the EWMA converges
        on the live failure fraction and decays when the platform heals)."""
        if n <= 0:
            return
        with self._lock:
            key = (step, platform)
            self._err_n[key] = self._err_n.get(key, 0) + int(n)
            self._ewma(self._err, key).update_many(1.0, int(n))

    def record_fetch(self, key: str, region: str, seconds: float):
        with self._lock:
            self._ewma(self._fetch, (key, region)).update(seconds)

    def record_transfer(
        self, src_region: str, dst_region: str, size_bytes: float, seconds: float
    ):
        pair = (src_region, dst_region)
        with self._lock:
            self._ewma(self._transfer_s, pair).update(seconds)
            self._ewma(self._transfer_b, pair).update(float(size_bytes))
            pts = self._transfer_pts.get(pair)
            if pts is None:
                pts = self._transfer_pts[pair] = deque(maxlen=_FIT_WINDOW)
            pts.append((float(size_bytes), float(seconds)))

    def record_edge_bytes(self, src_step: str, dst_step: str, nbytes: float):
        """Observed payload bytes on a DAG edge (EWMA). The engine's direct
        P2P path consults this to decide, per edge, whether the payload is
        small enough to skip the store round-trip."""
        with self._lock:
            self._ewma(self._edge_b, (src_step, dst_step)).update(float(nbytes))

    def record_cold_start(
        self, step: str, platform: str, seconds: Optional[float] = None
    ):
        """Count a cold start; when the producer knows how long the warm-up
        took (compile seconds on the engine, the sampled cold draw in the
        simulator) it passes ``seconds`` so placement can price cold starts
        (``cold_penalty_s``), not just count them."""
        with self._lock:
            key = (step, platform)
            self._cold[key] = self._cold.get(key, 0) + 1
            if seconds is not None:
                self._ewma(self._cold_s, key).update(seconds)

    def record_warm_hit(self, step: str, platform: str):
        with self._lock:
            key = (step, platform)
            self._warm[key] = self._warm.get(key, 0) + 1

    # -- batch producers (the vectorized simulator reports aggregates) ---------
    def record_compute_batch(self, step: str, platform: str, seconds):
        seconds = np.asarray(seconds)
        if seconds.size == 0:
            return
        with self._lock:
            self._ewma(self._compute, (step, platform)).update_many(
                float(seconds.mean()), seconds.size
            )
            self._ewma(self._err, (step, platform)).update_many(0.0, seconds.size)

    def record_error_batch(self, step: str, platform: str, n_err: int):
        """Vectorized-simulator twin of ``record_error``."""
        self.record_error(step, platform, n_err)

    def record_fetch_batch(self, key: str, region: str, seconds):
        seconds = np.asarray(seconds)
        if seconds.size == 0:
            return
        with self._lock:
            self._ewma(self._fetch, (key, region)).update_many(
                float(seconds.mean()), seconds.size
            )

    def record_transfer_batch(
        self, src_region: str, dst_region: str, size_bytes: float, seconds
    ):
        seconds = np.asarray(seconds)
        if seconds.size == 0:
            return
        pair = (src_region, dst_region)
        with self._lock:
            self._ewma(self._transfer_s, pair).update_many(
                float(seconds.mean()), seconds.size
            )
            self._ewma(self._transfer_b, pair).update_many(
                float(size_bytes), seconds.size
            )
            pts = self._transfer_pts.get(pair)
            if pts is None:
                pts = self._transfer_pts[pair] = deque(maxlen=_FIT_WINDOW)
            pts.append((float(size_bytes), float(seconds.mean())))

    def record_cold_start_batch(
        self, step: str, platform: str, n_cold: int, n_warm: int, cold_seconds=()
    ):
        cold_seconds = np.asarray(cold_seconds)
        with self._lock:
            key = (step, platform)
            if n_cold:
                self._cold[key] = self._cold.get(key, 0) + n_cold
            if n_warm:
                self._warm[key] = self._warm.get(key, 0) + n_warm
            if cold_seconds.size:
                self._ewma(self._cold_s, key).update_many(
                    float(cold_seconds.mean()), cold_seconds.size
                )

    # -- consumers (the cost estimator pulls these) ----------------------------
    def compute_s(self, step: str, platform: str, min_samples: int = 1):
        """Observed compute EWMA, or None below ``min_samples``."""
        with self._lock:
            e = self._compute.get((step, platform))
            return e.value if e is not None and e.n >= min_samples else None

    def fetch_s(self, key: str, region: str, min_samples: int = 1):
        with self._lock:
            e = self._fetch.get((key, region))
            return e.value if e is not None and e.n >= min_samples else None

    def transfer_s(
        self, src_region: str, dst_region: str, size_bytes: float, min_samples: int = 1
    ):
        """Observed per-transfer seconds on the pair's link (EWMA), or None
        when unobserved. Deliberately NOT rescaled to ``size_bytes``: the
        observations ARE the workflow's own payload/fetch traffic, so the
        EWMA already has the units placement scoring wants — seconds per
        transfer this workflow performs on this link. (Linear rescaling
        explodes on latency-dominated links where a 64-byte payload costs
        almost what a 1 MB one does; the observed bytes EWMA is kept for
        reporting.) ``size_bytes`` stays in the signature so the estimator
        is call-compatible with ``PlacementCosts.transfer_s``."""
        pair = (src_region, dst_region)
        with self._lock:
            es = self._transfer_s.get(pair)
            return es.value if es is not None and es.n >= min_samples else None

    def transfer_fit(
        self, src_region: str, dst_region: str, min_samples: int = 4
    ) -> Optional[tuple]:
        """Latency + bandwidth decomposition of the pair's link, fit from
        the recorded (bytes, seconds) points: returns ``(latency_s,
        per_byte_s)`` with both terms clamped >= 0, or None when fewer than
        ``min_samples`` points exist or the points carry no byte spread (a
        degree-1 fit needs at least two distinct sizes). Chunked transfers
        feed chunk-sized points alongside whole-object ones, which is what
        gives the fit its spread — the same telemetry that prices whole
        transfers prices pipelined first/last bytes."""
        with self._lock:
            pts = self._transfer_pts.get((src_region, dst_region))
            if pts is None or len(pts) < min_samples:
                return None
            xs = np.array([p[0] for p in pts])
            ys = np.array([p[1] for p in pts])
        if float(xs.max() - xs.min()) <= 0.0:
            return None
        per_byte, lat = np.polyfit(xs, ys, 1)
        return max(0.0, float(lat)), max(0.0, float(per_byte))

    def edge_bytes(self, src_step: str, dst_step: str, min_samples: int = 1):
        """Observed payload-bytes EWMA for a DAG edge, or None below
        ``min_samples``."""
        with self._lock:
            e = self._edge_b.get((src_step, dst_step))
            return e.value if e is not None and e.n >= min_samples else None

    def cold_start_rate(self, step: str, platform: str):
        """cold / (cold + warm) — None before any observation."""
        with self._lock:
            key = (step, platform)
            cold, warm = self._cold.get(key, 0), self._warm.get(key, 0)
            return cold / (cold + warm) if cold + warm else None

    def cold_penalty_s(self, step: str, platform: str):
        """Expected per-request cold-start seconds on (step, platform):
        ``cold_rate x observed cold EWMA``. None when the rate is unknown
        (no invocations seen) or cold starts happened but none carried a
        duration; 0.0 when every observed invocation was warm."""
        with self._lock:
            key = (step, platform)
            cold, warm = self._cold.get(key, 0), self._warm.get(key, 0)
            if cold + warm == 0:
                return None
            if cold == 0:
                return 0.0
            e = self._cold_s.get(key)
            if e is None or e.n == 0:
                return None
            return (cold / (cold + warm)) * e.value

    def error_rate(self, step: str, platform: str):
        """EWMA failure fraction for (step, platform) — None before any
        attempt (success or failure) has been observed."""
        with self._lock:
            e = self._err.get((step, platform))
            return e.value if e is not None and e.n else None

    def error_count(self, step: str, platform: str) -> int:
        with self._lock:
            return self._err_n.get((step, platform), 0)

    def error_counts(self) -> dict:
        """{(step, platform): total errors} copy — the controller diffs
        consecutive snapshots of this to detect *fresh* failures."""
        with self._lock:
            return dict(self._err_n)

    def error_penalty_s(self, step: str, platform: str):
        """Expected extra seconds per request a flaky-but-alive cell costs:
        with failure rate ``r`` and geometric retries, the expected number
        of extra attempts is ``r / (1 - r)``, each re-paying the compute
        EWMA. None when no attempts were observed or errors happened but
        compute is unmeasured; 0.0 when every attempt succeeded. ``r`` is
        clamped to 0.9 so a near-dead platform prices large-but-finite —
        *infinite* cost is the outage trigger's job, not the penalty's."""
        with self._lock:
            e = self._err.get((step, platform))
            if e is None or e.n == 0:
                return None
            r = e.value
            if r <= 0.0:
                return 0.0
            c = self._compute.get((step, platform))
            if c is None or c.n == 0:
                return None
            r = min(r, 0.9)
            return (r / (1.0 - r)) * c.value

    def reset_errors(self, step: str, platform: str):
        """Forget the error-rate EWMA for a cell (counts are kept for the
        audit trail). The controller calls this when an outage mark expires
        so fail-back gets an optimistic probe instead of being pinned down
        by stale failure history."""
        with self._lock:
            self._err.pop((step, platform), None)

    # -- reporting -------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict copy of every table (the ``report()`` surface)."""
        with self._lock:
            return {
                "compute_s": {
                    f"{s}@{p}": e.value for (s, p), e in self._compute.items()
                },
                "fetch_s": {f"{k}@{r}": e.value for (k, r), e in self._fetch.items()},
                "transfer_s": {
                    f"{a}->{b}": e.value for (a, b), e in self._transfer_s.items()
                },
                "transfer_bytes": {
                    f"{a}->{b}": e.value for (a, b), e in self._transfer_b.items()
                },
                "edge_bytes": {
                    f"{a}->{b}": e.value for (a, b), e in self._edge_b.items()
                },
                "cold_starts": {f"{s}@{p}": n for (s, p), n in self._cold.items()},
                "warm_hits": {f"{s}@{p}": n for (s, p), n in self._warm.items()},
                "cold_s": {f"{s}@{p}": e.value for (s, p), e in self._cold_s.items()},
                "errors": {f"{s}@{p}": n for (s, p), n in self._err_n.items()},
                "error_rate": {
                    f"{s}@{p}": e.value for (s, p), e in self._err.items() if e.n
                },
            }


def attach(deployment, hub: Optional[TelemetryHub] = None) -> TelemetryHub:
    """Wire a hub into an existing (Dag)Deployment's components.

    The engine, cache, prefetcher, and store each carry a ``telemetry``
    attribute (None by default — zero overhead when unused); this sets all
    four in one place so a deployment constructed without telemetry can be
    instrumented after the fact. Returns the hub."""
    hub = hub or TelemetryHub()
    deployment.telemetry = hub
    deployment.cache.telemetry = hub
    deployment.prefetcher.telemetry = hub
    deployment.store.telemetry = hub
    return hub
