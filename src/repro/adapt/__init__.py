"""repro.adapt — online telemetry and ad-hoc workflow recomposition.

GeoFF routes are per-request data, so recomposition never needed a
redeploy; this package closes the loop that makes recomposition *online*:

  telemetry   TelemetryHub — thread-safe EWMAs of observed compute,
              fetch, transfer, and cold-start behavior, fed by duck-typed
              hooks in the engine, compile cache, prefetcher, object
              store, and the unified simulator
  costs       observed_costs(hub, fallback) — a shipping.PlacementCosts
              view over the hub that falls back to the modeled costs for
              unobserved cells, keeping place_dag total
  controller  RecompositionController (re-run the exact placement DP
              every N requests or on cost drift, with cooldown +
              minimum-improvement hysteresis) + AdaptiveDeployment
              (versioned RouteTable hot-swap over a DagDeployment;
              in-flight requests finish on their captured routes, moved
              steps are pre-warmed before cutover)
  scorer      PlacementScorer — batched candidate scoring through the
              vectorized simulator: placements are compared on simulated
              latency distributions (common random numbers, quantile
              gate), not point costs

benchmarks/adapt_bench.py degrades one platform 5x mid-run and shows the
adaptive deployment recovering most of the lost end-to-end latency.
"""

from repro.adapt.telemetry import TelemetryHub, attach  # noqa: F401
from repro.adapt.costs import observed_costs, regions_of  # noqa: F401
from repro.adapt.controller import (  # noqa: F401
    AdaptiveDeployment,
    RecompositionController,
    RouteTable,
)
from repro.adapt.scorer import PlacementScorer  # noqa: F401
