"""Batched candidate-placement scoring: distributions, not point costs.

``dag_cost`` scores a placement with one number per cost cell — fine for
the DP's search, but a swap decision deserves better: two placements with
the same expected cost can have very different tails, and the tail is what
an SLO pays for. The vectorized simulator makes the better comparison
cheap: ``PlacementScorer`` lifts a ``PlacementCosts`` (typically
``observed_costs`` over live telemetry) into a calibrated
``WorkflowSimulator`` whose transfer model IS the cost model's, then runs
one batched experiment per candidate placement — hundreds of simulated
requests per candidate in well under a millisecond — and compares the
placements at a quantile (p95 by default).

Wired into ``RecompositionController(scorer=...)``, this turns the swap
gate from "the DP's point cost improved" into "the simulated latency
distribution improved where it matters". Candidates share the seed, so the
comparison uses common random numbers: the quantile gap between two
placements is driven by the placements, not by sampling noise.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.core.shipping import PlacementCosts
from repro.core.simulator import (
    Dist,
    ExperimentSpec,
    SimPlatform,
    SimStep,
    WorkflowSimulator,
)


class _CostSimulator(WorkflowSimulator):
    """A ``WorkflowSimulator`` whose inter-step transfer times come from a
    ``PlacementCosts`` callback instead of the built-in object-store model
    (platform names double as regions here, matching the cost model's
    vocabulary)."""

    def __init__(self, costs: PlacementCosts, platforms, **kwargs):
        super().__init__(platforms, **kwargs)
        self._costs = costs

    def _transfer_s(self, src: SimPlatform, dst: SimPlatform) -> float:
        return self._costs.transfer_s(src.name, dst.name, self._costs.payload_size)


class PlacementScorer:
    """Scores placements by simulated end-to-end latency distributions.

    ``sigma`` is the multiplicative spread given to every cost-derived
    median (the cost model carries no dispersion of its own); ``quantile``
    is where placements are compared — 0.5 reproduces a median ranking,
    the 0.95 default penalizes placements that only win on average.

    ``backend`` picks the simulator backend: ``"numpy"`` (default) runs
    one vectorized experiment per candidate; ``"jax"`` scores the WHOLE
    candidate set in one jitted call (``simulate_placements``, f32) —
    same CRN property, and the per-candidate cost stops growing with the
    set size. ``seeds`` replicates the experiment (tail quantiles get
    ``len(seeds) * n_requests`` samples); None keeps the single ``seed``
    stream.
    """

    def __init__(
        self,
        n_requests: int = 256,
        seed: int = 0,
        quantile: float = 0.95,
        sigma: float = 0.12,
        interarrival_s: float = 1.0,
        msg_latency_s: float = 0.045,
        backend: str = "numpy",
        seeds=None,
    ):
        self.n_requests = n_requests
        self.seed = seed
        self.quantile = quantile
        self.sigma = sigma
        self.interarrival_s = interarrival_s
        self.msg_latency_s = msg_latency_s
        self.backend = backend
        self.seeds = tuple(seeds) if seeds is not None else None

    # -- building the simulated world from a cost model ------------------------
    def _platforms(self, placements) -> list:
        names = sorted({p for pl in placements for p in pl.values()})
        # cold starts are priced into compute by observed_costs
        # (cold_penalty_s), so the scorer's platforms never go cold here
        return [
            SimPlatform(name, name, cold_start=Dist(0.0), keep_warm_s=float("inf"))
            for name in names
        ]

    def _steps(self, nodes, order, placement, costs: PlacementCosts) -> list:
        steps = []
        for name in order:
            platform = placement[name]
            deps = getattr(nodes[name], "data_deps", ())
            steps.append(
                SimStep(
                    name,
                    platform,
                    compute=Dist(costs.compute_s(name, platform), self.sigma),
                    fetch=Dist(costs.fetch_s(name, platform, deps), self.sigma),
                )
            )
        return steps

    # -- scoring ---------------------------------------------------------------
    def distributions(
        self, nodes, edges, placements, costs: PlacementCosts, prefetch: bool = True
    ) -> np.ndarray:
        """The whole candidate set under common random numbers: a
        ``(len(placements), len(seeds or [seed]) * n_requests)`` matrix of
        simulated totals, one row per placement. ``nodes`` is
        ``{name: step}`` (anything with optional ``data_deps``), ``edges``
        the DAG edge list. On ``backend="jax"`` all rows come from ONE
        jitted sweep; on ``"numpy"``/``"scalar"`` each row is its own
        experiment on the same seeds (bit-identical draws either way
        within a backend — the CRN guarantee)."""
        order = list(nodes)
        platforms = self._platforms(placements)
        step_sets = [self._steps(nodes, order, p, costs) for p in placements]
        sim = _CostSimulator(
            costs,
            platforms,
            msg_latency_s=self.msg_latency_s,
            payload_size_bytes=costs.payload_size,
            seed=self.seed,
        )
        spec = ExperimentSpec(
            step_sets[0],
            edges=tuple(edges),
            n_requests=self.n_requests,
            interarrival_s=self.interarrival_s,
            prefetch=prefetch,
            seeds=self.seeds if self.seeds is not None else (self.seed,),
        )
        if self.backend == "jax":
            totals = sim.simulate_placements(spec, step_sets, dtype=np.float32)
        else:
            totals = np.stack(
                [
                    sim.simulate(replace(spec, steps=ss), backend=self.backend)
                    for ss in step_sets
                ],
                axis=1,
            )
        # (S, P, n) -> (P, S * n): rows are placements, columns samples
        return np.ascontiguousarray(np.swapaxes(totals, 0, 1)).reshape(
            len(placements), -1
        )

    def quantiles(
        self, nodes, edges, placements, costs: PlacementCosts, prefetch: bool = True
    ) -> list:
        """The comparison statistic per placement (same order as given)."""
        dists = self.distributions(nodes, edges, placements, costs, prefetch)
        return [float(np.quantile(row, self.quantile)) for row in dists]

    def score(
        self, nodes, edges, placement, costs: PlacementCosts, prefetch: bool = True
    ) -> dict:
        """Summary statistics for one placement's simulated distribution."""
        row = self.distributions(nodes, edges, [placement], costs, prefetch)[0]
        return {
            "median_s": float(np.median(row)),
            "p95_s": float(np.quantile(row, 0.95)),
            "p99_s": float(np.quantile(row, 0.99)),
            "mean_s": float(row.mean()),
            "quantile_s": float(np.quantile(row, self.quantile)),
        }
