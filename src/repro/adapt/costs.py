"""Observed placement costs: materialize ``shipping.PlacementCosts`` from
live telemetry (the "measured EWMA stats (runtime)" mode that
``PlacementCosts``' docstring promised and nothing ever wired).

``observed_costs(hub, fallback, regions)`` returns a ``PlacementCosts``
whose callbacks consult the ``TelemetryHub`` first and fall back to the
modeled ``fallback`` costs for any cell with too few observations — so
``place_dag`` stays total: before traffic flows the estimator IS the model,
and as observations accumulate the measured cells take over one by one.
A candidate platform a step has never run on keeps its modeled compute
cost; the link it has never crossed keeps its modeled transfer cost. That
asymmetry is what makes online recomposition safe: degradation is measured
where it happens, alternatives are scored by the calibrated model.

``regions`` maps platform name -> region because the hub observes fetches
and transfers at region granularity (where the object store lives) while
``PlacementCosts`` callbacks speak platform names.
"""

from __future__ import annotations

import math
from typing import Optional

from repro.core.shipping import PlacementCosts

from repro.adapt.telemetry import TelemetryHub


def regions_of(registry) -> dict:
    """{platform_name: region} from a PlatformRegistry."""
    return {name: registry.get(name).region for name in registry.names()}


def observed_costs(
    hub: TelemetryHub,
    fallback: PlacementCosts,
    regions: Optional[dict] = None,
    min_samples: int = 2,
    cold_starts: bool = True,
    chunks: Optional[int] = None,
    errors: bool = True,
    outages=None,
) -> PlacementCosts:
    """A ``PlacementCosts`` that prefers measurements over the model.

    - ``compute_s(step, p)``: the (step, p) EWMA once it has
      ``min_samples`` observations, else ``fallback.compute_s``. With
      ``cold_starts`` on (the default), the hub's cold/warm counts are
      folded in as an expected warm-up term, ``cold_rate x observed cold
      EWMA`` (``TelemetryHub.cold_penalty_s``) — a platform that keeps
      missing its warm pool pays for it in placement instead of winning on
      compute alone. Cells with no cold observations add nothing, so the
      estimator stays total.
    - ``fetch_s(step, p, deps)``: the sum of per-(key, region-of-p) fetch
      EWMAs when EVERY dep has been observed in that region, else
      ``fallback.fetch_s`` for the whole dep set (a half-measured set
      would mix scales).
    - ``transfer_s(a, b, size)``: the (region(a), region(b)) observed
      per-transfer EWMA — deliberately NOT rescaled to ``size`` (see
      ``TelemetryHub.transfer_s``: the observations are the workflow's own
      traffic, and linear rescaling explodes latency-dominated links) —
      else ``fallback.transfer_s``.
    - ``transfer_fl(a, b, size)`` (only when ``chunks`` resolves > 1):
      first/last-byte seconds for a pipelined edge, priced from the hub's
      latency+bandwidth fit (``TelemetryHub.transfer_fit``) — first byte
      pays latency + one chunk of bandwidth, last byte latency + the whole
      object — falling back to ``fallback.transfer_fl`` then to the
      degenerate ``(t, t)`` whole-transfer pair.

    ``chunks`` defaults to ``fallback.chunks``; when the resolved value is
    <= 1 no ``transfer_fl`` is attached, so existing callers get exactly
    the costs they always did.

    ``regions`` defaults to the identity (platform name IS the region),
    which is what the simulator benches use.

    Durability hooks (PR 10): with ``errors`` on, a flaky-but-alive cell
    pays the hub's expected-retry tax (``TelemetryHub.error_penalty_s`` —
    the error-rate twin of the cold penalty); a cell in ``outages`` (a set
    of (step, platform) pairs the controller currently considers dead)
    prices ``math.inf``, so ``place_dag`` cannot route through it at all.
    """
    regions = regions or {}
    outages = outages if outages is not None else frozenset()

    def region(platform: str) -> str:
        return regions.get(platform, platform)

    def compute_s(step, platform):
        if (step, platform) in outages:
            return math.inf
        obs = hub.compute_s(step, platform, min_samples)
        base = obs if obs is not None else fallback.compute_s(step, platform)
        if cold_starts:
            penalty = hub.cold_penalty_s(step, platform)
            if penalty:
                base += penalty
        if errors:
            penalty = hub.error_penalty_s(step, platform)
            if penalty:
                base += penalty
        return base

    def fetch_s(step, platform, deps):
        if not deps:
            return fallback.fetch_s(step, platform, deps)
        r = region(platform)
        total = 0.0
        for d in deps:
            key = getattr(d, "key", d)
            obs = hub.fetch_s(key, r, min_samples)
            if obs is None:
                return fallback.fetch_s(step, platform, deps)
            total += obs
        return total

    def transfer_s(a, b, size_bytes):
        obs = hub.transfer_s(region(a), region(b), size_bytes, min_samples)
        return obs if obs is not None else fallback.transfer_s(a, b, size_bytes)

    n_chunks = chunks if chunks is not None else fallback.chunks

    def transfer_fl(a, b, size_bytes):
        fit = hub.transfer_fit(region(a), region(b), max(min_samples, 4))
        if fit is not None:
            lat, per_byte = fit
            first = lat + (size_bytes / n_chunks) * per_byte
            last = lat + size_bytes * per_byte
            return first, last
        if fallback.transfer_fl is not None:
            return fallback.transfer_fl(a, b, size_bytes)
        t = transfer_s(a, b, size_bytes)
        return t, t

    return PlacementCosts(
        fetch_s=fetch_s,
        compute_s=compute_s,
        transfer_s=transfer_s,
        payload_size=fallback.payload_size,
        transfer_fl=transfer_fl if n_chunks > 1 else None,
        chunks=n_chunks,
    )
