"""Async sharded checkpointing with atomic manifests and restart.

Layout:  <dir>/step_<N>/
           manifest.json     {step, leaf paths, shapes, dtypes, done: true}
           <leaf>.npy        one file per pytree leaf

Fault-tolerance properties:
  - ATOMIC: leaves are written to step_<N>.tmp/, the manifest is written
    last, then the directory is renamed — a crash mid-save never corrupts
    the restore point (``latest_step`` only returns dirs with a manifest).
  - ASYNC: ``save(..., blocking=False)`` snapshots to host (device_get) and
    writes on a background thread — the GeoFF overlap pattern applied to
    checkpointing: the train loop continues while bytes hit disk.
  - SHARDED restore: leaves are loaded and ``device_put`` with the target
    sharding (which may differ from the sharding at save time — that is the
    elastic-remesh path: restore a 256-chip checkpoint onto a 240-chip mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in leaves], \
        jax.tree_util.tree_structure(tree)


def _sanitize(keystr: str) -> str:
    return keystr.replace("/", "_").replace("'", "").replace("[", "(") \
        .replace("]", ")")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="ckpt")
        self._inflight = None
        self.stats = {"saves": 0, "restores": 0, "save_s": 0.0,
                      "blocked_s": 0.0}

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        t0 = time.perf_counter()
        # snapshot to host while devices keep computing
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        snap_s = time.perf_counter() - t0

        def write():
            t1 = time.perf_counter()
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            flat, _ = _flatten(host_tree)
            manifest = {"step": step, "leaves": [], "done": True}
            for key, leaf in flat:
                fname = _sanitize(key) + ".npy"
                np.save(os.path.join(tmp, fname), leaf)
                manifest["leaves"].append(
                    {"key": key, "file": fname,
                     "shape": list(np.shape(leaf)),
                     "dtype": str(np.asarray(leaf).dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self.stats["saves"] += 1
            self.stats["save_s"] += time.perf_counter() - t1
            self._gc()

        self.wait()                       # at most one async save in flight
        if blocking:
            write()
        else:
            self._inflight = self._pool.submit(write)
        self.stats["blocked_s"] += snap_s
        return snap_s

    def wait(self):
        if self._inflight is not None:
            t0 = time.perf_counter()
            self._inflight.result()
            self.stats["blocked_s"] += time.perf_counter() - t0
            self._inflight = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name,
                                                "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree`` (shapes checked).
        ``shardings``: optional pytree of NamedShardings for device_put —
        pass the CURRENT mesh's shardings to re-shard on restore."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {leaf["key"]: leaf for leaf in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        sh_flat = None
        if shardings is not None:
            sh_flat = jax.tree_util.tree_flatten(shardings)[0]
        out = []
        for i, (path, leaf) in enumerate(flat):
            key = jax.tree_util.keystr(path)
            meta = by_key[key]
            arr = np.load(os.path.join(d, meta["file"]))
            assert tuple(arr.shape) == tuple(np.shape(leaf)), \
                (key, arr.shape, np.shape(leaf))
            if sh_flat is not None:
                arr = jax.device_put(arr, sh_flat[i])
            out.append(arr)
        self.stats["restores"] += 1
        return jax.tree_util.tree_unflatten(treedef, out)
