"""Per-request tracing: spans, events, and the deployment-wide ``Tracer``.

The engine and simulator both measure every phase GeoFF cares about —
poke, pre-warm (compile), pre-fetch, compute, payload transfer — but until
now only aggregates survived (EWMAs, counters). A ``Trace`` keeps the
per-request structure: one root span per request, child spans per node and
phase, all stamped with the request's ``trace_id``, which the engine
propagates through the whole poke/payload cascade (a fan-out's branches,
running on different platform executors, record into the same trace).

Schema — the contract ``obs.critical_path`` consumes, produced identically
by the real engine (``dag/engine.py``) and all three simulator backends:

  root span          kind="request"; covers the whole request.
  node span          kind="node", one per DAG node, with ``attrs``:
                       node, platform, preds        identity + topology
                       poke_t                       absolute poke time
                                                    (None: never poked)
                       prepare_t0, prepare_t1       warm+fetch window
                       cold_s, fetch_s, compute_s   exposed phase seconds
                       compute_t0                   handler start
                       payload_t {pred: t}          per-edge payload arrival
                       transfer_s {pred: s}         per-edge transfer cost
  phase spans        kind="warm"|"fetch"|"compute" children of the node
                     span; kind="poke"/"transfer" parented to the root —
                     presentation detail for the Perfetto export, not load
                     bearing for extraction.
  span events        point-in-time observations appended by the duck-typed
                     hooks in ``CompileCache`` / ``Prefetcher`` /
                     ``ObjectStore`` (same pattern as the PR-4 telemetry
                     taps): components carry a ``tracer`` attribute and
                     call ``tracer.event(...)``, which lands on whatever
                     span the calling thread currently has bound via
                     ``tracer.bind(span)`` — background pre-fetch jobs
                     capture the poke span at submit time.

Times are ``time.perf_counter()`` seconds (engine) or simulation-clock
seconds (simulator); everything downstream works on differences, so the
two clocks never mix within a trace. All structures are thread-safe at the
granularity the engine needs (append-only under the trace lock).

The tracer is deliberately cheap to leave attached: recording holds a lock
only to append, finished traces live in a bounded ring, and every producer
guards with ``if tracer is not None`` so the untraced path is untouched —
the same zero-overhead-when-off discipline as the telemetry hooks, with
the same draw-neutrality guarantee in the simulator (pinned by test).
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Optional

from repro.obs.metrics import MetricsRegistry

_ids = itertools.count(1)


class Span:
    """One timed operation inside a trace. Mutable until ``end`` stamps
    ``t_end``; ``events`` collects (t, name, attrs) points."""

    __slots__ = (
        "span_id",
        "trace_id",
        "parent_id",
        "name",
        "kind",
        "t_start",
        "t_end",
        "attrs",
        "events",
    )

    def __init__(self, span_id, trace_id, parent_id, name, kind, t_start, attrs):
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.name = name
        self.kind = kind
        self.t_start = t_start
        self.t_end: Optional[float] = None
        self.attrs = attrs if attrs is not None else {}
        self.events: list = []

    @property
    def duration_s(self) -> float:
        return (self.t_end if self.t_end is not None else self.t_start) - self.t_start

    def add_event(self, name: str, attrs=None, t: Optional[float] = None):
        self.events.append((time.perf_counter() if t is None else t, name, attrs or {}))

    def end(self, t: Optional[float] = None):
        self.t_end = time.perf_counter() if t is None else t


class Trace:
    """One request's spans. ``root`` is created by ``Tracer.begin``; nodes
    and phases hang off it. Append-only under ``_lock``."""

    def __init__(self, trace_id: str, root: Span):
        self.trace_id = trace_id
        self.root = root
        self.spans: list = [root]
        self._lock = threading.Lock()

    def span(
        self,
        name: str,
        kind: str,
        parent: Optional[Span] = None,
        t_start: Optional[float] = None,
        attrs=None,
    ) -> Span:
        parent = parent if parent is not None else self.root
        s = Span(
            next(_ids),
            self.trace_id,
            parent.span_id,
            name,
            kind,
            time.perf_counter() if t_start is None else t_start,
            attrs,
        )
        with self._lock:
            self.spans.append(s)
        return s

    def node_spans(self) -> dict:
        """{node_name: span} for every kind="node" span (the extraction
        surface)."""
        with self._lock:
            return {s.attrs["node"]: s for s in self.spans if s.kind == "node"}

    @property
    def total_s(self) -> float:
        return self.root.duration_s


class Tracer:
    """Deployment-wide trace collector + thread-local span binding.

    ``begin``/``finish`` bracket one request; finished traces land in a
    bounded ring (``traces()``/``last()``). ``bind(span)`` installs the
    span as the calling thread's event target so instrumented components
    (``tracer.event``) attach observations without threading a span handle
    through every signature. ``record_event`` collects trace-less control
    events (the recomposition controller's swap decisions). Span
    durations are folded into ``metrics`` histograms at ``finish`` — one
    tracer gives both per-request traces and p50/p95/p99.

    ``sample`` bounds how many per-request traces the BATCHED simulator
    backends (numpy / jax) emit per experiment: k evenly spaced requests,
    chosen deterministically (never from the experiment's rng — tracing
    stays draw-neutral).

    ``sampler`` (an ``obs.sampler.TailSampler``) makes ring retention
    *tail-based*: ``finish`` asks it whether this request's span tree is
    worth keeping (slow / SLO-violating / head-sampled) and drops the tree
    otherwise. Metrics fold regardless of the verdict, so aggregates stay
    unbiased; kept traces carry ``attrs["sampled"]`` with the reason.
    """

    def __init__(
        self,
        max_traces: int = 256,
        sample: int = 8,
        metrics: Optional[MetricsRegistry] = None,
        max_events: int = 4096,
        sampler=None,
    ):
        self.sample = sample
        self.sampler = sampler
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.events = deque(maxlen=max_events)  # control-plane events
        self._traces = deque(maxlen=max_traces)
        self._lock = threading.Lock()
        self._tls = threading.local()

    # -- trace lifecycle -------------------------------------------------------
    def begin(
        self,
        name: str = "request",
        trace_id: Optional[str] = None,
        t0: Optional[float] = None,
        attrs=None,
    ) -> Trace:
        trace_id = trace_id if trace_id is not None else f"t{next(_ids):08x}"
        root = Span(
            next(_ids),
            trace_id,
            None,
            name,
            "request",
            time.perf_counter() if t0 is None else t0,
            attrs,
        )
        return Trace(trace_id, root)

    def finish(self, trace: Trace, t_end: Optional[float] = None) -> Trace:
        if trace.root.t_end is None:
            trace.root.end(t_end)
        keep = True
        if self.sampler is not None:
            keep, reason = self.sampler.decide(trace.total_s, now=trace.root.t_end)
            if keep:
                trace.root.attrs["sampled"] = reason
        if keep:
            with self._lock:
                self._traces.append(trace)
        m = self.metrics
        if m is not None:
            with trace._lock:
                spans = list(trace.spans)
            for s in spans:
                if s.t_end is None:
                    continue
                # per-request ids must NOT become series names (unbounded
                # cardinality): roots aggregate under their kind
                label = "all" if s.kind == "request" else (
                    s.attrs.get("node") or s.name
                )
                # windows keyed on the span's own clock (perf_counter for
                # the engine, sim seconds for the backends — never mixed
                # within one tracer)
                m.observe(f"{s.kind}_s/{label}", s.duration_s, now=s.t_end)
        return trace

    def traces(self) -> list:
        with self._lock:
            return list(self._traces)

    def last(self) -> Optional[Trace]:
        with self._lock:
            return self._traces[-1] if self._traces else None

    def clear(self):
        with self._lock:
            self._traces.clear()

    # -- thread-local span binding (component hooks) ---------------------------
    def bind(self, span: Optional[Span]):
        """Context manager: install ``span`` as this thread's event target
        (None rebinds to nothing — used by pool jobs that captured no
        span)."""
        return _Bound(self._tls, span)

    def current_span(self) -> Optional[Span]:
        return getattr(self._tls, "span", None)

    def event(self, name: str, attrs=None):
        """Attach a point event to the calling thread's bound span; no-op
        when nothing is bound (a component used outside a traced
        request)."""
        span = self.current_span()
        if span is not None:
            span.add_event(name, attrs)

    # -- control-plane events (no active request) ------------------------------
    def record_event(self, name: str, attrs=None, t: Optional[float] = None):
        self.events.append((time.perf_counter() if t is None else t, name, attrs or {}))


class _Bound:
    __slots__ = ("_tls", "_span", "_prev")

    def __init__(self, tls, span):
        self._tls = tls
        self._span = span

    def __enter__(self):
        self._prev = getattr(self._tls, "span", None)
        self._tls.span = self._span
        return self._span

    def __exit__(self, *exc):
        self._tls.span = self._prev
        return False


def instrument(deployment, tracer: Optional[Tracer] = None) -> Tracer:
    """Wire a tracer into an existing (Dag)Deployment's components — the
    tracing twin of ``repro.adapt.telemetry.attach``. The engine, compile
    cache, prefetcher, and object store each carry a duck-typed ``tracer``
    attribute (None by default: zero overhead); this sets all four and
    returns the tracer."""
    tracer = tracer if tracer is not None else Tracer()
    deployment.tracer = tracer
    deployment.cache.tracer = tracer
    deployment.prefetcher.tracer = tracer
    deployment.store.tracer = tracer
    return tracer
