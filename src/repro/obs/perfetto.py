"""Chrome/Perfetto trace-event JSON export.

Serializes finished traces into the Trace Event Format that
``chrome://tracing`` and https://ui.perfetto.dev open directly: complete
events (``"ph": "X"``) per span, instant events (``"ph": "i"``) per span
event and per tracer control-plane event (recomposition swap decisions),
and metadata events naming the rows. Rows are laid out one process per
trace and one thread per platform, so a fan-out's branches render as
parallel tracks and the payload hand-offs read left to right — the same
picture as GeoFF's Fig. 4 timeline, but for a live request.

Timestamps are microseconds relative to the earliest span start across the
exported traces; both engine (perf_counter) and simulator (sim-clock)
traces export cleanly since only differences matter.
"""

from __future__ import annotations

import json
from typing import Iterable, Optional


def _us(t: float, t_base: float) -> float:
    return (t - t_base) * 1e6


def to_chrome_trace(traces: Iterable, tracer=None) -> dict:
    """Build the Trace Event Format dict for ``traces`` (plus the tracer's
    control-plane events when given). Feed to ``json.dump`` or use
    ``write_chrome_trace``."""
    traces = [t for t in traces if t is not None]
    if not traces:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t_base = min(tr.root.t_start for tr in traces)

    events = []
    named_threads = set()
    for pid, tr in enumerate(traces, start=1):
        events.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": f"request {tr.trace_id}"},
            }
        )
        with tr._lock:
            spans = list(tr.spans)
        tids: dict = {}
        for s in spans:
            platform = s.attrs.get("platform") or s.kind
            tid = tids.setdefault(platform, len(tids) + 1)
            if (pid, tid) not in named_threads:
                named_threads.add((pid, tid))
                events.append(
                    {
                        "name": "thread_name",
                        "ph": "M",
                        "pid": pid,
                        "tid": tid,
                        "args": {"name": platform},
                    }
                )
            t_end = s.t_end if s.t_end is not None else s.t_start
            events.append(
                {
                    "name": s.name,
                    "cat": s.kind,
                    "ph": "X",
                    "ts": _us(s.t_start, t_base),
                    "dur": max(_us(t_end, t_base) - _us(s.t_start, t_base), 0.0),
                    "pid": pid,
                    "tid": tid,
                    "args": {k: _jsonable(v) for k, v in s.attrs.items()},
                }
            )
            for t, name, attrs in list(s.events):
                events.append(
                    {
                        "name": name,
                        "cat": "event",
                        "ph": "i",
                        "s": "t",
                        "ts": _us(t, t_base),
                        "pid": pid,
                        "tid": tid,
                        "args": {k: _jsonable(v) for k, v in attrs.items()},
                    }
                )

    if tracer is not None:
        for t, name, attrs in list(tracer.events):
            events.append(
                {
                    "name": name,
                    "cat": "control",
                    "ph": "i",
                    "s": "g",
                    "ts": _us(t, t_base),
                    "pid": 0,
                    "tid": 0,
                    "args": {k: _jsonable(v) for k, v in attrs.items()},
                }
            )

    events.sort(key=lambda e: e.get("ts", -1.0))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, traces: Iterable, tracer=None) -> str:
    """Serialize to ``path``; returns the path for chaining/logging."""
    doc = to_chrome_trace(traces, tracer=tracer)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    return str(path)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)
