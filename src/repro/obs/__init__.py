"""repro.obs — per-request tracing, histogram metrics, critical-path
attribution, and Perfetto export.

The observability layer over the GeoFF engine and simulator: a ``Tracer``
collects per-request span trees from the real DAG engine and from all
three simulator backends in one schema, ``MetricsRegistry`` keeps bounded
log-bucketed latency histograms (p50/p95/p99), ``extract_critical_path``
attributes end-to-end latency to cold/fetch/compute/transfer/poke-slack,
and ``write_chrome_trace`` exports ``chrome://tracing`` / Perfetto JSON.
``instrument(deployment)`` wires a tracer into a live deployment the same
way ``repro.adapt.attach`` wires telemetry.
"""

from repro.obs.critical_path import (
    BUCKETS,
    CriticalPath,
    Segment,
    extract_critical_path,
)
from repro.obs.metrics import LogHistogram, MetricsRegistry
from repro.obs.perfetto import to_chrome_trace, write_chrome_trace
from repro.obs.trace import Span, Trace, Tracer, instrument

__all__ = [
    "BUCKETS",
    "CriticalPath",
    "LogHistogram",
    "MetricsRegistry",
    "Segment",
    "Span",
    "Trace",
    "Tracer",
    "extract_critical_path",
    "instrument",
    "to_chrome_trace",
    "write_chrome_trace",
]
