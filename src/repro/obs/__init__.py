"""repro.obs — per-request tracing, histogram metrics, critical-path
attribution, SLOs, tail sampling, causal profiling, and Perfetto export.

The observability layer over the GeoFF engine and simulator. Level 1
(PR 7) sees: a ``Tracer`` collects per-request span trees from the real
DAG engine and all three simulator backends in one schema,
``MetricsRegistry`` keeps bounded log-bucketed latency histograms,
``extract_critical_path`` attributes end-to-end latency to
cold/fetch/compute/transfer/stream-wait/poke-slack, and
``write_chrome_trace`` exports Perfetto JSON. Level 2 (this layer) acts:
``WindowedHistogram`` turns quantiles time-local ("p95 over the last N
seconds"), ``SloSpec``/``SloTracker`` evaluate multi-window burn rates
and emit ``slo.burn`` events, ``TailSampler`` keeps only the traces worth
debugging (slow / SLO-violating / head-sampled), and
``calibrate``/``WhatIfProfiler`` replay observed traces with virtual
speedups to rank what to fix next — advice the recomposition controller
closes the loop on (``trigger="slo"``).

``instrument(deployment)`` wires a tracer into a live deployment the same
way ``repro.adapt.attach`` wires telemetry.
"""

from repro.obs.critical_path import (
    BUCKETS,
    CriticalPath,
    Segment,
    extract_critical_path,
)
from repro.obs.metrics import LogHistogram, MetricsRegistry, WindowedHistogram
from repro.obs.perfetto import to_chrome_trace, write_chrome_trace
from repro.obs.profiler import (
    CalibratedWorkflow,
    Intervention,
    WhatIfProfiler,
    calibrate,
    profile_trace,
)
from repro.obs.sampler import TailSampler
from repro.obs.slo import SloSpec, SloTracker
from repro.obs.trace import Span, Trace, Tracer, instrument

__all__ = [
    "BUCKETS",
    "CalibratedWorkflow",
    "CriticalPath",
    "Intervention",
    "LogHistogram",
    "MetricsRegistry",
    "Segment",
    "SloSpec",
    "SloTracker",
    "Span",
    "TailSampler",
    "Trace",
    "Tracer",
    "WhatIfProfiler",
    "WindowedHistogram",
    "calibrate",
    "extract_critical_path",
    "instrument",
    "profile_trace",
    "to_chrome_trace",
    "write_chrome_trace",
]
