"""What-if causal profiling: rank interventions by predicted tail impact.

A flame graph says where time WENT; it cannot say what fixing a component
would BUY — off-critical-path work attributes seconds that, removed,
change nothing, and a shared bottleneck can matter more than its share
suggests. Causal profiling (Coz, Curtsinger & Berger, SOSP'15) answers
the right question by *virtual speedups*: perturb one component, measure
the end-to-end delta. We get the perturbation for free — PR 7's traces
calibrate the workflow simulator to what production actually observed
(``scripts/trace_diff`` showed the calibrated model tracks the real
engine to <1% per bucket), so a virtual speedup is just an edited
``ExperimentSpec`` replayed on the vectorized backend.

Pipeline:

  ``calibrate(trace)``     observed trace -> :class:`CalibratedWorkflow`
                           (platform cold starts, per-step compute/fetch
                           medians, per-edge transfer table, estimated
                           poke latency — all pinned, sigma 0 by default
                           so replays are exact, not sampled)
  ``WhatIfProfiler``       applies one intervention per run — 2x compute
                           per step, 2x fetch / enable pre-fetch per
                           fetching step, 2x transfer per edge (what
                           streaming or co-placement buys), cold-start
                           elimination per platform (pre-warming) — and
                           ranks by predicted p95 delta: "pre-fetch
                           ocr/weights: -31% p95", "stream edge
                           virus->e_mail: -12% p95".

The per-edge transfer pins ride the simulator's ``transfer_table`` hook,
honored by all three backends. The ranked list is advice in the paper's
own vocabulary: pre-fetch, pre-warm, move/stream the edge.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.simulator import Dist, ExperimentSpec, SimPlatform, SimStep
from repro.core.simulator import WorkflowSimulator


# -- trace -> model -------------------------------------------------------------
def full_fetch_s(trace) -> dict:
    """Full (pre-overlap) fetch seconds per (node, key), from component
    span events. A node span's ``fetch_s`` is only the RESIDUAL the
    request waited; ``prefetch.done`` / ``fetch.cold`` events carry the
    modeled duration, and land on poke/fetch spans that name their node."""
    out: dict = {}
    for span in trace.spans:
        node = span.attrs.get("node") if span.attrs else None
        for _t, name, attrs in span.events:
            if name in ("prefetch.done", "fetch.cold") and "modeled_s" in attrs:
                k = (node, attrs.get("key"))
                out[k] = max(out.get(k, 0.0), float(attrs["modeled_s"]))
    return out


def estimate_msg_s(trace, default: float = 0.005) -> float:
    """Poke message latency from observed poke times: median of
    ``(poke_t - t0) / depth`` over nodes with poke depth >= 1."""
    nodes = trace.node_spans()
    preds = {n: set(s.attrs.get("preds") or ()) for n, s in nodes.items()}
    depth, frontier, d = {}, {n for n, p in preds.items() if not p}, 0
    while frontier:
        for n in frontier:
            depth[n] = d
        frontier = {n for n in preds if n not in depth and preds[n] <= set(depth)}
        d += 1
    ests = [
        (nodes[n].attrs["poke_t"] - trace.root.t_start) / depth[n]
        for n in nodes
        if depth.get(n, 0) >= 1 and nodes[n].attrs.get("poke_t") is not None
    ]
    return float(np.median(ests)) if ests else default


@dataclass(frozen=True)
class CalibratedWorkflow:
    """A simulator-ready model pinned to one observed trace: the shared
    input of the what-if profiler and ``scripts/trace_diff``."""

    platforms: tuple
    steps: tuple
    edges: Optional[tuple]
    transfer_table: dict = field(default_factory=dict)
    msg_latency_s: float = 0.005
    prefetch: bool = True

    def simulator(self, seed: int = 0, **kw) -> WorkflowSimulator:
        return WorkflowSimulator(
            list(self.platforms),
            msg_latency_s=self.msg_latency_s,
            transfer_table=dict(self.transfer_table),
            seed=seed,
            **kw,
        )

    def spec(self, **kw) -> ExperimentSpec:
        kw.setdefault("prefetch", self.prefetch)
        return ExperimentSpec(self.steps, edges=self.edges, **kw)


def calibrate(trace, regions=None, sigma: float = 0.0) -> CalibratedWorkflow:
    """Build a :class:`CalibratedWorkflow` from one observed trace (real
    engine or simulator — both emit the same span schema).

    Per platform: cold start pinned to the worst observed ``cold_s`` (the
    draw the trace actually paid); region looked up in ``regions`` (name
    -> region, defaults to the platform name — with every observed edge
    pinned in the transfer table, regions only matter for edges the trace
    never exercised). Per step: compute pinned to ``compute_s``; fetch
    pinned to the max of the summed per-key modeled fetches and the
    residual ``fetch_s`` (the prefetcher may have hidden most of it);
    ``prefetch`` mirrors whether the node was actually poked. Per edge:
    ``transfer_s`` attrs become the transfer table. ``sigma`` widens every
    pinned value into a lognormal for stochastic replay; the default 0
    keeps replays exact."""
    nodes = trace.node_spans()
    if not nodes:
        raise ValueError("trace has no node spans to calibrate from")
    regions = regions or {}
    fetch_by = full_fetch_s(trace)

    order = sorted(nodes)  # deterministic; the simulator re-topo-sorts
    plat_names = sorted({s.attrs["platform"] for s in nodes.values()})
    platforms = []
    for pname in plat_names:
        colds = [
            s.attrs.get("cold_s") or 0.0
            for s in nodes.values()
            if s.attrs["platform"] == pname
        ]
        platforms.append(
            SimPlatform(
                pname,
                regions.get(pname, pname),
                cold_start=Dist(max(colds, default=0.0), sigma),
            )
        )

    steps, edges, table = [], [], {}
    for name in order:
        span = nodes[name]
        a = span.attrs
        keyed = sum(v for (node, _k), v in fetch_by.items() if node == name)
        fetch = max(keyed, a.get("fetch_s") or 0.0)
        poked = a.get("poke_t") is not None
        steps.append(
            SimStep(
                name,
                a["platform"],
                compute=Dist(a.get("compute_s") or 0.0, sigma),
                fetch=Dist(fetch, sigma),
                prefetch=poked or not (a.get("preds") or ()),
            )
        )
        for pred in a.get("preds") or ():
            edges.append((pred, name))
            tr = (a.get("transfer_s") or {}).get(pred)
            if tr is not None:
                table[(pred, name)] = float(tr)

    return CalibratedWorkflow(
        platforms=tuple(platforms),
        steps=tuple(steps),
        edges=tuple(edges) if edges else None,
        transfer_table=table,
        msg_latency_s=estimate_msg_s(trace),
        prefetch=any(s.attrs.get("poke_t") is not None for s in nodes.values()),
    )


# -- virtual speedups -----------------------------------------------------------
@dataclass(frozen=True)
class Intervention:
    """One virtual change and its predicted end-to-end effect."""

    kind: str  # "compute" | "fetch" | "prefetch" | "transfer" | "warm"
    target: str  # step name, "src->dst" edge, or platform name
    speedup: float
    baseline_s: float
    predicted_s: float
    quantile: float

    @property
    def delta_s(self) -> float:
        return self.predicted_s - self.baseline_s

    @property
    def delta_pct(self) -> float:
        return 100.0 * self.delta_s / self.baseline_s if self.baseline_s else 0.0

    @property
    def label(self) -> str:
        q = f"p{int(round(self.quantile * 100))}"
        what = {
            "compute": f"{self.speedup:g}x compute {self.target}",
            "fetch": f"{self.speedup:g}x fetch {self.target}",
            "prefetch": f"pre-fetch deps of {self.target}",
            "transfer": f"stream edge {self.target}",
            "warm": f"keep {self.target} warm",
        }[self.kind]
        return f"{what}: {self.delta_pct:+.1f}% {q}"


def _scaled(dist: Dist, speedup: float) -> Dist:
    return Dist(dist.median / speedup, dist.sigma)


class WhatIfProfiler:
    """Rank virtual interventions on a :class:`CalibratedWorkflow` by
    predicted tail-quantile delta (most negative — biggest win — first).

    Every candidate run replays the same request stream on the vectorized
    numpy backend with exactly one thing changed; with the calibrated
    model's sigma 0 the replays are deterministic, so deltas are exact
    model predictions, not noisy estimates. Candidates cover the paper's
    intervention vocabulary: faster/pre-fetched data deps, pre-warmed
    platforms, faster (streamed / co-placed) edges, and plain compute
    optimization as the control."""

    def __init__(
        self,
        world: CalibratedWorkflow,
        n_requests: int = 200,
        interarrival_s: float = 1.0,
        quantile: float = 0.95,
        seeds: Optional[tuple] = None,
        backend: str = "numpy",
    ):
        self.world = world
        self.n_requests = n_requests
        self.interarrival_s = interarrival_s
        self.quantile = quantile
        self.seeds = seeds
        self.backend = backend

    def _quantile_of(self, steps=None, transfer_table=None, platforms=None) -> float:
        w = self.world
        sim = WorkflowSimulator(
            list(platforms if platforms is not None else w.platforms),
            msg_latency_s=w.msg_latency_s,
            transfer_table=dict(
                transfer_table if transfer_table is not None else w.transfer_table
            ),
            seed=0,
        )
        spec = ExperimentSpec(
            steps if steps is not None else w.steps,
            edges=w.edges,
            n_requests=self.n_requests,
            interarrival_s=self.interarrival_s,
            prefetch=w.prefetch,
            seeds=self.seeds,
        )
        totals = sim.simulate(spec, backend=self.backend)
        return float(np.quantile(np.asarray(totals).ravel(), self.quantile))

    def baseline(self) -> float:
        if not hasattr(self, "_baseline"):
            self._baseline = self._quantile_of()
        return self._baseline

    def _candidates(self, speedup: float):
        w = self.world
        steps = list(w.steps)
        for i, s in enumerate(steps):
            if s.compute.median > 0:
                edit = steps[:i] + [
                    dataclasses.replace(s, compute=_scaled(s.compute, speedup))
                ] + steps[i + 1 :]
                yield ("compute", s.name, {"steps": edit})
            if s.fetch.median > 0:
                edit = steps[:i] + [
                    dataclasses.replace(s, fetch=_scaled(s.fetch, speedup))
                ] + steps[i + 1 :]
                yield ("fetch", s.name, {"steps": edit})
                if not s.prefetch:
                    edit = steps[:i] + [
                        dataclasses.replace(s, prefetch=True)
                    ] + steps[i + 1 :]
                    yield ("prefetch", s.name, {"steps": edit})
        for (u, v), tr in sorted(w.transfer_table.items()):
            table = dict(w.transfer_table)
            table[(u, v)] = tr / speedup
            yield ("transfer", f"{u}->{v}", {"transfer_table": table})
        for i, p in enumerate(w.platforms):
            if p.cold_start.median > 0:
                plats = list(w.platforms)
                plats[i] = dataclasses.replace(p, cold_start=Dist(0.0, 0.0))
                yield ("warm", p.name, {"platforms": plats})

    def rank(self, speedup: float = 2.0, top: Optional[int] = None) -> list:
        base = self.baseline()
        out = []
        for kind, target, kw in self._candidates(speedup):
            q = self._quantile_of(**kw)
            out.append(
                Intervention(
                    kind=kind,
                    target=target,
                    speedup=speedup,
                    baseline_s=base,
                    predicted_s=q,
                    quantile=self.quantile,
                )
            )
        out.sort(key=lambda iv: (iv.predicted_s, iv.kind, iv.target))
        return out if top is None else out[:top]


def profile_trace(trace, regions=None, speedup: float = 2.0, top: int = 3, **kw):
    """One-call surface: calibrate from a trace and return the top ranked
    interventions (``scripts/obs_report.py`` uses this)."""
    world = calibrate(trace, regions=regions)
    return WhatIfProfiler(world, **kw).rank(speedup=speedup, top=top)
