"""Bounded log-bucketed latency histograms (the tail side of telemetry).

``repro.adapt.TelemetryHub`` keeps EWMAs — the right shape for placement
cost cells, and the wrong shape for "why was request #4812 slow?": an EWMA
cannot say p99. The FaaS measurement literature (Characterizing FaaS
Workflows on Public Clouds, PAPERS.md) attributes tail latency per
percentile, so ``repro.obs`` keeps full distributions — as histograms with
geometrically spaced buckets, which cost a fixed few hundred ints per
series no matter how many observations land (a long-lived deployment must
never grow per-request state).

``LogHistogram`` covers 1 microsecond to ~1 hour in 160 buckets at 15%
relative width: quantiles interpolate inside the winning bucket, so a
reported p99 is within one bucket width (~15%) of the true order
statistic — tight enough to rank and alert on, bounded enough to keep
forever. ``WindowedHistogram`` adds the time axis an SLO needs: a ring of
per-epoch sub-histograms rotated in O(1), merged on demand into "the
distribution over the last N seconds" — so p95 can mean *now*, not
since-birth. ``MetricsRegistry`` is the named collection the engine,
simulator and tracer feed; ``DagDeployment.report()`` merges its snapshot
next to the counter/EWMA surfaces.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Optional


class LogHistogram:
    """Fixed-size histogram with geometrically spaced bucket edges.

    Bucket ``i`` (0-based) covers ``[min_value * base**i,
    min_value * base**(i+1))``; one underflow and one overflow bucket
    bracket the range, so ``observe`` never fails and memory never grows.
    """

    __slots__ = ("base", "min_value", "n_buckets", "counts", "count", "sum", "max")

    def __init__(
        self, base: float = 1.15, min_value: float = 1e-6, n_buckets: int = 160
    ):
        self.base = base
        self.min_value = min_value
        self.n_buckets = n_buckets
        self.counts = [0] * (n_buckets + 2)  # [underflow, buckets..., overflow]
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def _bucket(self, x: float) -> int:
        if x < self.min_value:
            return 0
        i = int(math.log(x / self.min_value) / math.log(self.base))
        return min(i, self.n_buckets) + 1

    def observe(self, x: float):
        x = float(x)
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.sum += x
        if x > self.max:
            self.max = x

    def reset(self):
        """Zero in place (epoch recycling — no reallocation on rotate)."""
        for i in range(len(self.counts)):
            self.counts[i] = 0
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def copy(self) -> "LogHistogram":
        """Cheap structural copy: lets ``MetricsRegistry.snapshot`` copy
        bucket counts under its lock and run the quantile rank walks
        OUTSIDE it (a reporter must never block the observe hot path)."""
        h = LogHistogram(self.base, self.min_value, self.n_buckets)
        h.counts = list(self.counts)
        h.count = self.count
        h.sum = self.sum
        h.max = self.max
        return h

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other`` into self (same bucketing required). Maxes merge
        too, so a windowed histogram assembled from per-epoch pieces
        carries the max of the LIVE epochs only — an evicted epoch's
        stale all-time max can never clamp a windowed p99."""
        if (
            other.base != self.base
            or other.min_value != self.min_value
            or other.n_buckets != self.n_buckets
        ):
            raise ValueError("merge requires identical bucket geometry")
        counts = self.counts
        for i, c in enumerate(other.counts):
            if c:
                counts[i] += c
        self.count += other.count
        self.sum += other.sum
        if other.max > self.max:
            self.max = other.max
        return self

    def _edge(self, i: int) -> float:
        """Lower edge of bucket slot ``i`` (slot 0 is the underflow)."""
        if i <= 0:
            return 0.0
        return self.min_value * self.base ** (i - 1)

    def quantile(self, q: float) -> float:
        """The q-quantile by rank walk + geometric interpolation inside the
        winning bucket — exact to one bucket width (~``base - 1`` relative).
        0.0 before any observation."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c > rank:
                frac = (rank - seen + 0.5) / c
                lo = self._edge(i)
                hi = self._edge(i + 1) if i <= self.n_buckets else self.max
                if lo <= 0.0:
                    return min(hi, self.max)
                return min(lo * (hi / lo) ** min(max(frac, 0.0), 1.0), self.max)
            seen += c
        return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum_s": self.sum,
            "mean_s": self.sum / self.count if self.count else 0.0,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "p99_s": self.quantile(0.99),
            "max_s": self.max,
        }


class WindowedHistogram:
    """A ``LogHistogram`` with a time axis: quantiles over the trailing
    ``window_s`` seconds, not since birth.

    Implementation: a ring of ``epochs`` sub-histograms, each covering
    ``window_s / epochs`` seconds of the caller's clock. ``observe`` lands
    in the epoch containing ``now``; advancing past an epoch boundary
    rotates the ring in O(epochs)-bounded work (recycle the slots that
    fell out — no per-observation scan, no reallocation). ``window()``
    merges the live epochs into one histogram, so windowed quantiles cost
    the same rank walk as lifetime ones, and each epoch carries its OWN
    max (a stale all-time max from an evicted epoch cannot bias the
    windowed p99 — the bug the since-birth ``max`` clamp would introduce).

    The clock is whatever the producer passes as ``now`` — engine
    ``perf_counter`` seconds or simulation-clock seconds; one histogram
    must be fed by one clock. ``total`` keeps the since-birth histogram
    beside the ring. Not thread-safe on its own: ``MetricsRegistry``
    serializes access.
    """

    __slots__ = ("window_s", "epochs", "epoch_s", "total", "_ring", "_ids", "_cur")

    def __init__(self, window_s: float = 300.0, epochs: int = 10, **hist_kw):
        if window_s <= 0 or epochs <= 0:
            raise ValueError("window_s and epochs must be positive")
        self.window_s = float(window_s)
        self.epochs = int(epochs)
        self.epoch_s = self.window_s / self.epochs
        self.total = LogHistogram(**hist_kw)
        self._ring = [LogHistogram(**hist_kw) for _ in range(self.epochs)]
        self._ids = [None] * self.epochs  # absolute epoch id held per slot
        self._cur: Optional[int] = None  # latest epoch id seen

    def _epoch(self, now: float) -> int:
        return int(math.floor(now / self.epoch_s))

    def _rotate(self, e: int):
        """Advance the ring to epoch ``e``, recycling every slot that fell
        out of the window — at most ``epochs`` slots, however far the
        clock jumped (O(1) amortized per observation)."""
        if self._cur is not None and e <= self._cur:
            return  # same epoch, or a slightly-late observation: absorb
        steps = self.epochs if self._cur is None else min(e - self._cur, self.epochs)
        for eid in range(e - steps + 1, e + 1):
            slot = eid % self.epochs
            self._ring[slot].reset()
            self._ids[slot] = eid
        self._cur = e

    def observe(self, x: float, now: float):
        self._rotate(self._epoch(now))
        self._ring[self._cur % self.epochs].observe(x)
        self.total.observe(x)

    def window(self, now: Optional[float] = None) -> LogHistogram:
        """The merged histogram over epochs in the trailing window ending
        at ``now`` (default: the last observation's epoch). Read-only —
        never rotates, so probing a future ``now`` just sees epochs age
        out."""
        h = self.total
        out = LogHistogram(h.base, h.min_value, h.n_buckets)
        if self._cur is None:
            return out
        e = self._cur if now is None else self._epoch(now)
        lo = e - self.epochs  # live ids are (e - epochs, e]
        for slot, eid in enumerate(self._ids):
            if eid is not None and lo < eid <= e and self._ring[slot].count:
                out.merge(self._ring[slot])
        return out

    def copy(self) -> "WindowedHistogram":
        h = self.total
        c = WindowedHistogram.__new__(WindowedHistogram)
        c.window_s = self.window_s
        c.epochs = self.epochs
        c.epoch_s = self.epoch_s
        c.total = self.total.copy()
        c._ring = [hh.copy() for hh in self._ring]
        c._ids = list(self._ids)
        c._cur = self._cur
        del h
        return c

    def snapshot(self, now: Optional[float] = None) -> dict:
        """Since-birth fields (the PR-7 contract) plus the windowed tail:
        ``w_count`` / ``w_p50_s`` / ``w_p95_s`` / ``w_p99_s`` / ``w_max_s``
        over the trailing ``window_s`` seconds."""
        out = self.total.snapshot()
        w = self.window(now)
        out.update(
            {
                "window_s": self.window_s,
                "w_count": w.count,
                "w_p50_s": w.quantile(0.50),
                "w_p95_s": w.quantile(0.95),
                "w_p99_s": w.quantile(0.99),
                "w_max_s": w.max,
            }
        )
        return out


class MetricsRegistry:
    """Thread-safe named histogram collection, bounded in series count.

    Producers call ``observe(name, seconds, now=...)``; the name
    vocabulary is ``<signal>/<where>`` (e.g. ``compute_s/ocr@gcf``,
    ``transfer_s/eu->us``). Beyond ``max_series`` distinct names, new
    series are dropped and counted in ``dropped_series`` — a runaway label
    cardinality must degrade reporting, never memory.

    Every series is a ``WindowedHistogram``: since-birth quantiles stay
    (``quantiles``), and ``window_quantiles`` / the ``w_*`` snapshot
    fields answer "p95 over the last ``window_s`` seconds". ``now``
    defaults to ``time.monotonic()``; the tracer passes each span's end
    time so a registry fed from simulation traces windows on the sim
    clock.

    ``snapshot`` copies bucket counts under the lock and computes every
    quantile OUTSIDE it — with 512 series x 160 buckets the rank walks
    are the expensive part, and a reporter must never stall a hot-path
    ``observe`` behind them.
    """

    def __init__(
        self, max_series: int = 512, window_s: float = 300.0, epochs: int = 10
    ):
        self.max_series = max_series
        self.window_s = window_s
        self.epochs = epochs
        self._lock = threading.Lock()
        self._hists: dict = {}
        self.dropped_series = 0

    def observe(self, name: str, value: float, now: Optional[float] = None):
        if now is None:
            now = time.monotonic()
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                if len(self._hists) >= self.max_series:
                    self.dropped_series += 1
                    return
                h = self._hists[name] = WindowedHistogram(self.window_s, self.epochs)
            h.observe(value, now)

    def _copy(self, name: str) -> Optional[WindowedHistogram]:
        with self._lock:
            h = self._hists.get(name)
            return None if h is None else h.copy()

    def quantiles(self, name: str) -> tuple:
        """Since-birth (p50, p95, p99) for one series — zeros when
        unobserved. Rank walks run on a copy, outside the lock."""
        h = self._copy(name)
        if h is None:
            return (0.0, 0.0, 0.0)
        t = h.total
        return (t.quantile(0.50), t.quantile(0.95), t.quantile(0.99))

    def window_quantiles(self, name: str, now: Optional[float] = None) -> tuple:
        """(p50, p95, p99) over the trailing window — zeros when
        unobserved (or when every epoch aged out)."""
        h = self._copy(name)
        if h is None:
            return (0.0, 0.0, 0.0)
        w = h.window(now)
        return (w.quantile(0.50), w.quantile(0.95), w.quantile(0.99))

    def top(
        self, n: int = 5, key: str = "w_p99_s", now: Optional[float] = None
    ) -> list:
        """The ``n`` hottest series by one snapshot field (windowed p99 by
        default) — the ops-report surface. Returns (name, snapshot)
        pairs, hottest first."""
        snap = self.snapshot(now)
        rows = [(name, s) for name, s in snap.items() if not name.startswith("__")]
        rows.sort(key=lambda kv: kv[1].get(key, 0.0), reverse=True)
        return rows[:n]

    def snapshot(self, now: Optional[float] = None) -> dict:
        with self._lock:  # copy counts only; quantile math happens below
            copies = sorted((name, h.copy()) for name, h in self._hists.items())
            dropped = self.dropped_series
        out = {name: h.snapshot(now) for name, h in copies}
        if dropped:
            out["__dropped_series__"] = dropped
        return out
