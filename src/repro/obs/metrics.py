"""Bounded log-bucketed latency histograms (the tail side of telemetry).

``repro.adapt.TelemetryHub`` keeps EWMAs — the right shape for placement
cost cells, and the wrong shape for "why was request #4812 slow?": an EWMA
cannot say p99. The FaaS measurement literature (Characterizing FaaS
Workflows on Public Clouds, PAPERS.md) attributes tail latency per
percentile, so ``repro.obs`` keeps full distributions — as histograms with
geometrically spaced buckets, which cost a fixed few hundred ints per
series no matter how many observations land (a long-lived deployment must
never grow per-request state).

``LogHistogram`` covers 1 microsecond to ~1 hour in 160 buckets at 15%
relative width: quantiles interpolate inside the winning bucket, so a
reported p99 is within one bucket width (~15%) of the true order
statistic — tight enough to rank and alert on, bounded enough to keep
forever. ``MetricsRegistry`` is the named collection the engine, simulator
and tracer feed; ``DagDeployment.report()`` merges its snapshot next to the
counter/EWMA surfaces.
"""

from __future__ import annotations

import math
import threading


class LogHistogram:
    """Fixed-size histogram with geometrically spaced bucket edges.

    Bucket ``i`` (0-based) covers ``[min_value * base**i,
    min_value * base**(i+1))``; one underflow and one overflow bucket
    bracket the range, so ``observe`` never fails and memory never grows.
    """

    __slots__ = ("base", "min_value", "n_buckets", "counts", "count", "sum", "max")

    def __init__(
        self, base: float = 1.15, min_value: float = 1e-6, n_buckets: int = 160
    ):
        self.base = base
        self.min_value = min_value
        self.n_buckets = n_buckets
        self.counts = [0] * (n_buckets + 2)  # [underflow, buckets..., overflow]
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def _bucket(self, x: float) -> int:
        if x < self.min_value:
            return 0
        i = int(math.log(x / self.min_value) / math.log(self.base))
        return min(i, self.n_buckets) + 1

    def observe(self, x: float):
        x = float(x)
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.sum += x
        if x > self.max:
            self.max = x

    def _edge(self, i: int) -> float:
        """Lower edge of bucket slot ``i`` (slot 0 is the underflow)."""
        if i <= 0:
            return 0.0
        return self.min_value * self.base ** (i - 1)

    def quantile(self, q: float) -> float:
        """The q-quantile by rank walk + geometric interpolation inside the
        winning bucket — exact to one bucket width (~``base - 1`` relative).
        0.0 before any observation."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c > rank:
                frac = (rank - seen + 0.5) / c
                lo = self._edge(i)
                hi = self._edge(i + 1) if i <= self.n_buckets else self.max
                if lo <= 0.0:
                    return min(hi, self.max)
                return min(lo * (hi / lo) ** min(max(frac, 0.0), 1.0), self.max)
            seen += c
        return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum_s": self.sum,
            "mean_s": self.sum / self.count if self.count else 0.0,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "p99_s": self.quantile(0.99),
            "max_s": self.max,
        }


class MetricsRegistry:
    """Thread-safe named histogram collection, bounded in series count.

    Producers call ``observe(name, seconds)``; the name vocabulary is
    ``<signal>/<where>`` (e.g. ``compute_s/ocr@gcf``,
    ``transfer_s/eu->us``). Beyond ``max_series`` distinct names, new
    series are dropped and counted in ``dropped_series`` — a runaway label
    cardinality must degrade reporting, never memory.
    """

    def __init__(self, max_series: int = 512):
        self.max_series = max_series
        self._lock = threading.Lock()
        self._hists: dict = {}
        self.dropped_series = 0

    def observe(self, name: str, value: float):
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                if len(self._hists) >= self.max_series:
                    self.dropped_series += 1
                    return
                h = self._hists[name] = LogHistogram()
            h.observe(value)

    def quantiles(self, name: str) -> tuple:
        """(p50, p95, p99) for one series — zeros when unobserved."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                return (0.0, 0.0, 0.0)
            return (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99))

    def snapshot(self) -> dict:
        with self._lock:
            out = {name: h.snapshot() for name, h in sorted(self._hists.items())}
            if self.dropped_series:
                out["__dropped_series__"] = self.dropped_series
            return out
