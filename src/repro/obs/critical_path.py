"""Critical-path extraction + latency attribution from a finished trace.

This is the runtime dual of the simulator's recurrence: where the forward
pass computes ``start[v] = max(prepare[v], max_u(end[u] + transfer))``, the
backward walk here asks, at every instant of a finished request, *which
constraint was binding* — and tiles the whole ``[t0, sink_end]`` interval
with segments labelled by GeoFF's cost taxonomy:

  compute      a handler was running on the path
  transfer     a payload was in flight on the binding edge
  fetch        the node was waiting on data download (exposed, post-poke)
  cold         the node was waiting on a cold start / compile
  stream_wait  residual streamed chunks were draining: the node already
               held the first chunk (engine: wait between prepare and the
               handler; sim: the pipelined tail after compute)
  poke_slack   everything before the binding chain's first poke-gated
               prepare window (poke message fan-out, scheduling slack,
               and any unattributed gap between phases)

Because the segments tile the interval exactly (gaps become slack), the
bucket sums equal ``sink_end - t0`` by construction — the 5% acceptance
margin in ISSUE 7 only absorbs the epsilon between the root span and the
latest sink, never bookkeeping drift.

The walk consumes only the node-span attrs contract documented in
``obs.trace`` — so the same extractor serves the real engine and all three
simulator backends, which is precisely what lets ``scripts/trace_diff.py``
diff them per bucket.

Node-gating logic, per node ``v`` with cursor at its compute start:

  * compute segment ``[compute_t0, compute_t0 + compute_s]``; any gap from
    the previous segment is slack.
  * the binding constraint for ``compute_t0`` is whichever is later:
    ``prepare_t1`` (warm+fetch window end) or the latest payload arrival.
  * prepare-bound → attribute ``fetch`` ``[prepare_t1 - fetch_s,
    prepare_t1]`` then ``cold`` ``[prepare_t0, prepare_t0 + cold_s]``;
    then, if the prepare window opened at the poke (``prepare_t0 ≈
    poke_t``) the chain terminates in poke slack ``[t0, cursor]``; else the
    prepare window itself was payload-gated (engine semantics: warm/fetch
    exposed at fire time) and the walk continues through the predecessors.
  * payload-bound → ``transfer`` ``[arrival - transfer_s[u*], arrival]``
    on the argmax-arrival edge ``u*``, then recurse into ``u*``.
  * a source with no poke and no preds terminates in slack ``[t0, cursor]``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

BUCKETS = ("cold", "fetch", "compute", "transfer", "stream_wait", "poke_slack")

# prepare_t0 within this of poke_t counts as poke-gated (engine clocks are
# perf_counter with scheduling noise; sim clocks are exact).
_POKE_TOL = 5e-3


@dataclass
class Segment:
    """One contiguous attributed interval on the critical path."""

    t0: float
    t1: float
    bucket: str
    node: Optional[str] = None
    edge: Optional[Tuple[str, str]] = None

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


@dataclass
class CriticalPath:
    """The binding chain of a finished request, latest-sink-first walk
    re-sorted into time order. ``attribution`` sums segment durations per
    bucket; ``total_s`` is the walked interval ``sink_end - t0`` (== sum of
    all buckets, by construction)."""

    trace_id: str
    nodes: List[str]  # path nodes, source-to-sink order
    segments: List[Segment] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        if not self.segments:
            return 0.0
        return self.segments[-1].t1 - self.segments[0].t0

    @property
    def attribution(self) -> dict:
        out = {b: 0.0 for b in BUCKETS}
        for s in self.segments:
            out[s.bucket] += s.duration_s
        return out

    def format(self) -> str:
        attr = self.attribution
        total = self.total_s or 1.0
        lines = [
            f"critical path [{self.trace_id}]: {' -> '.join(self.nodes)}",
            f"  total {total:.4f}s",
        ]
        for b in BUCKETS:
            lines.append(f"  {b:<12}{attr[b]:>9.4f}s  {100.0 * attr[b] / total:5.1f}%")
        return "\n".join(lines)


def _node_attrs(span) -> dict:
    return span.attrs


def extract_critical_path(trace, tol: float = _POKE_TOL) -> CriticalPath:
    """Walk a finished trace backward from its latest-ending sink, emitting
    segments that tile ``[t0, sink_end]``. Raises ``ValueError`` on a trace
    with no node spans or an unfinished node on the binding chain."""
    nodes = trace.node_spans()
    if not nodes:
        raise ValueError(f"trace {trace.trace_id} has no node spans")
    t0 = trace.root.t_start

    # latest-ending node is the binding sink, whatever the DAG calls it
    sink = max(nodes.values(), key=lambda s: s.t_end if s.t_end is not None else t0)
    if sink.t_end is None:
        raise ValueError(f"trace {trace.trace_id}: sink span unfinished")

    segments: List[Segment] = []
    path_nodes: List[str] = []
    cursor = sink.t_end

    def emit(seg_t0: float, seg_t1: float, bucket: str, node=None, edge=None):
        nonlocal cursor
        seg_t0 = max(seg_t0, t0)
        seg_t1 = min(seg_t1, cursor)
        if seg_t1 < cursor:  # gap between phases → slack
            segments.append(Segment(seg_t1, cursor, "poke_slack", node=node))
        if seg_t1 > seg_t0:
            segments.append(Segment(seg_t0, seg_t1, bucket, node=node, edge=edge))
        cursor = min(cursor, seg_t0)

    span = sink
    visited = set()
    while True:
        a = _node_attrs(span)
        name = a["node"]
        if name in visited:  # defensive: malformed trace must not loop
            break
        visited.add(name)
        path_nodes.append(name)

        compute_t0 = a.get("compute_t0", span.t_start)
        compute_s = a.get("compute_s", 0.0)
        # the node's own on-path intervals: compute, plus the streamed-tail
        # wait when present. The wait sits AFTER compute in the simulator
        # (the closed-form pipelined tail) and BEFORE it on the engine
        # (drain-then-run), so emit latest-ending first — emit() clips to
        # the cursor either way, keeping the tiling exact.
        ivals = [(compute_t0, compute_t0 + compute_s, "compute")]
        sw0, sw1 = a.get("stream_wait_t0"), a.get("stream_wait_t1")
        if sw0 is not None and sw1 is not None and sw1 > sw0:
            ivals.append((sw0, sw1, "stream_wait"))
        for iv0, iv1, bucket in sorted(ivals, key=lambda iv: -iv[1]):
            emit(iv0, iv1, bucket, node=name)

        prepare_t1 = a.get("prepare_t1")
        payload_t = a.get("payload_t") or {}
        last_arrival = max(payload_t.values()) if payload_t else None

        prepare_bound = prepare_t1 is not None and (
            last_arrival is None or prepare_t1 >= last_arrival - tol
        )
        if prepare_bound:
            fetch_s = a.get("fetch_s", 0.0)
            emit(prepare_t1 - fetch_s, prepare_t1, "fetch", node=name)
            prepare_t0 = a.get("prepare_t0", prepare_t1 - fetch_s)
            cold_s = a.get("cold_s", 0.0)
            emit(prepare_t0, prepare_t0 + cold_s, "cold", node=name)
            poke_t = a.get("poke_t")
            if poke_t is not None and abs(prepare_t0 - poke_t) <= max(tol, _POKE_TOL):
                # prepare opened at the poke: everything earlier is the
                # poke fan-out — terminal.
                if cursor > t0:
                    segments.append(Segment(t0, cursor, "poke_slack", node=name))
                    cursor = t0
                break
            # prepare opened at fire time (engine baseline semantics):
            # the window itself was gated by the payload — fall through.
            if last_arrival is None:
                if cursor > t0:
                    segments.append(Segment(t0, cursor, "poke_slack", node=name))
                    cursor = t0
                break

        if not payload_t:  # no prepare window and no arrivals: bare source
            if cursor > t0:
                segments.append(Segment(t0, cursor, "poke_slack", node=name))
                cursor = t0
            break

        # payload-bound (or prepare window gated by payload): charge the
        # binding edge's transfer and continue into that predecessor.
        u_star = max(payload_t, key=payload_t.get)
        arrival = payload_t[u_star]
        transfer = (a.get("transfer_s") or {}).get(u_star, 0.0)
        emit(arrival - transfer, arrival, "transfer", node=name, edge=(u_star, name))
        nxt = nodes.get(u_star)
        if nxt is None or nxt.t_end is None:
            if cursor > t0:
                segments.append(Segment(t0, cursor, "poke_slack", node=name))
                cursor = t0
            break
        span = nxt

    if cursor > t0:  # safety: always tile down to t0
        segments.append(Segment(t0, cursor, "poke_slack"))

    segments.sort(key=lambda s: s.t0)
    path_nodes.reverse()
    return CriticalPath(trace.trace_id, path_nodes, segments)
