"""SLOs and multi-window burn-rate alerting over the obs telemetry.

A latency SLO is a statement like "95% of document-workflow requests
finish under 3 s". The 5% allowance is the *error budget*; the *burn
rate* is how fast observed violations consume it: ``burn = bad_fraction /
error_budget``, so burn 1.0 spends the budget exactly on schedule and
burn 10 exhausts it ten times too fast. Alerting on the burn rate over
TWO windows at once — a short one and a long one — is the standard SRE
construction: the long window proves the breach is sustained (no paging
on one slow request), the short window proves it is *still happening*
(the alert clears as soon as the system recovers, without waiting for
the long window to drain).

``SloTracker`` implements exactly that on the epoch-ring machinery from
``metrics``: per-window exact good/bad counters (not histograms — a
burn rate needs counts, not quantiles), edge-triggered transitions, and
``slo.burn`` / ``slo.ok`` events recorded through ``tracer.record_event``
— the same control-plane ring that carries ``recompose.decision``, so an
exported event log shows cause (burn) and effect (the ``trigger="slo"``
re-placement decision) side by side. ``RecompositionController`` watches
``alerts`` and forces a scored re-placement once per breach episode.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class SloSpec:
    """A per-workflow latency objective plus its alerting policy.

    ``target`` is the fraction of requests that must finish under
    ``objective_s`` (0.95 → 5% error budget). ``burn_threshold`` is the
    burn rate BOTH windows must exceed to alert; with the classic page
    thresholds (14.4 over 5m/1h) an all-bad outage pages in minutes while
    burn-1.0 noise never does. ``min_count`` keeps a near-empty fast
    window from alerting off two unlucky requests.
    """

    name: str
    objective_s: float
    target: float = 0.95
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    burn_threshold: float = 6.0
    min_count: int = 8

    def __post_init__(self):
        if not (0.0 < self.target < 1.0):
            raise ValueError("target must be in (0, 1)")
        if self.objective_s <= 0:
            raise ValueError("objective_s must be positive")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError("fast window must not exceed the slow window")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.target


class _WindowCounter:
    """Exact good/bad counts over a trailing window — the epoch ring from
    ``WindowedHistogram``, reduced to two ints per epoch."""

    __slots__ = ("epochs", "epoch_s", "_bad", "_n", "_ids", "_cur")

    def __init__(self, window_s: float, epochs: int = 12):
        self.epochs = int(epochs)
        self.epoch_s = float(window_s) / self.epochs
        self._bad = [0] * self.epochs
        self._n = [0] * self.epochs
        self._ids = [None] * self.epochs
        self._cur: Optional[int] = None

    def observe(self, bad: bool, now: float):
        e = int(math.floor(now / self.epoch_s))
        if self._cur is None or e > self._cur:
            steps = (
                self.epochs if self._cur is None else min(e - self._cur, self.epochs)
            )
            for eid in range(e - steps + 1, e + 1):
                slot = eid % self.epochs
                self._bad[slot] = 0
                self._n[slot] = 0
                self._ids[slot] = eid
            self._cur = e
        slot = self._cur % self.epochs  # late observations absorb into current
        self._n[slot] += 1
        if bad:
            self._bad[slot] += 1

    def counts(self, now: Optional[float] = None) -> tuple:
        """(bad, total) over the live window ending at ``now``."""
        if self._cur is None:
            return (0, 0)
        e = self._cur if now is None else int(math.floor(now / self.epoch_s))
        lo = e - self.epochs
        bad = n = 0
        for slot, eid in enumerate(self._ids):
            if eid is not None and lo < eid <= e:
                bad += self._bad[slot]
                n += self._n[slot]
        return (bad, n)


class SloTracker:
    """Multi-window burn-rate evaluation of one :class:`SloSpec`.

    Feed every request's end-to-end latency through ``record(latency_s,
    now)``; the tracker maintains fast- and slow-window violation counts
    and evaluates the alert condition on each observation. Transitions
    are edge-triggered: entering the burning state bumps ``alerts`` ONCE
    per breach episode and emits one ``slo.burn`` event (with both burn
    rates in the attrs); recovery emits ``slo.ok``. Consumers that act on
    breaches — ``RecompositionController`` — latch on the ``alerts``
    counter rather than the level, so a sustained breach triggers one
    re-placement, not one per request.

    The clock is the caller's (engine ``perf_counter`` or sim seconds),
    same contract as ``WindowedHistogram``. Thread-safe; events are
    emitted outside the lock.
    """

    def __init__(self, spec: SloSpec, tracer=None, epochs: int = 12):
        self.spec = spec
        self.tracer = tracer
        self._lock = threading.Lock()
        self._fast = _WindowCounter(spec.fast_window_s, epochs)
        self._slow = _WindowCounter(spec.slow_window_s, epochs)
        self.burning = False
        self.alerts = 0
        self.stats = {"observed": 0, "violations": 0, "alerts": 0, "recoveries": 0}

    def _rates_locked(self, now: Optional[float]) -> tuple:
        """((fast_burn, fast_n), (slow_burn, slow_n)) at ``now``."""
        budget = self.spec.error_budget
        out = []
        for win in (self._fast, self._slow):
            bad, n = win.counts(now)
            frac = bad / n if n else 0.0
            out.append((frac / budget, n))
        return tuple(out)

    def record(self, latency_s: float, now: float) -> bool:
        """Observe one request; returns the (possibly new) burning state."""
        bad = latency_s > self.spec.objective_s
        event = None
        with self._lock:
            self.stats["observed"] += 1
            if bad:
                self.stats["violations"] += 1
            self._fast.observe(bad, now)
            self._slow.observe(bad, now)
            (fast_burn, fast_n), (slow_burn, _) = self._rates_locked(now)
            breach = (
                fast_n >= self.spec.min_count
                and fast_burn >= self.spec.burn_threshold
                and slow_burn >= self.spec.burn_threshold
            )
            if breach and not self.burning:
                self.burning = True
                self.alerts += 1
                self.stats["alerts"] += 1
                event = "slo.burn"
            elif not breach and self.burning:
                self.burning = False
                self.stats["recoveries"] += 1
                event = "slo.ok"
            burning = self.burning
        if event is not None and self.tracer is not None:
            self.tracer.record_event(
                event,
                {
                    "slo": self.spec.name,
                    "objective_s": self.spec.objective_s,
                    "target": self.spec.target,
                    "fast_burn": round(fast_burn, 3),
                    "slow_burn": round(slow_burn, 3),
                    "threshold": self.spec.burn_threshold,
                    "now": now,
                },
            )
        return burning

    def burn_rates(self, now: Optional[float] = None) -> tuple:
        """(fast_burn, slow_burn) at ``now`` (default: last observation)."""
        with self._lock:
            (fast_burn, _), (slow_burn, _) = self._rates_locked(now)
        return (fast_burn, slow_burn)

    def snapshot(self, now: Optional[float] = None) -> dict:
        with self._lock:
            (fast_burn, fast_n), (slow_burn, slow_n) = self._rates_locked(now)
            return {
                "slo": self.spec.name,
                "objective_s": self.spec.objective_s,
                "target": self.spec.target,
                "burning": self.burning,
                "fast_burn": fast_burn,
                "slow_burn": slow_burn,
                "fast_n": fast_n,
                "slow_n": slow_n,
                **self.stats,
            }
