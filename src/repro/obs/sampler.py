"""Tail-based trace sampling: keep the traces worth debugging.

The Tracer's ring buffer is bounded (512 traces), but indiscriminate: a
burst of healthy requests evicts the one slow trace you needed. Tail
sampling inverts the retention policy — decide AFTER the request ends,
when its latency is known, and keep full span trees only for requests
that are (a) slow against the *windowed* p95 (sampling must adapt when
the baseline shifts — after a cutover, "slow" means slow *now*), (b) SLO
violations, or (c) a deterministic 1-in-N head-sampled baseline so the
healthy shape stays observable. Everything else keeps its aggregate
contribution — the metrics fold in ``Tracer.finish`` happens regardless
of the retention verdict, so histograms stay unbiased — and drops the
span tree.

``TailSampler`` is consulted by ``Tracer.finish`` when installed
(``Tracer(sampler=...)``); ``seen/kept/evicted`` counters (exact:
``kept + evicted == seen``) surface through ``DagDeployment.report()``
under ``trace_sampler``. The latency threshold is computed from the
window *before* folding the deciding request in, so one request never
raises the bar it is judged against.
"""

from __future__ import annotations

import threading
from typing import Optional

from .metrics import WindowedHistogram


class TailSampler:
    """Retention policy over finished traces, bounded-memory by design.

    ``decide(total_s, now)`` returns ``(keep, reason)`` with reason one of
    ``"slow"`` (at or above the windowed ``quantile`` threshold, scaled by
    ``margin``), ``"slo"`` (above the attached :class:`SloSpec` objective),
    or ``"head"`` (deterministic 1-in-``head_every`` baseline). The slow
    test arms only once the window holds ``min_count`` observations — a
    cold window keeps head samples, not everything.

    State is one :class:`WindowedHistogram` plus six counters; the clock
    contract is the caller's, same as the rest of ``repro.obs``.
    """

    def __init__(
        self,
        window_s: float = 300.0,
        epochs: int = 10,
        quantile: float = 0.95,
        margin: float = 1.0,
        head_every: int = 64,
        slo=None,
        min_count: int = 32,
    ):
        if not (0.0 < quantile < 1.0):
            raise ValueError("quantile must be in (0, 1)")
        self.quantile = quantile
        self.margin = margin
        self.head_every = head_every
        self.slo = slo  # an SloSpec (or anything with .objective_s), optional
        self.min_count = min_count
        self._lock = threading.Lock()
        self._hist = WindowedHistogram(window_s, epochs)
        self.stats = {
            "seen": 0,
            "kept": 0,
            "evicted": 0,
            "kept_slow": 0,
            "kept_slo": 0,
            "kept_head": 0,
        }

    def threshold(self, now: Optional[float] = None) -> float:
        """The current slow-trace latency bar (0.0 while the window is
        still below ``min_count``)."""
        with self._lock:
            w = self._hist.window(now)
            if w.count < self.min_count:
                return 0.0
            return self.margin * w.quantile(self.quantile)

    def decide(self, total_s: float, now: float) -> tuple:
        """Judge one finished request and fold it into the window."""
        with self._lock:
            self.stats["seen"] += 1
            head = (
                self.head_every > 0
                and (self.stats["seen"] - 1) % self.head_every == 0
            )
            w = self._hist.window(now)
            slow = (
                w.count >= self.min_count
                and total_s >= self.margin * w.quantile(self.quantile)
            )
            # Threshold was computed on the PRIOR window; fold afterwards so
            # a request never raises the bar it is judged against.
            self._hist.observe(total_s, now)
            violating = self.slo is not None and total_s > self.slo.objective_s
            if slow:
                reason = "slow"
            elif violating:
                reason = "slo"
            elif head:
                reason = "head"
            else:
                self.stats["evicted"] += 1
                return (False, None)
            self.stats["kept"] += 1
            self.stats[f"kept_{reason}"] += 1
            return (True, reason)

    def snapshot(self, now: Optional[float] = None) -> dict:
        thr = self.threshold(now)
        with self._lock:
            out = dict(self.stats)
        out["threshold_s"] = thr
        return out
