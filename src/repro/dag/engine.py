"""Dataflow executor for DAG workflows — THE execution core of this repo.

Chain workflows (``repro.core.choreographer.Deployment``) are a thin facade
over this engine: a chain is the degenerate DAG, lifted per request via
``DagSpec.from_chain``. There is exactly one implementation of the GeoFF
two-phase protocol, generalized to a DAG over the shared pieces
(CompileCache, Prefetcher, ObjectStore, PokeTimingController, per-platform
executors):

  - pokes cascade along EDGES: poking a node immediately pokes all of its
    successors, so a fan-out warms and pre-fetches every branch at once
    (poking is deduplicated per request — a diamond's join is poked once);
  - each node FIRES the moment its last predecessor payload lands
    (dataflow firing rule). Per-predecessor payloads are buffered — through
    the object store on platforms that disallow direct function-to-function
    traffic (one ``__payload__`` key per edge, deleted after the GET so
    fan-in buffers never leak) and in memory on sync platforms;
  - independent branches run concurrently on their platforms' executors:
    the latency win over the chain serialization is real wall-clock
    parallelism plus the usual pre-fetch overlap;
  - poke timing is learned PER EDGE: payload arrival is timestamped per
    predecessor, so a fan-in node feeds a distinct slack observation to the
    ``PokeTimingController`` for each in-edge (§5.5, generalized).

Handlers keep the chain signature ``handler(payload, data)``. A fan-in node
receives ``{pred_name: payload}``; source nodes receive the client payload;
everything else receives its single predecessor's output unwrapped — so
functions written for chains deploy onto DAGs without change.
"""

from __future__ import annotations

import concurrent.futures
import threading
import time
import uuid
from contextlib import nullcontext
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.faults import FaultSchedule, InjectedFault, RetryPolicy
from repro.core.platform import Platform, PlatformRegistry, PlatformWrapper
from repro.core.prefetch import Prefetcher
from repro.core.prewarm import CompileCache
from repro.core.store import ObjectStore, StreamConfig, _sizeof
from repro.core.timing import PokeTimingController
from repro.dag.spec import DagSpec


@dataclass
class DeployedFn:
    """One (handler, wrapper, middleware) package on one platform (§3.1)."""

    name: str
    platform: Platform
    wrapper: PlatformWrapper
    handler: Callable  # handler(payload, data: dict) -> out
    abstract_args: Optional[object] = None  # for pre-warm (compile) keys
    compile_fn: Optional[Callable] = None  # jit-able step body (optional)


@dataclass
class DagResult:
    request_id: str
    outputs: object  # sink output; {sink_name: output} when several sinks
    timeline: dict  # node -> {phase: seconds}
    total_s: float
    # "ok" | "timeout" — a timed-out request returns a structured record
    # (cascade cancelled, edge buffers cleaned) instead of a bare raise
    status: str = "ok"
    error: Optional[str] = None


class FaultInjector:
    """Engine-side twin of the simulator's fault plane: evaluates the same
    counter-hash (``FaultSchedule.attempt_outcome``) inside ``_run_node``
    and raises ``InjectedFault`` where the simulator would have priced a
    failed attempt — so a schedule replayed on the real engine fails the
    exact (step, platform, request, attempt) cells the sim predicted."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule

    def check(
        self, step: str, platform: str, region: str, request_k: int, attempt: int
    ):
        kind = self.schedule.attempt_outcome(
            step, platform, request_k, attempt, region=region
        )
        if kind is not None:
            raise InjectedFault(kind, step, platform, request_k, attempt)


class _RunState:
    """All per-request mutable state (one instance per ``run``)."""

    def __init__(self, spec: DagSpec, payload):
        self.spec = spec
        self.payload = payload
        self.rid = uuid.uuid4().hex[:12]
        self.lock = threading.Lock()
        self.poke_seen: set = set()  # nodes whose poke already ran (dedup)
        self.poked: dict = {}  # node -> (warm_fut, fetch_futs, t0, delay)
        self.buffers: dict = {n.name: {} for n in spec.steps}  # fan-in joins
        self.arrivals: dict = {n.name: {} for n in spec.steps}  # edge stamps
        # streaming: predecessors whose FIRST chunk has landed (fires the
        # node early) and the event set when the FULL payload set is in
        self.first_seen: dict = {n.name: set() for n in spec.steps}
        self.payload_done: dict = {n.name: threading.Event() for n in spec.steps}
        self.fired: set = set()
        self.timeline: dict = {}
        self.outputs: dict = {}
        self.pending_sinks = set(spec.sinks())
        self.error: Optional[BaseException] = None
        self.done = threading.Event()
        self.t0 = 0.0  # request clock zero (perf_counter, set by run)
        self.req_index = 0  # deployment-wide request counter (fault keying)
        self.trace = None  # obs.Trace when the deployment has a tracer
        self.poke_t: dict = {}  # node -> absolute poke time
        self.transfer_s: dict = {n.name: {} for n in spec.steps}  # dst->{src: s}

    def fail(self, exc: BaseException):
        with self.lock:
            if self.error is None:
                self.error = exc
        self.done.set()


class DagDeployment:
    """Deployer + client entry point for DAG workflows.

    Same deployment surface as the chain ``Deployment`` — one
    platform-independent handler deployed to N platforms — but ``run``
    takes a ``DagSpec`` and drives the dataflow schedule. Usable as a
    context manager; ``shutdown`` is idempotent, so thread pools never
    leak across runs even when both paths trigger.
    """

    def __init__(
        self,
        registry: Optional[PlatformRegistry] = None,
        store: Optional[ObjectStore] = None,
        timing_mode: str = "eager",
        telemetry=None,
        tracer=None,
        stream: Optional[StreamConfig] = None,
        payload_region: Optional[str] = None,
        faults=None,
        retry: Optional[RetryPolicy] = None,
    ):
        self.registry = registry or PlatformRegistry()
        self.store = store or ObjectStore(self.registry.network)
        self.cache = CompileCache()
        # chunked data plane: None keeps every path exactly as before;
        # chunks > 1 pipelines payload edges (successor fires on the first
        # chunk) and chunked-fetches data deps
        self.stream = stream
        # where buffered payloads are homed: None = the destination's own
        # region (the store GET is then intra-region); a staging region
        # makes both hops pay wire time — the setting under which the
        # streamed cut-through and the P2P bypass earn their keep
        self.payload_region = payload_region
        self.prefetcher = Prefetcher(self.store, stream=stream)
        self.timing = PokeTimingController(timing_mode)
        # durability: an injected-fault schedule (accepts a raw
        # FaultSchedule or a FaultInjector) and the per-step retry budget
        if faults is not None and not isinstance(faults, FaultInjector):
            faults = FaultInjector(faults)
        self.faults: Optional[FaultInjector] = faults
        self.retry = retry
        self._req_count = 0  # monotone request index (fault/backoff keying)
        # hedged duplicates run on per-platform side pools, NOT the
        # platform executors — a hedge must never occupy the slot its
        # primary needs (the thread name keeps the "plat-<name>" prefix so
        # handlers keyed off it behave identically on either lane)
        self._hedge_pools: dict = {}
        self._functions: dict = {}  # (name, platform) -> DeployedFn
        self._stats_lock = threading.Lock()
        self._shut = False
        self.stats = {
            "pokes": {},
            "joins": 0,
            "buffered_edges": 0,
            "streamed_edges": 0,  # edges moved chunk-by-chunk (cut-through)
            "p2p_edges": 0,  # edges that skipped the store entirely
            "retries": 0,  # re-attempts after a failed handler call
            "attempt_errors": 0,  # failed attempts (injected or real)
            "timeouts": 0,  # requests returned with status="timeout"
            "hedges": 0,  # duplicate executions launched for stragglers
            "hedge_wins": 0,  # hedges that beat their primary
            "hedge_cancelled": 0,  # losers cancelled before starting
        }
        # duck-typed TelemetryHub (repro.adapt): propagated to every piece
        # so one hub sees compute + warm/cold + fetch + transfer events
        self.telemetry = telemetry
        if telemetry is not None:
            self.cache.telemetry = telemetry
            self.prefetcher.telemetry = telemetry
            self.store.telemetry = telemetry
        # duck-typed obs.Tracer: same propagation, per-request span trees
        self.tracer = tracer
        if tracer is not None:
            self.cache.tracer = tracer
            self.prefetcher.tracer = tracer
            self.store.tracer = tracer

    # -- deployer --------------------------------------------------------------
    def deploy(
        self,
        name: str,
        handler: Callable,
        platforms,
        abstract_args=None,
        compile_fn=None,
    ):
        for pname in platforms:
            plat = self.registry.get(pname)
            wrapper = PlatformWrapper(plat, handler, name)
            self._functions[(name, pname)] = DeployedFn(
                name, plat, wrapper, handler, abstract_args, compile_fn
            )
        return self

    def _resolve(self, name: str, platform: str) -> DeployedFn:
        try:
            return self._functions[(name, platform)]
        except KeyError:
            raise KeyError(
                f"function {name!r} is not deployed on {platform!r}; "
                f"deployed: {sorted(self._functions)}"
            ) from None

    def _resolve_step(self, step) -> DeployedFn:
        """Resolve a spec node to its deployed function: ``step.fn`` names
        the function when the node name is disambiguated (a chain invoking
        the same function twice lifts to ``f@i`` nodes with ``fn='f'``)."""
        return self._resolve(getattr(step, "fn", "") or step.name, step.platform)

    # -- client ----------------------------------------------------------------
    def run(
        self, spec: DagSpec, payload, timeout_s: Optional[float] = 120.0
    ) -> DagResult:
        """Invoke the DAG: deliver the client payload to every source node
        and wait for all sinks (``timeout_s=None`` waits indefinitely).
        Raises whatever a node's handler raised. A TIMEOUT does not raise:
        it cancels the in-flight cascade (every phase entry checks
        ``state.error``), deletes any buffered ``__payload__`` edge keys,
        and returns a structured ``DagResult(status="timeout")`` — the
        caller gets a failed-request record, not a stranded request."""
        for s in spec.steps:  # fail fast on missing deployments
            self._resolve_step(s)
        state = _RunState(spec, payload)
        with self._stats_lock:
            state.req_index = self._req_count
            self._req_count += 1
        t0 = time.perf_counter()
        state.t0 = t0
        if self.tracer is not None:
            # trace_id == request_id: one root span per request, carried by
            # the state object through the whole poke/payload cascade
            state.trace = self.tracer.begin(
                name=f"request:{state.rid}", trace_id=state.rid, t0=t0
            )
        for source in spec.sources():
            self._deliver(state, None, source, payload)
        if not state.done.wait(timeout_s):
            # cancel the cascade: fail() sets the error every phase checks
            # at entry, so nothing new fires and pollers unwind
            state.fail(
                TimeoutError(
                    f"request {state.rid} timed out after {timeout_s}s; "
                    f"fired={sorted(state.fired)}"
                )
            )
            with self._stats_lock:
                self.stats["timeouts"] += 1
            self._cleanup_request(state)
            t_end = time.perf_counter()
            if state.trace is not None:
                state.trace.root.attrs["status"] = "timeout"
                state.trace.root.attrs["error"] = repr(state.error)
                self.tracer.finish(state.trace, t_end=t_end)
            return DagResult(
                state.rid,
                None,
                dict(state.timeline),
                t_end - t0,
                status="timeout",
                error=repr(state.error),
            )
        if state.error is not None:
            self._cleanup_request(state)
            if state.trace is not None:
                state.trace.root.attrs["error"] = repr(state.error)
                self.tracer.finish(state.trace)
            raise state.error
        outs = state.outputs
        outputs = outs[next(iter(outs))] if len(outs) == 1 else dict(outs)
        t_end = time.perf_counter()
        if state.trace is not None:
            self.tracer.finish(state.trace, t_end=t_end)
        return DagResult(state.rid, outputs, dict(state.timeline), t_end - t0)

    def _cleanup_request(self, state: _RunState):
        """Delete every edge buffer a failed/timed-out request left in the
        object store (``__payload__/<rid>/...`` keys are otherwise only
        deleted by the GET side that never ran)."""
        prefix = f"__payload__/{state.rid}/"
        for key in self.store.keys(prefix):
            self.store.delete(key)

    def report(self) -> dict:
        """ONE merged runtime-stats surface (locked snapshots throughout):
        engine counters, compile cache, prefetcher, object store, and the
        per-step/per-edge timing report — plus the telemetry snapshot when
        a hub is attached. This is also the surface ``repro.adapt`` taps."""
        with self._stats_lock:
            engine = {
                "pokes": dict(self.stats["pokes"]),
                "joins": self.stats["joins"],
                "buffered_edges": self.stats["buffered_edges"],
                "streamed_edges": self.stats["streamed_edges"],
                "p2p_edges": self.stats["p2p_edges"],
                "retries": self.stats["retries"],
                "attempt_errors": self.stats["attempt_errors"],
                "timeouts": self.stats["timeouts"],
                "hedges": self.stats["hedges"],
                "hedge_wins": self.stats["hedge_wins"],
                "hedge_cancelled": self.stats["hedge_cancelled"],
            }
        out = {
            "engine": engine,
            "compile": self.cache.stats_snapshot(),
            "prefetch": self.prefetcher.stats_snapshot(),
            "store": self.store.stats_snapshot(),
            "timing": self.timing.report(),
        }
        if self.telemetry is not None:
            out["telemetry"] = self.telemetry.snapshot()
        metrics = getattr(self.tracer, "metrics", None)
        if metrics is not None:
            out["metrics"] = metrics.snapshot()
        sampler = getattr(self.tracer, "sampler", None)
        if sampler is not None:
            # tail-sampling accounting: kept/evicted/seen (exact) and the
            # current slow-trace threshold — retention must be auditable
            out["trace_sampler"] = sampler.snapshot()
        return out

    def shutdown(self):
        if self._shut:
            return
        self._shut = True
        self.registry.shutdown()
        self.cache.shutdown()
        self.prefetcher.shutdown()
        with self._stats_lock:
            pools = list(self._hedge_pools.values())
            self._hedge_pools.clear()
        for pool in pools:
            pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- phase 1: poke (cascades along edges) ----------------------------------
    def _poke(self, state: _RunState, node: str, delay_applied: float = 0.0):
        if state.error is not None:  # request cancelled (timeout/failure)
            return
        try:
            with state.lock:
                if node in state.poke_seen or node in state.fired:
                    return
                state.poke_seen.add(node)
            t0 = time.perf_counter()
            step = state.spec.node(node)
            fn = self._resolve_step(step)
            with state.lock:
                state.poke_t[node] = t0
            poke_span = None
            if state.trace is not None:
                poke_span = state.trace.span(
                    f"poke:{node}",
                    "poke",
                    t_start=t0,
                    attrs={
                        "node": node,
                        "platform": step.platform,
                        "delay_applied_s": delay_applied,
                    },
                )
            ctx = (
                self.tracer.bind(poke_span)
                if self.tracer is not None and poke_span is not None
                else nullcontext()
            )
            with ctx:
                warm_fut = None
                if fn.compile_fn is not None and fn.abstract_args is not None:
                    warm_fut = self.cache.warm(
                        fn.name, fn.platform.name, fn.compile_fn, fn.abstract_args
                    )
                fetch_futs = {}
                if step.data_deps:
                    fetch_futs = self.prefetcher.start(
                        step.data_deps, fn.platform.region
                    )
            if poke_span is not None:
                poke_span.end()
            with state.lock:
                state.poked[node] = (warm_fut, fetch_futs, t0, delay_applied)
            with self._stats_lock:
                self.stats["pokes"][node] = self.stats["pokes"].get(node, 0) + 1
            # cascade: a fan-out pokes ALL successors at once, each edge
            # shifted by its learned delay (eager mode: 0) — matching the
            # simulator's poke[v] = min over u of poke[u] + msg + delay(u,v)
            for succ in state.spec.successors(node):
                if not state.spec.node(succ).prefetch:
                    continue
                delay = self.timing.poke_delay(step.name, succ)

                def cascade(succ=succ, delay=delay):
                    if delay > 0:
                        time.sleep(delay)
                    self._poke(state, succ, delay_applied=delay)

                self.registry.executor(step.platform).submit(cascade)
        except BaseException as exc:  # surface poke-path bugs to the client
            state.fail(exc)

    # -- phase 2: payload (dataflow firing) ------------------------------------
    def _deliver(self, state: _RunState, pred: Optional[str], node: str, value):
        """Record one predecessor payload; fire when the LAST one lands.

        Streamed edges fire earlier — ``_deliver_first`` marks the edge on
        its first chunk — so by the time the full payload gets here the
        node is usually already preparing; this then just completes the
        buffer and releases ``payload_done``."""
        if state.error is not None:
            return
        n_preds = len(state.spec.predecessors(node))
        with state.lock:
            if pred is not None:
                state.buffers[node][pred] = value
                state.arrivals[node][pred] = time.perf_counter()
                state.first_seen[node].add(pred)
            full = len(state.buffers[node]) == n_preds
            fire = len(state.first_seen[node]) == n_preds and node not in state.fired
            if fire:
                state.fired.add(node)
        if full:
            state.payload_done[node].set()
        if fire:
            step = state.spec.node(node)
            self.registry.executor(step.platform).submit(self._fire, state, node)

    def _deliver_first(self, state: _RunState, pred: str, node: str):
        """A streamed edge's FIRST chunk landed: fire the node as soon as
        every in-edge has shown its first chunk, overlapping the node's
        prepare (warm + fetch) with the residual chunks still in flight."""
        if state.error is not None:
            return
        with state.lock:
            state.first_seen[node].add(pred)
            fire = (
                len(state.first_seen[node]) == len(state.spec.predecessors(node))
                and node not in state.fired
            )
            if fire:
                state.fired.add(node)
        if fire:
            step = state.spec.node(node)
            self.registry.executor(step.platform).submit(self._fire, state, node)

    def _fire(self, state: _RunState, node: str):
        if state.error is not None:
            return
        try:
            self._run_node(state, node)
        except BaseException as exc:
            state.fail(exc)

    def _transfer(self, state: _RunState, src: str, dst: str, value):
        """Move one edge payload, then deliver it to the join buffer."""
        if state.error is not None:
            return
        try:
            dst_plat = self.registry.get(state.spec.node(dst).platform)
            src_plat = self.registry.get(state.spec.node(src).platform)
            t0 = time.perf_counter()
            span = None
            if state.trace is not None:
                span = state.trace.span(
                    f"transfer:{src}->{dst}",
                    "transfer",
                    t_start=t0,
                    attrs={"src": src, "dst": dst, "platform": dst_plat.name},
                )
            ctx = (
                self.tracer.bind(span)
                if self.tracer is not None and span is not None
                else nullcontext()
            )
            with ctx:
                if not (dst_plat.allows_sync and dst_plat.native_prefetch):
                    nbytes = _sizeof(value)
                    home = self.payload_region or dst_plat.region
                    if self._p2p_eligible(src, dst, nbytes):
                        # direct P2P path: one src->dst hop, no store
                        p2p_dt = self.registry.network.transfer_s(
                            src_plat.region, dst_plat.region, nbytes
                        )
                        if self.store.enforce_latency:
                            time.sleep(p2p_dt)
                        if self.telemetry is not None:
                            self.telemetry.record_transfer(
                                src_plat.region, dst_plat.region, nbytes, p2p_dt
                            )
                        with self._stats_lock:
                            self.stats["p2p_edges"] += 1
                    elif self.stream is not None and self.stream.chunks > 1:
                        value = self._transfer_streamed(
                            state, src, dst, value, src_plat, dst_plat, home
                        )
                    else:
                        # public-cloud path: buffer through the object
                        # store, one key per edge; delete after the GET
                        # (no fan-in leak)
                        key = f"__payload__/{state.rid}/{src}->{dst}"
                        self.store.put(key, value, home, from_region=src_plat.region)
                        value, _ = self.store.get(key, dst_plat.region)
                        self.store.delete(key)
                        with self._stats_lock:
                            self.stats["buffered_edges"] += 1
                    if self.telemetry is not None:
                        self.telemetry.record_edge_bytes(src, dst, nbytes)
            dt = time.perf_counter() - t0
            if span is not None:
                span.end()
            with state.lock:
                state.transfer_s[dst][src] = dt
            self._deliver(state, src, dst, value)
        except BaseException as exc:
            state.fail(exc)

    def _p2p_eligible(self, src: str, dst: str, nbytes: int) -> bool:
        """Direct payload path decision: learned per edge from the
        TelemetryHub byte EWMA (so a normally-small edge with one outlier
        payload keeps its fast path), falling back to the live payload's
        actual size before any observation exists."""
        stream = self.stream
        if stream is None or stream.p2p_threshold_bytes <= 0:
            return False
        est = None
        if self.telemetry is not None:
            est = self.telemetry.edge_bytes(src, dst)
        size = est if est is not None else nbytes
        return size <= stream.p2p_threshold_bytes

    def _transfer_streamed(
        self, state: _RunState, src: str, dst: str, value, src_plat, dst_plat, home
    ):
        """Cut-through edge transfer: the payload moves as ``chunks`` wire
        pieces, PUT chunks pacing on the SOURCE platform's executor while
        this (destination-executor) thread drives the matching GET chunks
        one semaphore release behind — so the destination holds chunk i
        after it crossed BOTH hops, and the node fires on chunk 0 while
        the rest pipeline (``_deliver_first``)."""
        chunks = self.stream.chunks
        key = f"__payload__/{state.rid}/{src}->{dst}"
        sem = threading.Semaphore(0)
        errs: list = []
        put_iter = self.store.put_stream(
            key, value, home, from_region=src_plat.region, chunks=chunks
        )

        def producer():
            try:
                for _ in put_iter:
                    sem.release()
            except BaseException as exc:
                errs.append(exc)
                for _ in range(chunks):
                    sem.release()

        self.registry.executor(src_plat.name).submit(producer)
        get_iter = self.store.get_stream(key, dst_plat.region, chunks=chunks)
        out = None
        for i in range(chunks):
            # wait for wire chunk i to clear the first hop; poll so a
            # failed producer (or failed request) can't strand this thread
            while not sem.acquire(timeout=0.1):
                if errs:
                    raise errs[0]
                if state.error is not None:
                    raise state.error
            if errs:
                raise errs[0]
            v, _ = next(get_iter)
            if i == 0:
                self._deliver_first(state, src, dst)
            if v is not None:
                out = v
        self.store.delete(key)
        with self._stats_lock:
            self.stats["streamed_edges"] += 1
        return out

    def _invoke(self, state: _RunState, node, step, fn, payload, data, node_span):
        """Run the node's handler under the retry budget.

        Injected faults are checked BEFORE the handler (the fault model
        fails attempts, not half-executed handlers); real handler errors
        consume attempts the same way. Each retry waits out the policy's
        seeded backoff — the same ``RetryPolicy.backoff_s`` hash the
        simulator prices — and lands as a ``retry`` event on the node span.
        Exhausting the budget re-raises the last error; returns ``(out,
        attempts_used)``."""
        policy = self.retry
        max_attempts = policy.max_attempts if policy is not None else 1
        platform = fn.platform.name
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.check(
                        step.name, platform, fn.platform.region,
                        state.req_index, attempt,
                    )
                return self._call_handler(fn, payload, data), attempt + 1
            except BaseException as exc:
                if state.error is not None:
                    raise  # request already cancelled: don't burn budget
                with self._stats_lock:
                    self.stats["attempt_errors"] += 1
                if self.telemetry is not None:
                    self.telemetry.record_error(step.name, platform)
                attempt += 1
                if attempt >= max_attempts:
                    raise
                backoff = policy.backoff_s(
                    attempt - 1, step.name, platform, state.req_index
                )
                with self._stats_lock:
                    self.stats["retries"] += 1
                if node_span is not None:
                    node_span.add_event(
                        "retry",
                        {
                            "attempt": attempt,
                            "node": node,
                            "platform": platform,
                            "error": repr(exc),
                            "backoff_s": backoff,
                            "injected": isinstance(exc, InjectedFault),
                        },
                    )
                if backoff > 0:
                    time.sleep(backoff)

    def _hedge_pool(self, platform: str) -> concurrent.futures.ThreadPoolExecutor:
        with self._stats_lock:
            pool = self._hedge_pools.get(platform)
            if pool is None:
                pool = self._hedge_pools[platform] = (
                    concurrent.futures.ThreadPoolExecutor(
                        max_workers=8,
                        thread_name_prefix=f"plat-{platform}-hedge",
                    )
                )
            return pool

    def _call_handler(self, fn, payload, data):
        """One handler attempt, hedged when the policy asks for it: if the
        primary has not returned after ``hedge_after_s`` a duplicate is
        launched on the platform's side pool; the first finisher wins and
        the loser is cancelled (counted either way). Without a hedge
        deadline this is exactly the old direct call."""
        policy = self.retry
        hedge_after = policy.hedge_after_s if policy is not None else None
        if hedge_after is None:
            return fn.wrapper(payload, data)
        pool = self._hedge_pool(fn.platform.name)
        primary = pool.submit(fn.wrapper, payload, data)
        try:
            return primary.result(timeout=hedge_after)
        except concurrent.futures.TimeoutError:
            pass
        with self._stats_lock:
            self.stats["hedges"] += 1
        backup = pool.submit(fn.wrapper, payload, data)
        done, _ = concurrent.futures.wait(
            {primary, backup}, return_when=concurrent.futures.FIRST_COMPLETED
        )
        winner = primary if primary in done else backup
        loser = backup if winner is primary else primary
        if loser.cancel():
            with self._stats_lock:
                self.stats["hedge_cancelled"] += 1
        if winner is backup:
            with self._stats_lock:
                self.stats["hedge_wins"] += 1
        return winner.result()

    def _run_node(self, state: _RunState, node: str):
        spec = state.spec
        step = spec.node(node)
        fn = self._resolve_step(step)
        preds = spec.predecessors(node)
        timeline = {}
        t_fire = time.perf_counter()
        node_span = None
        if state.trace is not None:
            with state.lock:
                poke_t = state.poke_t.get(node)
            node_span = state.trace.span(
                node,
                "node",
                t_start=t_fire,
                attrs={
                    "node": node,
                    "platform": step.platform,
                    "preds": list(preds),
                    "poke_t": poke_t,
                },
            )

        # poke successors NOW (as early as possible; the learned controller
        # may delay, per edge). The cascade usually got there first — _poke
        # dedups.
        for succ in spec.successors(node):
            if not spec.node(succ).prefetch:
                continue
            delay = self.timing.poke_delay(step.name, succ)

            def do_poke(succ=succ, delay=delay):
                if delay > 0:
                    time.sleep(delay)
                self._poke(state, succ, delay_applied=delay)

            self.registry.executor(step.platform).submit(do_poke)

        # cold start (compile) — hidden iff this node was poked. The warm
        # and fetch windows here are the EXPOSED waits: the background work
        # started at the poke, so joining it measures exactly what the
        # critical path saw.
        prepare_t0 = time.perf_counter()
        t0 = prepare_t0
        with state.lock:
            poked = state.poked.pop(node, None)
        warm_span = None
        if node_span is not None:
            warm_span = state.trace.span(
                f"warm:{node}",
                "warm",
                parent=node_span,
                t_start=t0,
                attrs={"node": node, "platform": step.platform},
            )
        ctx = (
            self.tracer.bind(warm_span)
            if self.tracer is not None and warm_span is not None
            else nullcontext()
        )
        with ctx:
            if fn.compile_fn is not None and fn.abstract_args is not None:
                self.cache.get(
                    fn.name, fn.platform.name, fn.compile_fn, fn.abstract_args
                )
        timeline["warm_s"] = time.perf_counter() - t0
        if warm_span is not None:
            warm_span.end()

        # data deps: join prefetch futures, or fetch cold
        t0 = time.perf_counter()
        fetch_span = None
        if node_span is not None:
            fetch_span = state.trace.span(
                f"fetch:{node}",
                "fetch",
                parent=node_span,
                t_start=t0,
                attrs={"node": node, "platform": step.platform},
            )
        ctx = (
            self.tracer.bind(fetch_span)
            if self.tracer is not None and fetch_span is not None
            else nullcontext()
        )
        with ctx:
            if poked is not None and poked[1]:
                data, exposed, modeled = self.prefetcher.join(poked[1])
                # per-edge slack: each predecessor's payload arrival stamp vs
                # this node's prepare, shifted back by the applied poke delay
                # so the controller sees the gap relative to the undelayed
                # poke
                now = time.perf_counter()
                with state.lock:
                    arrivals = dict(state.arrivals.get(node, {}))
                for u in preds:
                    self.timing.record_slack(
                        u,
                        node,
                        (arrivals.get(u, now) - poked[2]) - modeled + poked[3],
                    )
            elif step.data_deps:
                data, _ = self.prefetcher.fetch_blocking(
                    step.data_deps, fn.platform.region
                )
            else:
                data = {}
        prepare_t1 = time.perf_counter()
        timeline["fetch_s"] = prepare_t1 - t0
        if fetch_span is not None:
            fetch_span.end(prepare_t1)
        self.timing.record_prepare(step.name, timeline["warm_s"] + timeline["fetch_s"])

        # streamed edges fire this node on FIRST chunks, so the prepare
        # above overlapped the residual chunks; whatever tail is still in
        # flight is waited out here and surfaced as its own bucket
        t_wait0 = t_wait1 = None
        if self.stream is not None:
            t_wait0 = time.perf_counter()
            while not state.payload_done[node].wait(0.05):
                if state.error is not None:
                    return
            t_wait1 = time.perf_counter()
            timeline["stream_wait_s"] = t_wait1 - t_wait0

        # assemble the input: client payload / unwrapped single pred /
        # fan-in dict keyed by predecessor name
        with state.lock:
            buf = state.buffers.pop(node, {})
            payload_t = state.arrivals.pop(node, {})
            edge_transfer = dict(state.transfer_s.get(node, {}))
            poke_ref = state.poke_t.get(node)
        # per-edge poke-to-payload wait: how long after this node's poke
        # (request start when never poked) each predecessor payload landed
        wait_ref = poke_ref if poke_ref is not None else state.t0
        timeline["payload_wait_s"] = {
            u: payload_t[u] - wait_ref for u in preds if u in payload_t
        }
        timeline["transfer_s"] = edge_transfer
        if not preds:
            payload = state.payload
        elif len(preds) == 1:
            payload = buf[preds[0]]
        else:
            payload = {p: buf[p] for p in preds}
            with self._stats_lock:
                self.stats["joins"] += 1

        # handler
        t0 = time.perf_counter()
        compute_span = None
        if node_span is not None:
            compute_span = state.trace.span(
                f"compute:{node}",
                "compute",
                parent=node_span,
                t_start=t0,
                attrs={"node": node, "platform": step.platform},
            )
        out, attempts = self._invoke(state, node, step, fn, payload, data, node_span)
        t1 = time.perf_counter()
        dt = t1 - t0
        timeline["compute_s"] = dt
        if self.retry is not None or self.faults is not None:
            timeline["attempts"] = attempts
        if compute_span is not None:
            compute_span.end(t1)
        if node_span is not None:
            node_span.attrs.update(
                {
                    "prepare_t0": prepare_t0,
                    "prepare_t1": prepare_t1,
                    "cold_s": timeline["warm_s"],
                    "fetch_s": timeline["fetch_s"],
                    "compute_t0": t0,
                    "compute_s": dt,
                    "payload_t": dict(payload_t),
                    "transfer_s": dict(edge_transfer),
                }
            )
            if t_wait0 is not None:
                node_span.attrs["stream_wait_t0"] = t_wait0
                node_span.attrs["stream_wait_t1"] = t_wait1
            node_span.end(t1)
        self.timing.record_compute(step.name, dt)
        if self.telemetry is not None:
            self.telemetry.record_compute(step.name, fn.platform.name, dt)
        with state.lock:
            state.timeline[node] = timeline

        # hand off along every out-edge (concurrently: each transfer runs
        # on the DESTINATION platform's executor so branches stay parallel)
        succs = spec.successors(node)
        if not succs:
            with state.lock:
                state.outputs[node] = out
                state.pending_sinks.discard(node)
                finished = not state.pending_sinks
            if finished:
                state.done.set()
            return
        for succ in succs:
            self.registry.executor(spec.node(succ).platform).submit(
                self._transfer, state, node, succ, out
            )
