"""DAG simulation facade — the recurrence lives in the unified simulator.

``repro.core.simulator.WorkflowSimulator`` executes one dataflow recurrence
for chains and DAGs (``payload[v] = max over preds of end[u] + transfer``),
mirroring the runtime where the chain deployer is a facade over the
dataflow engine. This module keeps the historical DAG-side names importable
(``DagWorkflowSimulator`` IS the unified simulator) and hosts the
calibrated DAG shapes used by the chain-vs-DAG experiments.
"""

from __future__ import annotations

from repro.core.simulator import (  # noqa: F401
    DagTrace,
    Dist,
    SimStep,
    WorkflowSimulator,
    serialize_chain,
)

# A degenerate subclass kept for its established name: every capability —
# run_request AND run_dag_request — already lives on the unified simulator.
DagWorkflowSimulator = WorkflowSimulator


# ---------------------------------------------------------------------------
# calibrated DAG shapes (the paper's §4.2 workflow, restructured)
# ---------------------------------------------------------------------------
def document_dag_fig4():
    """The Fig-4 document workflow as a real fan-out: after ``check``, the
    virus scan and the OCR don't depend on each other — run them in
    parallel and join at ``e_mail``. Same calibrated distributions as
    ``simulator.document_workflow_fig4`` so the chain serialization of
    these steps IS the paper's chain."""
    steps = [
        SimStep("check", "tinyfaas-edge", compute=Dist(0.22)),
        SimStep("virus", "gcf", compute=Dist(0.30), fetch=Dist(0.32)),
        SimStep("ocr", "lambda-us-east-1", compute=Dist(0.45), fetch=Dist(1.45)),
        SimStep("e_mail", "lambda-us-east-1", compute=Dist(0.20), fetch=Dist(0.85)),
    ]
    edges = [
        ("check", "virus"),
        ("check", "ocr"),
        ("virus", "e_mail"),
        ("ocr", "e_mail"),
    ]
    return steps, edges
