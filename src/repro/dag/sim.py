"""Discrete-event model for DAG workflows (the dataflow recurrence).

Extends the chain simulator (core/simulator.py) with the DAG timeline. Per
request, with ``u`` ranging over the predecessors of node ``v``:

    poke[v]      = min over u of poke[u] + msg_latency     (cascade;
                   sources are poked at t0, like the chain's step 0)
    prepare[v]   = poke[v] + cold_v + fetch_v              (prefetch on)
    payload[v]   = max over u of end[u] + transfer(u -> v) (fan-in join)
    start[v]     = max(payload[v], prepare[v])             (prefetch on)
                 = payload[v] + cold_v + fetch_v           (baseline)
    end[v]       = start[v] + compute_v
    total        = max over sinks of end[sink] - t0

The same calibrated latency distributions as the chain experiments apply,
so chain-vs-DAG comparisons isolate the scheduling effect: a fan-out's
branches overlap (the max replaces the chain's sum) and pre-fetch hides
each branch's cold start + fetch exactly as in the linear recurrence. For
a degenerate DAG (``DagSpec.from_chain`` shapes) the recurrence — and the
sampled trace, draw for draw — reduces to the chain one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.simulator import Dist, SimStep, WorkflowSimulator


@dataclass
class DagTrace:
    total_s: float
    start: dict
    end: dict
    prepare: dict
    payload: dict
    double_billed_s: float
    exposed_fetch_s: float


def _graph(steps, edges):
    names = [s.name for s in steps]
    pred = {n: [] for n in names}
    succ = {n: [] for n in names}
    for a, b in edges:
        succ[a].append(b)
        pred[b].append(a)
    pos = {n: i for i, n in enumerate(names)}
    indeg = {n: len(pred[n]) for n in names}
    order = []
    ready = sorted((n for n in names if indeg[n] == 0), key=pos.get)
    while ready:
        u = ready.pop(0)
        order.append(u)
        for v in succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
        ready.sort(key=pos.get)
    if len(order) != len(names):
        raise ValueError("workflow graph has a cycle")
    return pred, succ, order


def serialize_chain(steps, edges):
    """The chain serialization of a DAG: its steps in topological order,
    executed as a linear workflow (the baseline a DAG schedule beats)."""
    _, _, order = _graph(steps, edges)
    by_name = {s.name: s for s in steps}
    return [by_name[n] for n in order]


class DagWorkflowSimulator(WorkflowSimulator):
    """Chain simulator + the DAG recurrence (same platforms, latencies,
    cold-start bookkeeping and rng, so results are comparable)."""

    def run_dag_request(self, steps, edges, t0: float, prefetch: bool) -> DagTrace:
        nodes = {s.name: s for s in steps}
        pred, succ, order = _graph(steps, edges)

        poke = {n: math.inf for n in order}
        prepare = {n: 0.0 for n in order}
        payload = {}
        start = {}
        end = {}
        double_billed = 0.0
        exposed_fetch = 0.0

        if prefetch:
            for v in order:
                if not pred[v]:
                    poke[v] = t0
                elif nodes[v].prefetch:
                    poke[v] = min(poke[u] for u in pred[v]) + self.msg

        for v in order:
            step = nodes[v]
            cold = self._cold(step, t0)
            fetch = step.fetch.sample(self.rng)
            if not pred[v]:
                payload[v] = t0 + self.msg / 2
            else:
                dst = self.platforms[step.platform]
                payload[v] = max(
                    end[u] + self._transfer_s(self.platforms[nodes[u].platform], dst)
                    for u in pred[v]
                )
            if prefetch and poke[v] < math.inf:
                prepare[v] = poke[v] + cold + fetch
                start[v] = max(payload[v], prepare[v])
                double_billed += max(0.0, start[v] - prepare[v])
                exposed_fetch += max(0.0, prepare[v] - payload[v])
            else:
                start[v] = payload[v] + cold + fetch
                exposed_fetch += fetch
            end[v] = start[v] + step.compute.sample(self.rng)
            self._last_use[(step.name, step.platform)] = end[v]

        total = max(end[n] for n in order if not succ[n]) - t0
        return DagTrace(
            total, start, end, prepare, payload, double_billed, exposed_fetch
        )

    def run_dag_experiment(
        self,
        steps,
        edges,
        n_requests: int = 1800,
        interarrival_s: float = 1.0,
        prefetch: bool = True,
    ) -> np.ndarray:
        self._last_use = {}
        out = np.empty(n_requests)
        for k in range(n_requests):
            out[k] = self.run_dag_request(
                steps, edges, k * interarrival_s, prefetch
            ).total_s
        return out


# ---------------------------------------------------------------------------
# calibrated DAG shapes (the paper's §4.2 workflow, restructured)
# ---------------------------------------------------------------------------
def document_dag_fig4():
    """The Fig-4 document workflow as a real fan-out: after ``check``, the
    virus scan and the OCR don't depend on each other — run them in
    parallel and join at ``e_mail``. Same calibrated distributions as
    ``simulator.document_workflow_fig4`` so the chain serialization of
    these steps IS the paper's chain."""
    steps = [
        SimStep("check", "tinyfaas-edge", compute=Dist(0.22)),
        SimStep("virus", "gcf", compute=Dist(0.30), fetch=Dist(0.32)),
        SimStep("ocr", "lambda-us-east-1", compute=Dist(0.45), fetch=Dist(1.45)),
        SimStep("e_mail", "lambda-us-east-1", compute=Dist(0.20), fetch=Dist(0.85)),
    ]
    edges = [
        ("check", "virus"),
        ("check", "ocr"),
        ("virus", "e_mail"),
        ("ocr", "e_mail"),
    ]
    return steps, edges
