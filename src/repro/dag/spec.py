"""Per-request DAG workflow specifications (fan-out/fan-in, beyond GeoFF).

GeoFF's workflows are chains (paper §3.2); ``DagSpec`` generalizes the
per-request spec to a directed acyclic graph so branches can execute
concurrently and a step can join several predecessors (the DFlow /
DataFlower dataflow model, PAPERS.md). The design keeps every property the
chain spec had:

  - it is runtime DATA that travels inside the invocation (JSON
    round-trip), so routing stays per-request — ad-hoc recomposition via
    ``reroute`` / ``apply_placement`` needs no redeployment;
  - steps are the same (function, platform, data_deps, prefetch) tuples, so
    every deployed function serves chains and DAGs alike;
  - a chain is just a degenerate DAG: ``DagSpec.from_chain`` lifts any
    existing ``WorkflowSpec`` losslessly — it is how the chain facade
    (``core.choreographer.Deployment``) routes every chain request onto
    the one dataflow engine.

Edges are named pairs of step names. ``__post_init__`` validates the graph
(unique names, known endpoints, no self-loops or duplicates, acyclic), so a
spec that deserializes is a spec the engine can execute.
"""

from __future__ import annotations

import json
from dataclasses import dataclass

from repro.core.shipping import PlacementCosts, place_dag
from repro.core.workflow import StepSpec, WorkflowSpec


@dataclass(frozen=True)
class DagStep(StepSpec):
    """A DAG node: identical runtime contract to a chain ``StepSpec``.

    A node with several in-edges is a fan-in join: the engine buffers each
    predecessor's payload and fires the handler once with
    ``{pred_name: payload}``. Single-predecessor nodes receive the payload
    unwrapped, exactly like a chain step, so chain handlers port unchanged.

    ``fn`` optionally names the deployed function when it differs from the
    node name ("" = same). Node names must be unique per spec, but a
    workflow may invoke the same function at two nodes — ``from_chain``
    relies on this to lift chains that repeat a step.
    """

    fn: str = ""  # deployed function name; "" -> the node name

    def resolved_fn(self) -> str:
        return self.fn or self.name

    def to_json(self):
        d = StepSpec.to_json(self)
        if self.fn:
            d["fn"] = self.fn
        return d

    @staticmethod
    def from_json(d) -> "DagStep":
        s = StepSpec.from_json(d)
        return DagStep(
            s.name,
            s.platform,
            s.data_deps,
            s.prefetch,
            s.sync,
            s.params,
            d.get("fn", ""),
        )

    @staticmethod
    def from_step(s: StepSpec, name: str = "", fn: str = "") -> "DagStep":
        return DagStep(
            name or s.name, s.platform, s.data_deps, s.prefetch, s.sync, s.params, fn
        )


@dataclass(frozen=True)
class DagSpec:
    """A DAG of steps with named edges ``(src_name, dst_name)``."""

    steps: tuple  # tuple[DagStep]
    edges: tuple  # tuple[tuple[str, str]]
    workflow_id: str = ""

    def __post_init__(self):
        if not self.steps:
            raise ValueError("empty workflow")
        names = [s.name for s in self.steps]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate step names: {names}")
        known = set(names)
        seen = set()
        for e in self.edges:
            a, b = e
            if a not in known or b not in known:
                raise ValueError(f"edge {e} references unknown step")
            if a == b:
                raise ValueError(f"self-edge {e}")
            if (a, b) in seen:
                raise ValueError(f"duplicate edge {e}")
            seen.add((a, b))
        # normalize edges to tuples (from_json hands us lists)
        object.__setattr__(self, "edges", tuple((a, b) for a, b in self.edges))
        if len(self.topo_order()) != len(names):
            raise ValueError("workflow graph has a cycle")

    # -- graph accessors -------------------------------------------------------
    def node(self, name: str) -> DagStep:
        for s in self.steps:
            if s.name == name:
                return s
        raise KeyError(name)

    def successors(self, name: str) -> tuple:
        return tuple(b for a, b in self.edges if a == name)

    def predecessors(self, name: str) -> tuple:
        return tuple(a for a, b in self.edges if b == name)

    def sources(self) -> tuple:
        dsts = {b for _, b in self.edges}
        return tuple(s.name for s in self.steps if s.name not in dsts)

    def sinks(self) -> tuple:
        srcs = {a for a, _ in self.edges}
        return tuple(s.name for s in self.steps if s.name not in srcs)

    def topo_order(self) -> tuple:
        """Kahn's algorithm, deterministic: ties broken by ``steps`` order."""
        pos = {s.name: i for i, s in enumerate(self.steps)}
        indeg = {s.name: 0 for s in self.steps}
        for _, b in self.edges:
            indeg[b] += 1
        order = []
        ready = sorted((n for n, d in indeg.items() if d == 0), key=pos.get)
        while ready:
            u = ready.pop(0)
            order.append(u)
            for v in self.successors(u):
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
            ready.sort(key=pos.get)
        return tuple(order)

    # -- recomposition (per-request routing, no redeploy) ----------------------
    def reroute(self, step_name: str, platform: str) -> "DagSpec":
        """Ad-hoc recomposition: same workflow, one step moved."""
        return self.apply_placement({step_name: platform})

    def apply_placement(self, placement: dict, platforms=None) -> "DagSpec":
        """Move every step named in ``placement`` (a ``{name: platform}``
        map, e.g. the output of ``shipping.place_dag``) to its platform.

        Validates its input: a placement naming an unknown step raises
        ``ValueError`` with the offending name, and when ``platforms`` (the
        deployment's platform set, e.g. ``registry.names()``) is given, so
        does a target platform outside it — a hot-swapped route must never
        point at a platform that cannot serve it."""
        known = {s.name for s in self.steps}
        for name in placement:
            if name not in known:
                raise ValueError(
                    f"placement names unknown step {name!r}; "
                    f"steps: {sorted(known)}"
                )
        if platforms is not None:
            allowed = set(platforms)
            for name, platform in placement.items():
                if platform not in allowed:
                    raise ValueError(
                        f"placement moves step {name!r} to unknown platform "
                        f"{platform!r}; platforms: {sorted(allowed)}"
                    )
        steps = tuple(
            DagStep(
                s.name,
                placement.get(s.name, s.platform),
                s.data_deps,
                s.prefetch,
                s.sync,
                s.params,
                s.fn,
            )
            for s in self.steps
        )
        return DagSpec(steps, self.edges, self.workflow_id)

    # -- serialization ---------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "workflow_id": self.workflow_id,
                "steps": [s.to_json() for s in self.steps],
                "edges": [list(e) for e in self.edges],
            }
        )

    @staticmethod
    def from_json(s: str) -> "DagSpec":
        d = json.loads(s)
        return DagSpec(
            tuple(DagStep.from_json(x) for x in d["steps"]),
            tuple((a, b) for a, b in d.get("edges", ())),
            d.get("workflow_id", ""),
        )

    # -- chain interop ---------------------------------------------------------
    @staticmethod
    def from_chain(wf: WorkflowSpec) -> "DagSpec":
        """Lift a chain ``WorkflowSpec`` into the degenerate DAG.

        Chains may invoke the same function twice (they are positional);
        DAG node names must be unique, so repeated names get an ``@index``
        suffix with ``fn`` pointing back at the deployed function."""
        counts: dict = {}
        for s in wf.steps:
            counts[s.name] = counts.get(s.name, 0) + 1
        steps = []
        for i, s in enumerate(wf.steps):
            if counts[s.name] > 1:
                steps.append(DagStep.from_step(s, name=f"{s.name}@{i}", fn=s.name))
            else:
                steps.append(DagStep.from_step(s))
        names = [s.name for s in steps]
        edges = tuple((names[i], names[i + 1]) for i in range(len(names) - 1))
        return DagSpec(tuple(steps), edges, wf.workflow_id)


def place_dag_spec(
    spec: DagSpec, candidates: dict, costs: PlacementCosts, prefetch: bool = True
) -> DagSpec:
    """Automated placement for a DAG spec (paper §5.3, generalized).

    Runs ``shipping.place_dag`` over the spec's nodes and edges and applies
    the resulting ``{name: platform}`` routes — the DAG analogue of
    ``place_chain`` returning a re-routed spec.
    """
    nodes = {s.name: s for s in spec.steps}
    placement = place_dag(nodes, list(spec.edges), candidates, costs, prefetch)
    return spec.apply_placement(placement)
