"""repro.dag — THE dataflow execution core (chains are degenerate DAGs).

Every workflow in this repo executes here; the chain stack
(``repro.core.choreographer``) is a facade that lifts ``WorkflowSpec``
through ``DagSpec.from_chain``:

  spec     DagSpec / DagStep — per-request DAG routing (JSON round-trip,
           topological validation, from_chain lift, place_dag wiring)
  engine   DagDeployment — the one dataflow executor: pokes cascade along
           edges, nodes fire when their last predecessor payload lands,
           branches run concurrently on the platform executors, poke
           timing learns per (pred -> succ) edge
  sim      DagWorkflowSimulator — alias of the unified simulator
           (core.simulator), which runs one recurrence for chains + DAGs
"""

from repro.dag.spec import DagSpec, DagStep, place_dag_spec  # noqa: F401
from repro.dag.engine import DagDeployment, DagResult, DeployedFn  # noqa: F401
from repro.dag.sim import (  # noqa: F401
    DagTrace,
    DagWorkflowSimulator,
    document_dag_fig4,
    serialize_chain,
)
