"""repro.dag — dataflow DAG engine for fan-out/fan-in federated workflows.

Generalizes the chain-only GeoFF core to directed acyclic graphs:

  spec     DagSpec / DagStep — per-request DAG routing (JSON round-trip,
           topological validation, from_chain lift, place_dag wiring)
  engine   DagDeployment — dataflow executor: pokes cascade along edges,
           nodes fire when their last predecessor payload lands, branches
           run concurrently on the platform executors
  sim      DagWorkflowSimulator — the DAG timeline recurrence over the
           calibrated latency distributions (chain-vs-DAG medians)
"""

from repro.dag.spec import DagSpec, DagStep, place_dag_spec  # noqa: F401
from repro.dag.engine import DagDeployment, DagResult  # noqa: F401
from repro.dag.sim import (  # noqa: F401
    DagTrace,
    DagWorkflowSimulator,
    document_dag_fig4,
    serialize_chain,
)
