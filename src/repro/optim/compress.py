"""int8 error-feedback gradient compression for the cross-pod DCN all-reduce.

On the multi-pod mesh the only train-path collective crossing DCN is the
gradient reduction over the "pod" axis (params are FSDP-sharded within a
pod, replicated across pods — HSDP). DCN is ~20-30x slower per byte than
ICI, so we quantize the cross-pod reduction to int8 with per-tensor scales
and ERROR FEEDBACK: the quantization residual is carried into the next
step's gradient, so compression bias vanishes over steps (proved to
converge for SGD-class methods; tests/test_compress.py checks the residual
telescopes and a quadratic converges).

``cross_pod_mean`` is shard_map-ready: inside a shard_map over the "pod"
axis it performs   q = quant(g);  psum(q)  in int32;  dequant / n_pods.
Outside a mesh it degrades to identity (single-pod training).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """x: float array -> (int8 values, scale). Symmetric per-tensor scale."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad, residual):
    """Returns (int8 payload, scale, new_residual). grad+residual is what we
    try to transmit; what we couldn't express becomes the new residual."""
    target = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(target)
    sent = dequantize_int8(q, scale)
    return q, scale, target - sent


def cross_pod_mean(grad, residual, axis_name: str = "pod"):
    """Error-feedback int8 mean over the pod axis (use inside shard_map).

    int8 payloads are summed as int32 (exact for <= 2^23 pods), then
    dequantized with the max scale — one DCN all-reduce of ~1/4 the bf16
    bytes (1/2 of f32: int8 values + negligible scale).
    """
    q, scale, new_res = compress_with_feedback(grad, residual)
    n = jax.lax.psum(1, axis_name)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(scale, axis_name)
    # NOTE: summing with each pod's own scale would need per-pod scales on
    # the wire; using pmax(scale) for dequant bounds the error by the same
    # 1/127 envelope and keeps the payload a single tensor.
    mean = qsum.astype(jnp.float32) * smax / n
    return mean, new_res


def tree_compress_stats(grads):
    """Wire bytes with and without compression (reporting)."""
    leaves = jax.tree_util.tree_leaves(grads)
    raw = sum(leaf.size * 4 for leaf in leaves)
    compressed = sum(leaf.size * 1 + 4 for leaf in leaves)
    return {"raw_bytes": raw, "int8_bytes": compressed,
            "ratio": raw / max(compressed, 1)}
