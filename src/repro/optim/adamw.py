"""AdamW with warmup+cosine schedule and global-norm clipping.

Self-contained (no optax dependency). State is {m, v, count}; m/v mirror the
parameter pytree (same logical axes -> same sharding), so the optimizer adds
exactly 2x parameter bytes, FSDP/TP-sharded identically to the params.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


def cosine_schedule(step, *, peak_lr, warmup_steps, total_steps,
                    final_frac=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * (step + 1.0) / max(1, warmup_steps)
    t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                     (1.0 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig()):
        self.cfg = cfg

    # -- state ---------------------------------------------------------------
    def init(self, params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree_util.tree_map(jnp.copy, zeros),
                "count": jnp.zeros((), jnp.int32)}

    def state_defs(self, pdefs):
        """ParamDef pytree for the opt state (dry-run ShapeDtypeStructs)."""
        f32 = jax.tree_util.tree_map(
            lambda d: ParamDef(d.shape, d.axes, "zeros"), pdefs,
            is_leaf=lambda x: isinstance(x, ParamDef))
        return {"m": f32, "v": jax.tree_util.tree_map(lambda d: d, f32),
                "count": ParamDef((), (), "zeros")}

    # -- update ----------------------------------------------------------------
    def update(self, params, state, grads, step):
        c = self.cfg
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-9))
        lr = cosine_schedule(step, peak_lr=c.peak_lr,
                             warmup_steps=c.warmup_steps,
                             total_steps=c.total_steps)
        count = state["count"] + 1
        bc1 = 1.0 - c.b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - c.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = c.b1 * m + (1 - c.b1) * g
            v = c.b2 * v + (1 - c.b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            step_ = mhat / (jnp.sqrt(vhat) + c.eps)
            if p.ndim >= 2:  # decoupled wd on matrices only
                step_ = step_ + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
