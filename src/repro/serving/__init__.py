from repro.serving.engine import ServingEngine, Request, pad_cache  # noqa: F401
