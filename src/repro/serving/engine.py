"""Serving engine: continuous batching + GeoFF prefill/decode disaggregation.

A request's lifecycle is a two-step GeoFF workflow:

    prefill (platform A)  ->  decode (platform B)

The prefill "function" builds the KV cache; the decode "function" consumes
it. Disaggregation is the paper's choreography applied to serving: while a
prefill runs, the decode platform is POKED — its step function pre-warms
(AOT compile at the decode batch shape) and its weights are already resident
(platform state). The KV-cache handoff is the function-shipping decision
inverted: ship the CACHE to the decode pod (cheap: one sequence) rather than
the decode step to the prefill pod (which would idle the prefill compute).

Continuous batching: decode runs a fixed-slot batch; finished sequences free
their slot and the scheduler immediately admits the next prefilled request
(slot-level admission, like vLLM's continuous batching but with functional
JAX cache updates — the cache is a pytree with a leading slot axis).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prewarm import CompileCache
from repro.models import model as M
from repro.models.transformer import cache_defs, _is_spec


def _axis_trees(cfg):
    """Pytrees (matching the cache structure) of the batch axis index and
    the cache_seq axis index (or -1) for every cache leaf, derived from the
    SpecDef logical axes — the single source of cache-layout truth."""
    defs = cache_defs(cfg, 1, 8)
    baxis = jax.tree_util.tree_map(lambda d: d.axes.index("batch"), defs,
                                   is_leaf=_is_spec)
    saxis = jax.tree_util.tree_map(
        lambda d: d.axes.index("cache_seq") if "cache_seq" in d.axes else -1,
        defs, is_leaf=_is_spec)
    return baxis, saxis


def pad_cache(caches, target_len: int, cur_len: int, cfg=None, saxis=None):
    """Pad prefill caches (capacity == prompt len) to the generation budget.

    Attention caches grow along their cache_seq axis; recurrent states
    (ssd/rglru conv/h/state) are length-independent and pass through, as do
    ring buffers already at their window size.
    """
    if target_len == cur_len:
        return caches
    if saxis is None:
        saxis = _axis_trees(cfg)[1]

    def pad(leaf, ax):
        if ax < 0 or leaf.shape[ax] != cur_len:
            return leaf           # recurrent state / ring buffer
        width = [(0, 0)] * leaf.ndim
        width[ax] = (0, target_len - cur_len)
        return jnp.pad(leaf, width)

    return jax.tree_util.tree_map(pad, caches, saxis)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    t_submit: float = field(default_factory=time.perf_counter)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    tokens: list = field(default_factory=list)


class ServingEngine:
    """Single-host engine (the real thing runs one instance per platform and
    GeoFF choreographs between them — see examples/federated_serving.py)."""

    def __init__(self, cfg, params, max_batch: int = 4,
                 max_len: int = 512, cache: Optional[CompileCache] = None):
        self.cfg = cfg.replace(scan_layers=True)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: deque = deque()
        self.active: dict = {}            # slot -> Request
        self.cache = cache or CompileCache()
        self.stats = {"prefills": 0, "decode_steps": 0, "ttft_s": [],
                      "done": 0}

        self._prefill = jax.jit(
            lambda p, b: M.prefill(self.cfg, p, b))
        self._decode = jax.jit(
            lambda p, t, c, i: M.decode_step(self.cfg, p, t, c, i))
        self._baxis, self._saxis = _axis_trees(self.cfg)
        # slot-batched decode state
        self.slot_caches = None
        self.slot_tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.slot_pos = np.zeros(max_batch, np.int64)  # per-slot next index
        self.free_slots = list(range(max_batch))

    # -- pre-warm (GeoFF poke) -----------------------------------------------------
    def prewarm(self, prompt_len: int):
        """Compile prefill+decode ahead of traffic (cold start off path)."""
        B = self.max_batch
        dummy = {"tokens": jnp.zeros((1, prompt_len), jnp.int32)}
        self._prefill.lower(self.params, dummy).compile()
        cd = cache_defs(self.cfg, B, self.max_len)
        caches = M.spec_zeros(cd)
        self._decode.lower(self.params, self.slot_tokens, caches,
                           jnp.zeros((), jnp.int32)).compile()

    # -- admission -------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop(0)
            T = len(req.prompt)
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
            self.stats["prefills"] += 1
            tok = int(jnp.argmax(logits[0]))
            req.tokens.append(tok)
            req.t_first_token = time.perf_counter()
            self.stats["ttft_s"].append(req.t_first_token - req.t_submit)
            caches = pad_cache(caches, self.max_len, T, saxis=self._saxis)
            if self.slot_caches is None:
                # materialize the slot-batched cache pytree lazily
                self.slot_caches = jax.tree_util.tree_map(
                    lambda leaf, ax: jnp.zeros(
                        leaf.shape[:ax] + (self.max_batch,)
                        + leaf.shape[ax + 1:], leaf.dtype),
                    caches, self._baxis)
            self.slot_caches = jax.tree_util.tree_map(
                lambda sc, c, ax: jax.lax.dynamic_update_slice_in_dim(
                    sc, c.astype(sc.dtype), slot, axis=ax),
                self.slot_caches, caches, self._baxis)
            self.slot_tokens = self.slot_tokens.at[slot, 0].set(tok)
            self.slot_pos[slot] = T
            self.active[slot] = req

    # -- decode ----------------------------------------------------------------------
    def _decode_once(self):
        if not self.active:
            return
        # one position index per step: use the max (sequences are
        # right-aligned enough for the demo; production uses per-slot
        # positions via vmapped decode)
        cur = int(max(self.slot_pos[s] for s in self.active))
        cur = min(cur, self.max_len - 1)
        logits, self.slot_caches = self._decode(
            self.params, self.slot_tokens, self.slot_caches,
            jnp.asarray(cur, jnp.int32))
        self.stats["decode_steps"] += 1
        toks = np.asarray(jnp.argmax(logits, -1))
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(toks[slot])
            req.tokens.append(tok)
            self.slot_pos[slot] += 1
            if (len(req.tokens) >= req.max_new_tokens
                    or self.slot_pos[slot] >= self.max_len - 1):
                req.t_done = time.perf_counter()
                finished.append(slot)
        for slot in finished:
            req = self.active.pop(slot)
            self.free_slots.append(slot)
            self.stats["done"] += 1
        self.slot_tokens = jnp.asarray(
            toks.reshape(-1, 1).astype(np.int32))

    # -- main loop ---------------------------------------------------------------------
    def run(self, max_steps: int = 1000):
        """Continuous batching: admit whenever slots free up, decode the
        active batch, repeat until drained."""
        done_reqs = []
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self._admit()
            self._decode_once()
            steps += 1
        return self.stats
