"""Mamba-2 block: SSD (state-space duality) chunked algorithm.

Train/prefill use the chunked SSD decomposition (intra-chunk quadratic term
+ inter-chunk state scan, arXiv:2405.21060 §6); decode is the O(1) recurrent
update. The pure-jnp path here is also the oracle for the Pallas
`ssd_scan` kernel (kernels/ssd_scan/ref.py re-exports `ssd_chunked`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import rmsnorm
from repro.models.params import ParamDef


def ssd_defs(cfg) -> dict:
    D = cfg.d_model
    d_inner = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    conv_ch = d_inner + 2 * N  # x, B, C all pass the causal conv
    d_in_proj = 2 * d_inner + 2 * N + H
    return {
        "norm": ParamDef((D,), ("embed",), "zeros"),
        "in_proj": ParamDef((D, d_in_proj), ("embed", "inner")),
        "conv_w": ParamDef((cfg.conv_width, conv_ch), ("conv", "inner")),
        "conv_b": ParamDef((conv_ch,), ("inner",), "zeros"),
        "A_log": ParamDef((H,), (None,), "ssd_alog"),
        "D": ParamDef((H,), (None,), "ones"),
        "dt_bias": ParamDef((H,), (None,), "dt_bias"),
        "norm_y": ParamDef((d_inner,), ("inner",), "zeros"),
        "out_proj": ParamDef((d_inner, D), ("inner", "embed")),
    }


def causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,L,C), w: (cw,C). Returns (B,L,C)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def conv_step(x_t, conv_cache, w, b):
    """One decode step. x_t: (B,C); conv_cache: (B,cw-1,C). Returns (y, cache)."""
    window = jnp.concatenate([conv_cache, x_t[:, None, :]], axis=1)  # (B,cw,C)
    y = jnp.einsum("bwc,wc->bc", window, w) + b[None, :]
    return jax.nn.silu(y), window[:, 1:, :]


def ssd_chunked(x, dt, A_log, B_mat, C_mat, chunk, init_state=None):
    """Chunked SSD. Shapes:
      x: (B,L,H,P)  dt: (B,L,H)  A_log: (H,)  B_mat/C_mat: (B,L,N)
    Returns y: (B,L,H,P), final_state: (B,H,P,N).
    """
    Bb, L, H, Pp = x.shape
    N = B_mat.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    f32 = jnp.float32

    a = -jnp.exp(A_log.astype(f32))                     # (H,)
    dA = a[None, None, :] * dt.astype(f32)              # (B,L,H), <= 0
    xr = x.reshape(Bb, nc, Q, H, Pp)
    dtr = dt.reshape(Bb, nc, Q, H).astype(f32)
    Br = B_mat.reshape(Bb, nc, Q, N).astype(f32)
    Cr = C_mat.reshape(Bb, nc, Q, N).astype(f32)
    dAr = dA.reshape(Bb, nc, Q, H)
    cum = jnp.cumsum(dAr, axis=2)                       # (B,nc,Q,H)

    # intra-chunk (quadratic within chunk). Mask BEFORE exp: for t < s the
    # raw diff is positive and can overflow; exp(overflow) * 0 would push
    # NaNs through the backward pass.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H) t,s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    CB = jnp.einsum("bcqn,bcsn->bcqs", Cr, Br)
    # G[b,c,t,s,h] = CB[b,c,t,s] * Lmat[b,c,t,s,h] * dt[b,c,s,h]
    G = CB[..., None] * Lmat * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", G, xr.astype(f32))

    # chunk states: S_c = sum_s exp(cum[-1]-cum[s]) dt_s B_s x_s
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtr       # (B,nc,Q,H)
    S_c = jnp.einsum("bcsh,bcsn,bcshp->bchpn", w_end, Br, xr.astype(f32))

    # inter-chunk recurrence over nc
    decay_chunk = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)
    h0 = (jnp.zeros((Bb, H, Pp, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(h, inp):
        dc, s = inp                                       # dc:(B,H), s:(B,H,P,N)
        h_new = h * dc[:, :, None, None] + s
        return h_new, h

    dc_seq = jnp.moveaxis(decay_chunk, 1, 0)             # (nc,B,H)
    s_seq = jnp.moveaxis(S_c, 1, 0)                      # (nc,B,H,P,N)
    h_final, h_prevs = jax.lax.scan(step, h0, (dc_seq, s_seq))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)        # (B,nc,H,P,N) pre-chunk state

    # inter-chunk contribution: C_t · (h_prev * exp(cum[t]))
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr, h_prevs, jnp.exp(cum))

    y = (y_intra + y_inter).reshape(Bb, L, H, Pp)
    return y.astype(x.dtype), h_final


def ssd_step(x_t, dt_t, A_log, B_t, C_t, state):
    """O(1) decode update.
      x_t:(B,H,P) dt_t:(B,H) B_t/C_t:(B,N) state:(B,H,P,N)
    Returns (y:(B,H,P), new_state)."""
    f32 = jnp.float32
    a = -jnp.exp(A_log.astype(f32))
    da = jnp.exp(a[None, :] * dt_t.astype(f32))                     # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t.astype(f32),
                     B_t.astype(f32), x_t.astype(f32))
    new = state.astype(f32) * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, C_t.astype(f32))
    return y.astype(x_t.dtype), new.astype(state.dtype)


def ssd_block(cfg, p, x, mode, cache=None, use_pallas=False):
    """Full mamba2 block (norm -> in_proj -> conv -> SSD -> gated norm -> out).

    cache (decode): {"conv": (B,cw-1,conv_ch), "state": (B,H,P,N)}.
    Returns (out, new_cache) — new_cache also produced by prefill.
    """
    d_inner, N, H, Pp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bb, T, D = x.shape
    u = rmsnorm(x, p["norm"])
    zxbcdt = jnp.einsum("btd,de->bte", u, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]

    if mode in ("train", "prefill"):
        xBC = causal_conv(xBC, p["conv_w"], p["conv_b"])
        xs = xBC[..., :d_inner].reshape(Bb, T, H, Pp)
        Bm = xBC[..., d_inner:d_inner + N]
        Cm = xBC[..., d_inner + N:]
        dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])
        xs = shard(xs, "batch", "seq", "act_inner", None)
        # pad T to a chunk multiple; zero-dt padding is EXACT for SSD
        # (state multiplies by exp(0)=1 and accumulates dt*B*x = 0)
        Q = min(cfg.ssm_chunk, T)
        pad = (-T) % Q
        if pad:
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        else:
            xs_p, dt_p, Bm_p, Cm_p = xs, dt, Bm, Cm
        if use_pallas:
            from repro.kernels import ops as kops
            y, state = kops.ssd_scan(xs_p, dt_p, p["A_log"], Bm_p, Cm_p, Q)
        else:
            y, state = ssd_chunked(xs_p, dt_p, p["A_log"], Bm_p, Cm_p, Q)
        if pad:
            y = y[:, :T]
        y = y + xs * p["D"][None, None, :, None]
        new_cache = None
        if mode == "prefill":
            # conv tail for continuing decode
            raw = jnp.einsum("btd,de->bte", u, p["in_proj"])[
                ..., d_inner:2 * d_inner + 2 * N]
            tail = raw[:, -(cfg.conv_width - 1):, :]
            new_cache = {"conv": tail, "state": state}
    else:  # decode, T == 1
        xBC_t = xBC[:, 0, :]
        xc, conv_cache = conv_step(xBC_t, cache["conv"], p["conv_w"], p["conv_b"])
        xs = xc[:, :d_inner].reshape(Bb, H, Pp)
        Bm = xc[:, d_inner:d_inner + N]
        Cm = xc[:, d_inner + N:]
        dt_t = jax.nn.softplus(dt[:, 0, :] + p["dt_bias"][None, :])
        y, state = ssd_step(xs, dt_t, p["A_log"], Bm, Cm, cache["state"])
        y = (y + xs * p["D"][None, :, None])[:, None]          # (B,1,H,P)
        new_cache = {"conv": conv_cache, "state": state}

    y = y.reshape(Bb, T, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_y"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    return shard(out, "batch", "seq", "act_embed"), new_cache


def ssd_cache_specs(cfg, batch):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": (batch, cfg.conv_width - 1, conv_ch),
        "state": (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
    }
