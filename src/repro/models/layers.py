"""Transformer building blocks: RMSNorm, RoPE, GQA attention (global /
sliding-window, qk-norm, ring-buffer decode caches), gated MLP, and
capacity-based top-k MoE with scatter dispatch (EP-shardable).

All blocks run in three modes:
  train   — full sequence, no cache
  prefill — full sequence, returns the KV cache (+ last-position states)
  decode  — T=1 step against a cache (full-length or ring buffer)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.params import ParamDef


@dataclass(frozen=True)
class BlockCfg:
    """Static per-layer info resolved from ArchConfig.block_pattern."""
    kind: str                 # attn | rglru | ssd
    window: Optional[int]     # None -> global attention
    theta: float = 10_000.0


def block_cfg_for(cfg, kind: str) -> BlockCfg:
    if kind == "global":
        theta = cfg.rope_theta_global or cfg.rope_theta
        return BlockCfg("attn", None, theta)
    if kind == "local":
        return BlockCfg("attn", cfg.local_window, cfg.rope_theta)
    if kind == "rglru":
        return BlockCfg("rglru", None)
    if kind == "ssd":
        return BlockCfg("ssd", None)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def rope(x, positions, theta):
    """x: (..., T, n, d) rotated pairwise; positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attn_defs(cfg) -> dict:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((hd,), ("head_dim",), "zeros")
        d["k_norm"] = ParamDef((hd,), ("head_dim",), "zeros")
    return d


def _attn_mask(q_pos, k_pos, window, causal):
    """q_pos: (Tq,), k_pos: (Tk,) absolute positions; True = attend."""
    dq = q_pos[:, None] - k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= dq >= 0
    if window is not None:
        m &= dq < window
    return m


def _sdpa(cfg, q, k, v, mask):
    """q:(B,T,H,hd) k/v:(B,S,K,hd) mask:(T,S) or (B,T,S)."""
    B, T, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, T, K, G, hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32)
    scores *= hd ** -0.5
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v)
    return out.reshape(B, T, H, hd)


def _sdpa_chunked(cfg, q, k, v, q_pos, k_pos, window, causal, chunk, unroll):
    """Flash-style q-chunked attention: scores stay O(chunk x S).

    For sliding-window layers the K/V are sliced to the band
    [chunk_start - window + 1, chunk_end] so local attention costs
    O(T*(window+chunk)) instead of O(T*S).

    unroll=True emits a python loop (exact XLA flop accounting — used by the
    dry-run for train shapes); unroll=False emits one lax.scan (small HLO —
    used for very long prefills; flops corrected analytically, see
    launch/analytic.py).
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    n = T // chunk
    assert T % chunk == 0, (T, chunk)

    banded = window is not None and S > window + chunk
    if banded:
        band = window + chunk
        # left-pad so every chunk's band slice has static size `band`;
        # padded positions get k_pos = -window (always masked by dq < window)
        pad = band - chunk
        k = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (pad, 0), constant_values=-(window + 1))

    # checkpoint per chunk: the bwd pass recomputes the O(chunk x S) score
    # tile instead of saving it — without this, the stacked per-chunk scores
    # (f32, n x B x H x chunk x S) dominate peak memory.
    @jax.checkpoint
    def one(i, qi, qpos_i):
        if banded:
            ks = jax.lax.dynamic_slice_in_dim(k, i * chunk, band, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, i * chunk, band, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, i * chunk, band, axis=0)
        else:
            ks, vs, kp = k, v, k_pos
        mask = _attn_mask(qpos_i, kp, window, causal)
        return _sdpa(cfg, qi, ks, vs, mask)

    if unroll:
        outs = [one(i, q[:, i * chunk:(i + 1) * chunk],
                    q_pos[i * chunk:(i + 1) * chunk]) for i in range(n)]
        return jnp.concatenate(outs, axis=1)

    qr = jnp.moveaxis(q.reshape(B, n, chunk, H, hd), 1, 0)      # (n,B,c,H,hd)
    pr = q_pos.reshape(n, chunk)

    def body(_, inp):
        i, qi, pi = inp
        return None, one(i, qi, pi)

    _, outs = jax.lax.scan(body, None,
                           (jnp.arange(n), qr, pr))
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, H, hd)


def attention(cfg, bc: BlockCfg, p, x, positions, mode, cache=None,
              cur_index=None):
    """Returns (out, new_cache).

    prefill: cache returned is (k, v) over the full sequence, or a ring
    buffer of size `window` for local layers.
    decode:  T==1; cache is updated functionally at `cur_index`.
    """
    B, T, D = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = rope(q, positions, bc.theta)
    k = rope(k, positions, bc.theta)
    q = shard(q, "batch", "attn_seq", "act_heads", None)
    k = shard(k, "batch", None, "act_kv", None)
    v = shard(v, "batch", None, "act_kv", None)

    if mode in ("train", "prefill"):
        causal = cfg.causal
        pos = positions if positions.ndim == 1 else positions[0]
        chunk = cfg.attn_chunk_q
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=causal,
                                       window=bc.window)
        elif chunk and T > chunk:
            out = _sdpa_chunked(cfg, q, k, v, pos, pos, bc.window, causal,
                                chunk, cfg.attn_chunk_unroll)
        else:
            mask = _attn_mask(pos, pos, bc.window, causal)
            out = _sdpa(cfg, q, k, v, mask)
        new_cache = None
        if mode == "prefill":
            if bc.window is not None and T > bc.window:
                # keep only the trailing window as a ring buffer
                W = bc.window
                start = T - W
                kr, vr = k[:, start:], v[:, start:]
                # roll so that slot i = position p with p % W == i
                shift = (start % W)
                kr = jnp.roll(kr, shift, axis=1)
                vr = jnp.roll(vr, shift, axis=1)
                new_cache = (kr, vr)
            else:
                new_cache = (k, v)
    else:  # decode
        ck, cv = cache
        S = ck.shape[1]
        if bc.window is not None and S == bc.window:
            slot = cur_index % S
        else:
            slot = cur_index
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        ck = shard(ck, "batch", "cache_seq", "act_kv", None)
        cv = shard(cv, "batch", "cache_seq", "act_kv", None)
        idx = jnp.arange(S)
        if bc.window is not None and S == bc.window:
            # slot i holds absolute position cur_index - ((cur_index - i) mod S)
            k_pos = cur_index - jnp.mod(cur_index - idx, S)
            valid = k_pos >= 0
        else:
            k_pos = idx
            valid = idx <= cur_index
        dq = cur_index - k_pos
        m = valid & (dq >= 0)
        if bc.window is not None:
            m &= dq < bc.window
        out = _sdpa(cfg, q, ck, cv, m[None, None, :].repeat(B, 0))
        new_cache = (ck, cv)

    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return shard(out, "batch", "seq", "act_embed"), new_cache


def attn_cache_shape(cfg, bc: BlockCfg, batch, seq_len):
    S = seq_len if bc.window is None else min(bc.window, seq_len)
    return (batch, S, cfg.num_kv_heads, cfg.head_dim)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------
def mlp_defs(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((D, F), ("embed", "ff")),
        "w_up": ParamDef((D, F), ("embed", "ff")),
        "w_down": ParamDef((F, D), ("ff", "embed")),
    }


def mlp(cfg, p, x):
    g = jnp.einsum("btd,df->btf", x, p["w_gate"])
    u = jnp.einsum("btd,df->btf", x, p["w_up"])
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "seq", "act_ff")
    out = jnp.einsum("btf,fd->btd", h, p["w_down"])
    return shard(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# MoE: top-k token-choice routing with BATCH-GROUP-LOCAL dispatch.
#
# Tokens are regrouped (N,D) -> (G, N/G, D) with G = the mesh's batch-shard
# count, and routing positions/capacity are computed with per-group cumsums.
# The scatter then lands in a group-local (G, E, C, D) buffer — batch-sharded
# over `data`, so dispatch needs NO collectives (a global cumsum would force
# GSPMD to replicate the buffers and all-reduce the scatter — measured 100x
# worse on granite, whose 40 experts don't divide the model axis).
#
# Expert weights shard over `model` via EP when E divides (moonshot 64e) —
# the combine gather then costs one all-gather of the out-buffer (the EP
# "all-to-all") — and fall back to TP on the expert ff dim otherwise
# (granite 40e), costing the standard Megatron down-proj all-reduce.
# ---------------------------------------------------------------------------
def moe_defs(cfg) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    e_ax = None if cfg.moe_tp_ff else "expert"
    return {
        "router": ParamDef((D, E), ("embed", None)),
        "w_gate": ParamDef((E, D, F), (e_ax, "embed", "ff")),
        "w_up": ParamDef((E, D, F), (e_ax, "embed", "ff")),
        "w_down": ParamDef((E, F, D), (e_ax, "ff", "embed")),
    }


def _batch_groups(n_tokens: int) -> int:
    """Batch-shard count from the ambient mesh (1 outside a mesh ctx)."""
    from repro.dist.sharding import current_sharding
    mesh, rules = current_sharding()
    if mesh is None or rules is None:
        return 1
    spec = rules.lookup("batch")
    if spec is None:
        return 1
    axes = (spec,) if isinstance(spec, str) else tuple(spec)
    g = 1
    for a in axes:
        g *= mesh.shape.get(a, 1)
    return g if n_tokens % g == 0 else 1


def moe(cfg, p, x):
    """x: (B,T,D) -> ((B,T,D), aux load-balance loss)."""
    B, T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = B * T
    G = _batch_groups(N)
    n = N // G                                           # tokens per group
    xg = x.reshape(G, n, D)
    xg = shard(xg, "batch", None, None)

    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)             # (G,n,K)
    gate_w = gate_w / jnp.sum(gate_w, -1, keepdims=True)

    # group-local capacity
    C = max(1, int(n * K / E * cfg.capacity_factor))

    # slot of each (token, choice) within its expert: per-choice exclusive
    # cumsum over the GROUP-LOCAL token dim (k <= 8, tiny python loop)
    pos = jnp.zeros((G, n, K), jnp.int32)
    base = jnp.zeros((G, 1, E), jnp.int32)
    for j in range(K):
        oh = jax.nn.one_hot(gate_i[:, :, j], E, dtype=jnp.int32)  # (G,n,E)
        within = jnp.cumsum(oh, axis=1) - oh                      # exclusive
        pos = pos.at[:, :, j].set(jnp.take_along_axis(
            within + base, gate_i[:, :, j:j + 1], axis=2)[:, :, 0])
        base = base + jnp.sum(oh, axis=1, keepdims=True)
    keep = pos < C
    slot = jnp.where(keep, pos, C - 1)

    # dispatch: group-local scatter into (G, E, C, D). All scatters/gathers
    # are vmapped over G so the group axis is an explicit scatter BATCH
    # dimension — GSPMD then partitions them cleanly over `data`; indexing
    # G with an iota instead makes it all-reduce the whole buffer across
    # the batch shards (measured ~10 GB/layer of pure waste).
    w_in = jnp.where(keep, 1.0, 0.0).astype(x.dtype)
    upd = (xg[:, :, None, :] * w_in[..., None]).reshape(G, n * K, D)
    e_idx = gate_i.reshape(G, n * K)
    s_idx = slot.reshape(G, n * K)
    buf = jax.vmap(lambda e, s, u: jnp.zeros((E, C, D), x.dtype)
                   .at[e, s].add(u))(e_idx, s_idx, upd)
    if cfg.moe_tp_ff:
        # expert FFN TP-sharded on ff: the buffer stays model-replicated;
        # scatter/gather (fwd AND bwd) never cross the model axis.
        buf = shard(buf, "batch", None, None, None)
    else:
        if cfg.moe_local_scatter:
            # pin the scatter model-LOCAL (replicated over `model`,
            # redundant but memory-bound and tiny), THEN slice to the EP
            # sharding — GSPMD otherwise makes the scatter produce the
            # E-sharded buffer directly and all-reduces the whole dispatch
            # buffer to get there.
            buf = shard(buf, "batch", None, None, None)
        buf = shard(buf, "batch", "act_expert", None, None)

    # expert FFN (weights EP-sharded over `model` when E divides, else the
    # ff dim shards — see moe_defs axes)
    g_ = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = jax.nn.silu(g_) * u
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])

    # combine: scatter-BACK (not gather). Each slot knows its source token;
    # every device scatters the slots it owns into a token-indexed buffer.
    # With E sharded over `model` (EP) each rank contributes its experts'
    # slots; with the ff-dim TP fallback each rank contributes partial sums
    # — either way the cross-device reduction happens on the TOKEN-sized
    # (G,n,D) tensor, not the kxcapacity_factor-larger dispatch buffer
    # (gathering from the E-sharded buffer instead made GSPMD all-gather
    # the whole thing: measured 50-100x more collective traffic).
    flat_tok = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[None, :, None], (G, n, K)
    ).reshape(G, n * K)
    keep_f = keep.reshape(G, n * K)
    tok_of_slot = jax.vmap(lambda e, s, t: jnp.zeros((E, C), jnp.int32)
                           .at[e, s].add(t))(
        e_idx, s_idx, jnp.where(keep_f, flat_tok, 0))
    gate_of_slot = jax.vmap(lambda e, s, g: jnp.zeros((E, C), jnp.float32)
                            .at[e, s].add(g))(
        e_idx, s_idx, (gate_w.reshape(G, n * K) * keep_f).astype(jnp.float32))
    contrib = out_buf * gate_of_slot[..., None].astype(out_buf.dtype)
    out_tokens = jax.vmap(lambda t, c: jnp.zeros((n, D), x.dtype)
                          .at[t].add(c))(
        tok_of_slot.reshape(G, E * C), contrib.reshape(G, E * C, D))
    out = shard(out_tokens, "batch", None, None).reshape(B, T, D)

    # switch-style load-balance aux loss
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    fe = jnp.mean(jax.nn.one_hot(gate_i[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))                                     # (E,)
    aux = jnp.sum(me * fe) * E
    return shard(out, "batch", "seq", "act_embed"), aux


def ffn_defs(cfg) -> dict:
    return moe_defs(cfg) if cfg.num_experts else mlp_defs(cfg)


def ffn(cfg, p, x):
    """Returns (out, aux_loss)."""
    if cfg.num_experts:
        return moe(cfg, p, x)
    return mlp(cfg, p, x), jnp.float32(0.0)
