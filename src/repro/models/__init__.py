"""Declarative params + the unified multi-arch backbone."""
