"""Declarative parameter definitions.

A model is described once as a pytree of ``ParamDef`` (shape + logical axes +
init); materialized params, abstract ShapeDtypeStructs (for the allocation-free
dry-run), and PartitionSpecs are all derived from that single source, so the
sharding metadata can never diverge from the parameter structure.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.dist.sharding import pspec_for


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple               # logical axis names, len == len(shape)
    init: str = "normal"      # normal | zeros | ones | embed
                              # | lru_lambda | ssd_alog | dt_bias
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def _path_key(base_key, path):
    s = jax.tree_util.keystr(path)
    h = int.from_bytes(hashlib.sha256(s.encode()).digest()[:4], "big")
    return jax.random.fold_in(base_key, h)


def _materialize(d: ParamDef, key, dtype):
    shape = d.shape
    if d.init == "zeros":
        return jnp.zeros(shape, dtype)
    if d.init == "ones":
        return jnp.ones(shape, dtype)
    if d.init == "dt_bias":
        # mamba2 dt bias: softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32,
                               math.log(1e-3), math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if d.init == "ssd_alog":
        a = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(dtype)
    if d.init == "lru_lambda":
        # RG-LRU Lambda: sigmoid(L)^c in [0.9, 0.999] at c=8
        r = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        a = r ** (1.0 / 8.0)
        return jnp.log(a / (1 - a)).astype(dtype)
    scale = d.scale
    if scale is None:
        # fan-in variance scaling; the stacked "layers" axis (scan over
        # cycles) is NOT a fan-in dim — skipping it matters: with it, a
        # 2-cycle model initializes every weight ~1/sqrt(2), saturating
        # gates (found via NaN grads in the RG-LRU smoke).
        eff = shape[1:] if (d.axes and d.axes[0] == "layers") else shape
        fan_in = eff[0] if len(eff) >= 1 else 1
        if d.init == "embed":
            scale = 1.0
        else:
            scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(defs, key, dtype=jnp.float32):
    return jax.tree_util.tree_map_with_path(
        lambda p, d: _materialize(d, _path_key(key, p), dtype),
        defs, is_leaf=_is_def)


def abstract_params(defs, dtype=jnp.float32, rules=None, mesh=None):
    """ShapeDtypeStructs (optionally with shardings) — dry-run inputs."""
    def mk(d: ParamDef):
        sh = None
        if rules is not None and mesh is not None:
            sh = NamedSharding(mesh, pspec_for(d.shape, d.axes, rules, mesh))
        return jax.ShapeDtypeStruct(d.shape, dtype, sharding=sh)
    return jax.tree_util.tree_map(mk, defs, is_leaf=_is_def)


def param_pspecs(defs, rules, mesh):
    return jax.tree_util.tree_map(
        lambda d: pspec_for(d.shape, d.axes, rules, mesh), defs, is_leaf=_is_def)


def param_count(defs) -> int:
    return sum(math.prod(d.shape)
               for d in jax.tree_util.tree_leaves(defs, is_leaf=_is_def))


def stack_defs(defs, n: int):
    """Stack a block's defs along a leading `layers` axis (for scan)."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        defs, is_leaf=_is_def)
