"""Unified backbone covering all ten assigned architectures.

A model is ``embed -> [blocks cycled from cfg.block_pattern] -> norm -> head``.
Block kinds: "global"/"local" attention (GQA, qk-norm, sliding window),
"rglru" (griffin temporal mixing), "ssd" (mamba-2). Dense/MoE FFN is attached
to every block unless ``d_ff == 0`` (mamba2 blocks are mixer-only).

Depth handling: layers are grouped into *cycles* of ``len(block_pattern)``
and scanned with ``jax.lax.scan`` over stacked parameters, so HLO size is
independent of depth (compile time is the binding constraint for the 62-cell
dry-run sweep). Remainder layers (``num_layers % pattern``) run unscanned.

Modality frontends are stubs per the assignment: "frames" (hubert) and
"tokens+patches" (llava) models consume precomputed embeddings through a
linear adapter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.griffin import rglru_block, rglru_cache_specs, rglru_defs
from repro.models.layers import (attention, attn_cache_shape, attn_defs,
                                 block_cfg_for, ffn, ffn_defs, rmsnorm)
from repro.models.params import ParamDef, stack_defs
from repro.models.ssm import ssd_block, ssd_cache_specs, ssd_defs


# ---------------------------------------------------------------------------
# parameter structure
# ---------------------------------------------------------------------------
def block_defs(cfg, kind: str) -> dict:
    bc = block_cfg_for(cfg, kind)
    D = cfg.d_model
    if bc.kind == "ssd":
        d = {"mixer": ssd_defs(cfg)}          # ssd blocks self-norm
    elif bc.kind == "rglru":
        d = {"norm1": ParamDef((D,), ("embed",), "zeros"),
             "mixer": rglru_defs(cfg)}
    else:
        d = {"norm1": ParamDef((D,), ("embed",), "zeros"),
             "mixer": attn_defs(cfg)}
    if cfg.d_ff:
        d["norm2"] = ParamDef((D,), ("embed",), "zeros")
        d["ffn"] = ffn_defs(cfg)
    return d


def transformer_defs(cfg) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    pattern = cfg.block_pattern
    n_cyc, n_rem = divmod(cfg.num_layers, len(pattern))
    blocks: dict = {}
    if n_cyc:
        blocks["cycle"] = {
            f"p{j}": stack_defs(block_defs(cfg, k), n_cyc)
            for j, k in enumerate(pattern)}
    rem_kinds = cfg.layer_kinds()[n_cyc * len(pattern):]
    for i, k in enumerate(rem_kinds):
        blocks[f"rem{i}"] = block_defs(cfg, k)

    d: dict = {"blocks": blocks,
               "final_norm": ParamDef((D,), ("embed",), "zeros")}
    if cfg.input_kind == "frames":
        d["in_proj"] = ParamDef((D, D), ("embed", None))
        d["head"] = ParamDef((D, V), ("embed", "vocab"))
    else:
        d["embed"] = ParamDef((V, D), ("vocab", "embed"), "embed")
        if cfg.input_kind == "tokens+patches":
            d["patch_proj"] = ParamDef((D, D), ("embed", None))
        if not cfg.tie_embeddings:
            d["head"] = ParamDef((D, V), ("embed", "vocab"))
    return d


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------
def apply_block(cfg, kind, p, x, positions, mode, cache=None, cur_index=None):
    """Returns (x, new_cache, aux_loss)."""
    bc = block_cfg_for(cfg, kind)
    if bc.kind == "attn":
        h, c = attention(cfg, bc, p["mixer"], rmsnorm(x, p["norm1"]),
                         positions, mode, cache, cur_index)
    elif bc.kind == "rglru":
        h, c = rglru_block(cfg, p["mixer"], rmsnorm(x, p["norm1"]), mode,
                           cache, cfg.use_pallas)
    else:
        h, c = ssd_block(cfg, p["mixer"], x, mode, cache, cfg.use_pallas)
    x = x + h
    aux = jnp.float32(0.0)
    if "ffn" in p:
        f, aux = ffn(cfg, p["ffn"], rmsnorm(x, p["norm2"]))
        x = x + f
    return x, c, aux


# ---------------------------------------------------------------------------
# the stack (scan over cycles + unscanned remainder)
# ---------------------------------------------------------------------------
def _cycle_body(cfg, pattern, positions, mode, cur_index, x, p_sl, c_sl):
    aux_total = jnp.float32(0.0)
    new_c = {}
    for j, kind in enumerate(pattern):
        cj = None if c_sl is None else c_sl[f"p{j}"]
        x, cj_new, aux = apply_block(cfg, kind, p_sl[f"p{j}"], x, positions,
                                     mode, cj, cur_index)
        if mode != "train":
            new_c[f"p{j}"] = cj_new
        aux_total = aux_total + aux
    return x, (new_c if mode != "train" else None), aux_total


def run_blocks(cfg, params, x, positions, mode, caches=None, cur_index=None):
    """Returns (x, new_caches, aux_total)."""
    pattern = cfg.block_pattern
    n_cyc = cfg.num_layers // len(pattern)
    blocks_p = params["blocks"]
    new_caches: dict = {}
    aux_total = jnp.float32(0.0)

    if "cycle" in blocks_p:
        cyc_caches = None if caches is None else caches.get("cycle")

        def body(carry, xs):
            p_sl, c_sl = xs
            x, new_c, aux = _cycle_body(cfg, pattern, positions, mode,
                                        cur_index, carry, p_sl, c_sl)
            return x, (new_c, aux)

        if cfg.remat == "full":
            body = jax.checkpoint(body, policy=None)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        xs = (blocks_p["cycle"], cyc_caches)
        if cfg.scan_layers:
            # scan requires every xs leaf to carry the cycle axis; a bare
            # None (cyc_caches in train) is an empty pytree node, so it's ok.
            x, (cyc_new, auxs) = jax.lax.scan(body, x, xs, length=n_cyc)
            aux_total = aux_total + jnp.sum(auxs)
        else:
            # Unrolled: same stacked param structure, python loop + index.
            # Exact XLA flop/collective accounting (the dry-run path).
            cyc_list, aux_list = [], []
            for i in range(n_cyc):
                xs_i = jax.tree_util.tree_map(lambda a: a[i], xs)
                x, (c_i, aux_i) = body(x, xs_i)
                cyc_list.append(c_i)
                aux_list.append(aux_i)
            cyc_new = None
            if mode != "train":
                cyc_new = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *cyc_list)
            aux_total = aux_total + sum(aux_list)
        if mode != "train":
            new_caches["cycle"] = cyc_new

    rem_kinds = cfg.layer_kinds()[n_cyc * len(pattern):]
    for i, kind in enumerate(rem_kinds):
        ci = None if caches is None else caches.get(f"rem{i}")
        x, c_new, aux = apply_block(cfg, kind, blocks_p[f"rem{i}"], x,
                                    positions, mode, ci, cur_index)
        if mode != "train":
            new_caches[f"rem{i}"] = c_new
        aux_total = aux_total + aux
    return x, (new_caches if mode != "train" else None), aux_total


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def embed_inputs(cfg, params, batch):
    """Returns x: (B, T, D) in compute dtype."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.input_kind == "frames":
        x = batch["frames"].astype(cdt)
        x = jnp.einsum("btd,de->bte", x, params["in_proj"])
    elif cfg.input_kind == "tokens+patches" and "patches" in batch:
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        pat = jnp.einsum("bpd,de->bpe", batch["patches"].astype(cdt),
                         params["patch_proj"])
        x = jnp.concatenate([pat, tok], axis=1)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    return shard(x.astype(cdt), "batch", "seq", "act_embed")


def unembed(cfg, params, x):
    """x: (B,T,D) -> logits (B,T,V) in compute dtype (+softcap)."""
    if "head" in params:
        logits = jnp.einsum("btd,dv->btv", x, params["head"])
    else:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"])
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c)
    return shard(logits, "batch", "seq", "act_vocab")


def cast_params(cfg, params):
    """Matmul weights (ndim>=2) -> compute dtype; vectors stay float32
    (norm scales, A_log/lam/dt_bias gates are precision-sensitive)."""
    cdt = jnp.dtype(cfg.compute_dtype)

    def cast(x):
        return x.astype(cdt) if x.ndim >= 2 else x.astype(jnp.float32)
    return jax.tree_util.tree_map(cast, params)


# ---------------------------------------------------------------------------
# cache structure (SpecDefs mirror forward()'s cache pytree exactly)
# ---------------------------------------------------------------------------
from dataclasses import dataclass


@dataclass(frozen=True)
class SpecDef:
    shape: tuple
    axes: tuple
    dtype: str = "bfloat16"


def _is_spec(x):
    return isinstance(x, SpecDef)


def _block_cache_defs(cfg, kind, batch, seq_len):
    bc = block_cfg_for(cfg, kind)
    cdt = cfg.compute_dtype
    if bc.kind == "attn":
        sh = attn_cache_shape(cfg, bc, batch, seq_len)
        ax = ("batch", "cache_seq", "act_kv", None)
        return (SpecDef(sh, ax, cdt), SpecDef(sh, ax, cdt))
    if bc.kind == "rglru":
        s = rglru_cache_specs(cfg, batch)
        return {"conv": SpecDef(s["conv"], ("batch", None, "act_inner"), cdt),
                "h": SpecDef(s["h"], ("batch", "act_inner"), "float32")}
    s = ssd_cache_specs(cfg, batch)
    return {"conv": SpecDef(s["conv"], ("batch", None, "act_inner"), cdt),
            "state": SpecDef(s["state"], ("batch", "act_inner", None, None),
                             "float32")}


def _stack_spec(d: SpecDef, n: int) -> SpecDef:
    return SpecDef((n,) + d.shape, ("layers",) + d.axes, d.dtype)


def cache_defs(cfg, batch, seq_len) -> dict:
    pattern = cfg.block_pattern
    n_cyc, _ = divmod(cfg.num_layers, len(pattern))
    out: dict = {}
    if n_cyc:
        out["cycle"] = {
            f"p{j}": jax.tree_util.tree_map(
                lambda d: _stack_spec(d, n_cyc),
                _block_cache_defs(cfg, k, batch, seq_len), is_leaf=_is_spec)
            for j, k in enumerate(pattern)}
    rem_kinds = cfg.layer_kinds()[n_cyc * len(pattern):]
    for i, k in enumerate(rem_kinds):
        out[f"rem{i}"] = _block_cache_defs(cfg, k, batch, seq_len)
    return out
