"""RecurrentGemma / Griffin temporal-mixing block: RG-LRU linear recurrence.

Block layout (arXiv:2402.19427): two parallel branches off the input —
  gate branch: linear -> GeLU
  lru branch:  linear -> causal conv1d -> RG-LRU
merged multiplicatively, then projected back to d_model.

RG-LRU recurrence (per channel, diagonal):
  r_t = sigmoid(W_a x_t)            recurrence gate
  i_t = sigmoid(W_x x_t)            input gate
  a_t = exp(-c * softplus(Lambda) * r_t)   with c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill evaluate the recurrence with an associative scan
(`jax.lax.associative_scan`) — O(log T) depth; decode is the O(1) update.
The pure-jnp `lru_scan` here is the oracle for the Pallas `rglru_scan`
kernel (kernels/ref.py re-exports it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.params import ParamDef
from repro.models.ssm import causal_conv, conv_step

RG_LRU_C = 8.0


def rglru_defs(cfg) -> dict:
    D, W = cfg.d_model, (cfg.lru_width or cfg.d_model)
    return {
        "in_x": ParamDef((D, W), ("embed", "lru")),
        "in_gate": ParamDef((D, W), ("embed", "lru")),
        "conv_w": ParamDef((cfg.conv_width, W), ("conv", "lru")),
        "conv_b": ParamDef((W,), ("lru",), "zeros"),
        "w_a": ParamDef((W, W), ("lru", None)),
        "b_a": ParamDef((W,), (None,), "zeros"),
        "w_i": ParamDef((W, W), ("lru", None)),
        "b_i": ParamDef((W,), (None,), "zeros"),
        "lam": ParamDef((W,), (None,), "lru_lambda"),
        "out": ParamDef((W, D), ("lru", "embed")),
    }


def _gates(p, x):
    """x: (..., W) -> (log_a, gated_input) both (..., W), float32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xf, p["w_a"].astype(jnp.float32))
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xf, p["w_i"].astype(jnp.float32))
                       + p["b_i"].astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated = i * xf
    return log_a, gated


def lru_scan(log_a, b, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    log_a, b: (B, T, W) float32; h0: (B, W) or None. Returns (y, h_final):
    y (B,T,W) = all h_t; h_final (B,W).
    """
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold h0 into the first step: b_0' = a_0 * h0 + b_0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    ys = jax.lax.associative_scan(combine, (a, b), axis=1)[1]
    return ys, ys[:, -1, :]


def lru_step(log_a_t, b_t, h):
    """One decode step: (B,W) each. Returns (y, new_h)."""
    a = jnp.exp(log_a_t)
    new = a * h + b_t
    return new, new


def rglru_block(cfg, p, x, mode, cache=None, use_pallas=False):
    """Temporal-mixing half of a griffin layer. x: (B,T,D) (pre-normed).

    cache (decode): {"conv": (B, cw-1, W), "h": (B, W)}.
    Returns (out (B,T,D), new_cache) — new_cache also produced by prefill.
    """
    B, T, D = x.shape
    W = cfg.lru_width or cfg.d_model
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["in_gate"]))
    xb = jnp.einsum("btd,dw->btw", x, p["in_x"])
    xb = shard(xb, "batch", "seq", "act_inner")

    if mode in ("train", "prefill"):
        xc = causal_conv(xb, p["conv_w"], p["conv_b"])
        log_a, gated = _gates(p, xc)
        beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))        # sqrt(1 - a^2), stable
        b = beta * gated
        if use_pallas:
            from repro.kernels import ops as kops
            y, h_last = kops.rglru_scan(log_a, b)
        else:
            y, h_last = lru_scan(log_a, b)
        new_cache = None
        if mode == "prefill":
            tail = xb[:, -(cfg.conv_width - 1):, :]
            new_cache = {"conv": tail.astype(x.dtype), "h": h_last}
    else:  # decode, T == 1
        xb_t = xb[:, 0, :]
        xc_t, conv_cache = conv_step(xb_t, cache["conv"], p["conv_w"], p["conv_b"])
        log_a, gated = _gates(p, xc_t)
        beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
        y_t, h_new = lru_step(log_a, beta * gated, cache["h"].astype(jnp.float32))
        y = y_t[:, None, :]
        new_cache = {"conv": conv_cache.astype(x.dtype), "h": h_new}

    y = y.astype(x.dtype) * gate
    out = jnp.einsum("btw,wd->btd", y, p["out"])
    return shard(out, "batch", "seq", "act_embed"), new_cache


def rglru_cache_specs(cfg, batch):
    W = cfg.lru_width or cfg.d_model
    return {"conv": (batch, cfg.conv_width - 1, W), "h": (batch, W)}
