"""Model-level public API.

  param_defs / init_params        declarative params (single sharding source)
  loss_fn / make_train_step       training
  prefill / decode_step           serving
  input_specs / step_for_shape    allocation-free dry-run inputs (ShapeDtypeStruct)

Every entry point is mesh-polymorphic: sharding comes from the ambient
``use_sharding`` context plus the SpecDef/ParamDef logical axes, so the same
step function deploys to any GeoFF platform (single host, one pod, multi-pod).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.dist import sharding as shd
from repro.models import params as prm
from repro.models import transformer as tfm
from repro.models.transformer import SpecDef, _is_spec, cache_defs

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def param_defs(cfg) -> dict:
    return tfm.transformer_defs(cfg)


def init_params(cfg, key):
    return prm.init_params(param_defs(cfg), key, jnp.dtype(cfg.param_dtype))


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------
def _ce_terms(cfg, params, x, labels):
    """Cross-entropy pieces for hidden states x vs labels: (nll_sum, n_tok)."""
    logits = tfm.unembed(cfg, params, x)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(lp, labels_safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask), jnp.sum(mask)


def forward_train(cfg, params, batch):
    """Returns (loss, metrics). Labels are pre-shifted by the data pipeline."""
    p = tfm.cast_params(cfg, params)
    x = tfm.embed_inputs(cfg, p, batch)
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    x, _, aux = tfm.run_blocks(cfg, p, x, positions, "train")
    x = tfm.rmsnorm(x, p["final_norm"])
    labels = batch["labels"]
    if cfg.input_kind == "tokens+patches":
        x = x[:, x.shape[1] - labels.shape[1]:, :]
    Tl = labels.shape[1]
    if cfg.ce_chunk and Tl > cfg.ce_chunk and Tl % cfg.ce_chunk == 0:
        # seq-chunked CE: never materializes the full (B,T,V) float32 logits
        c = cfg.ce_chunk
        nll, ntok = jnp.float32(0.0), jnp.float32(0.0)
        for i in range(Tl // c):
            s, n = _ce_terms(cfg, p, x[:, i * c:(i + 1) * c, :],
                             labels[:, i * c:(i + 1) * c])
            nll, ntok = nll + s, ntok + n
    else:
        nll, ntok = _ce_terms(cfg, p, x, labels)
    ce = nll / jnp.maximum(ntok, 1.0)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": ntok.astype(jnp.int32)}


def prefill(cfg, params, batch):
    """Full-sequence forward that also returns the layer caches.

    Returns (last_logits (B,V) float32, caches). Cache sequence capacity is
    the prompt length; serving pads to the generation budget
    (serving/engine.pad_cache).
    """
    p = tfm.cast_params(cfg, params)
    x = tfm.embed_inputs(cfg, p, batch)
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    mode = "prefill" if cfg.supports_decode else "train"  # encoders: no cache
    x, caches, _ = tfm.run_blocks(cfg, p, x, positions, mode)
    x = tfm.rmsnorm(x, p["final_norm"])
    logits = tfm.unembed(cfg, p, x[:, -1:, :])
    return logits[:, 0, :].astype(jnp.float32), (caches or {})


def decode_step(cfg, params, token, caches, cur_index):
    """One autoregressive step.

    token: (B, 1) int32; cur_index: scalar int32 — the absolute position the
    new token occupies (its KV lands at ``cur_index % window`` for local
    layers). Returns (logits (B, V) float32, new_caches).
    """
    p = tfm.cast_params(cfg, params)
    x = tfm.embed_inputs(cfg, p, {"tokens": token})
    positions = jnp.full((1,), cur_index, dtype=jnp.int32)
    x, caches, _ = tfm.run_blocks(cfg, p, x, positions, "decode", caches,
                                  cur_index)
    x = tfm.rmsnorm(x, p["final_norm"])
    logits = tfm.unembed(cfg, p, x)
    return logits[:, 0, :].astype(jnp.float32), caches


# ---------------------------------------------------------------------------
# train step factory
# ---------------------------------------------------------------------------
def make_train_step(cfg, optimizer, num_microbatches: int = 1):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``num_microbatches > 1`` runs gradient accumulation as a scan over
    microbatches (memory, not throughput).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda pp: forward_train(cfg, pp, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        if num_microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def mb(carry, mbatch):
                gsum = carry
                (mb_loss, m), g = grads_of(params, mbatch)
                return jax.tree_util.tree_map(jnp.add, gsum, g), (mb_loss, m)

            split = jax.tree_util.tree_map(
                lambda x: x.reshape((num_microbatches,
                                     x.shape[0] // num_microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            gsum, (ls, ms) = jax.lax.scan(mb, zeros, split)
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, gsum)
            loss = jnp.mean(ls)
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        params, opt_state, gnorm = optimizer.update(params, opt_state, grads,
                                                    step)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# microbatched training (production path): the jit'd unit is one microbatch
# grad step; the optimizer applies on the accumulation boundary. Two small
# programs instead of one giant scan keeps the dry-run HLO exact (XLA counts
# while-loop bodies once) and matches how the GeoFF trainer choreographs
# steps (data prefetch overlaps the previous micro step).
# ---------------------------------------------------------------------------
def make_micro_step(cfg):
    """(params, grad_acc, batch_micro) -> (grad_acc', (loss, metrics)).

    grad_acc mirrors params in float32 and is donated; grads arrive already
    reduced over the batch axes (pjit inserts the reduce-scatter/all-reduce
    for the sharded param axes automatically).
    """

    def micro_step(params, grad_acc, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: forward_train(cfg, p, batch), has_aux=True)(params)
        grad_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
        return grad_acc, (loss, metrics)

    return micro_step


def make_apply_step(cfg, optimizer, num_microbatches: int):
    """(params, opt_state, grad_acc, step) -> (params', opt_state', zeros)."""

    def apply_step(params, opt_state, grad_acc, step):
        grads = jax.tree_util.tree_map(
            lambda g: g / float(num_microbatches), grad_acc)
        params, opt_state, gnorm = optimizer.update(params, opt_state, grads,
                                                    step)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, grad_acc)
        return params, opt_state, zeros, gnorm

    return apply_step


def grad_acc_defs(pdefs):
    from repro.models.params import ParamDef
    return jax.tree_util.tree_map(
        lambda d: ParamDef(d.shape, d.axes, "zeros"), pdefs,
        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# spec helpers (SpecDef / ParamDef -> ShapeDtypeStruct / PartitionSpec)
# ---------------------------------------------------------------------------
def spec_structs(defs, rules=None, mesh=None):
    def mk(d: SpecDef):
        sh = None
        if rules is not None and mesh is not None:
            sh = NamedSharding(mesh, shd.pspec_for(d.shape, d.axes, rules, mesh))
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype), sharding=sh)
    return jax.tree_util.tree_map(mk, defs, is_leaf=_is_spec)


def spec_pspecs(defs, rules, mesh):
    return jax.tree_util.tree_map(
        lambda d: shd.pspec_for(d.shape, d.axes, rules, mesh), defs,
        is_leaf=_is_spec)


def spec_zeros(defs):
    return jax.tree_util.tree_map(
        lambda d: jnp.zeros(d.shape, jnp.dtype(d.dtype)), defs,
        is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# batch / input specs per (arch x shape) cell
# ---------------------------------------------------------------------------
def batch_defs(cfg, shape) -> dict:
    """SpecDefs for one batch of the given ShapeSpec (train/prefill kinds)."""
    B, T = shape.global_batch, shape.seq_len
    cdt = cfg.compute_dtype
    if cfg.input_kind == "frames":
        d = {"frames": SpecDef((B, T, cfg.d_model), ("batch", "seq", None), cdt)}
        if shape.kind == "train":
            d["labels"] = SpecDef((B, T), ("batch", "seq"), "int32")
        return d
    if cfg.input_kind == "tokens+patches":
        P_ = cfg.num_patches
        Ttxt = T - P_
        d = {"tokens": SpecDef((B, Ttxt), ("batch", "seq"), "int32"),
             "patches": SpecDef((B, P_, cfg.d_model), ("batch", "seq", None), cdt)}
        if shape.kind == "train":
            d["labels"] = SpecDef((B, Ttxt), ("batch", "seq"), "int32")
        return d
    d = {"tokens": SpecDef((B, T), ("batch", "seq"), "int32")}
    if shape.kind == "train":
        d["labels"] = SpecDef((B, T), ("batch", "seq"), "int32")
    return d


def decode_input_defs(cfg, shape) -> dict:
    """SpecDefs for one decode step: token + caches at capacity seq_len."""
    B, T = shape.global_batch, shape.seq_len
    return {"token": SpecDef((B, 1), ("batch", "seq"), "int32"),
            "caches": cache_defs(cfg, B, T),
            "cur_index": SpecDef((), (), "int32")}


def input_specs(cfg, shape, rules=None, mesh=None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    if shape.kind == "decode":
        return spec_structs(decode_input_defs(cfg, shape), rules, mesh)
    return spec_structs(batch_defs(cfg, shape), rules, mesh)
