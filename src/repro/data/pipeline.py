"""Token data pipeline: synthetic corpus -> sharded loader -> GeoFF prefetch.

The corpus is deterministic (seeded PRNG, skip-ahead addressable by step), so
restarts reproduce the exact token stream from any step — a requirement for
checkpoint/restart determinism (tests/test_checkpoint.py asserts it).

The loader yields GLOBAL batches as numpy and the iterator stage device-puts
them with the batch sharding; ``make_train_iterator`` wraps it in the GeoFF
``DoubleBuffer`` so batch k+1's generation + host->device transfer overlap
step k's compute (the data-pipeline instance of the paper's pre-fetching).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core.prefetch import DoubleBuffer
from repro.dist import sharding as shd


class SyntheticCorpus:
    """An infinite, step-addressable stream of (tokens, labels) batches.

    Documents are Zipf-ish token sequences with document separators — enough
    structure for a language-model loss to fall during the example runs.
    """

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.seed = seed

    def batch(self, step: int, batch_size: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # zipf-ish unigram stream with a repeated-bigram structure so the
        # model has something learnable
        base = rng.zipf(1.3, size=(batch_size, self.seq + 1))
        toks = (base % (self.vocab - 2)).astype(np.int32) + 1
        # inject determinism-friendly structure: even positions repeat
        toks[:, 2::2] = toks[:, 1:-1:2]
        tokens = toks[:, :-1]
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


class ShardedLoader:
    """Yields consecutive global batches starting at `start_step`."""

    def __init__(self, corpus: SyntheticCorpus, batch_size: int,
                 start_step: int = 0):
        self.corpus = corpus
        self.batch_size = batch_size
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        b = self.corpus.batch(self.step, self.batch_size)
        self.step += 1
        return b


def shard_batch(batch, mesh, rules):
    """numpy batch -> sharded device arrays per the batch rules."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = shd.pspec_for(v.shape, ("batch",) + (None,) * (v.ndim - 1),
                             rules, mesh)
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def make_train_iterator(cfg, seq_len: int, batch_size: int, mesh=None,
                        rules=None, start_step: int = 0, seed: int = 0,
                        prefetch_depth: int = 2):
    corpus = SyntheticCorpus(cfg.vocab_size, seq_len, seed)
    loader = ShardedLoader(corpus, batch_size, start_step)
    return DoubleBuffer(loader, depth=prefetch_depth,
                        transform=lambda b: shard_batch(b, mesh, rules))
