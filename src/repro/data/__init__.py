from repro.data.pipeline import (SyntheticCorpus, ShardedLoader,  # noqa: F401
                                 make_train_iterator)
