"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run process
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (smoke tests, benches) sees the real single device.

Mesh geometry (TPU v5e pods as the reference target):
  single-pod:  (data=16, model=16)           = 256 chips, ICI everywhere
  multi-pod:   (pod=2, data=16, model=16)    = 512 chips; the leading "pod"
               axis crosses DCN — gradient reduction over "pod" is the only
               cross-pod collective on the train path (see dist/sharding.py).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dry-run "
            "only)")
    return jax.sharding.Mesh(
        np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh(model_parallel: int = 1):
    """A mesh over whatever devices actually exist (smoke tests, examples)."""
    devs = jax.devices()
    n = len(devs)
    dp = n // model_parallel
    return jax.sharding.Mesh(
        np.asarray(devs[:dp * model_parallel]).reshape(dp, model_parallel),
        ("data", "model"))


# TPU v5e hardware constants for the roofline terms (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (~4 links usable per chip)
DCN_BW = 25e9                 # B/s per host crossing pods
