"""Meshes, the multi-pod dry-run, and HLO accounting tools."""
