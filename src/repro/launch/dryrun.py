import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# This flag is dry-run-only: smoke tests and benches see the real device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell, build the production
mesh, lower the cell's step program(s) with sharded ShapeDtypeStruct inputs
(no allocation), ``.compile()`` them, and record ``memory_analysis()`` +
``cost_analysis()`` + the HLO collective schedule. Output JSON feeds
benchmarks/roofline.py.

Train cells lower TWO programs, matching the production trainer: the
microbatch grad step (fwd+bwd+accumulate; run n_micro times per step) and
the optimizer apply step. The dry-run unrolls the layer loop so XLA's
cost_analysis counts every matmul and collective exactly (XLA counts
while-loop bodies once — verified); the remaining inner scans (long-prefill
attention chunks, SSD chunks) get closed-form corrections from
launch/analytic.py.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --both-meshes      # every live cell
  ... --set seq_shard_attn=true --tag variant_seqshard
"""
import argparse
import json
import math
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.base import SHAPES, applicable_shapes
from repro.dist import sharding as shd
from repro.launch import mesh as meshlib
from repro.launch.analytic import CellModel
from repro.models import model as M
from repro.models import params as prm
from repro.optim import AdamW

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
MICRO_TOKENS_PER_DEV = 4096   # microbatch sizing target (activation memory)

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f32|f16|bf16|f64|s32|s64|s8|u8|u32|pred|s16|u16)"
                       r"\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "f16": 2,
                "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, pod_count: int = 1) -> dict:
    """Per-device collective traffic from the post-optimization SPMD HLO.

    Wire-bytes use the ring model: all-gather / reduce-scatter move
    ~shard-size x (G-1) bytes per device; all-reduce ~2x that. Collectives
    whose group size equals the pod count are attributed to DCN (the 'pod'
    axis is the only size-2 axis in the multi-pod mesh), the rest to ICI.
    """
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        out_bytes = _shape_bytes(m.group(1))
        opcode = m.group(2)
        gm = _GROUPS_RE.search(line)
        n_groups, group_size = (int(gm.group(1)), int(gm.group(2))) if gm \
            else (1, 1)
        if opcode == "all-gather":
            wire = out_bytes * (group_size - 1) / max(group_size, 1)
        elif opcode == "reduce-scatter":
            wire = out_bytes * (group_size - 1)  # output is the small side
        elif opcode == "all-reduce":
            wire = 2 * out_bytes * (group_size - 1) / max(group_size, 1)
        elif opcode == "all-to-all":
            wire = out_bytes * (group_size - 1) / max(group_size, 1)
        else:  # collective-permute
            wire = out_bytes
        ops.append({"op": opcode, "bytes": out_bytes, "wire_bytes": wire,
                    "group_size": group_size, "n_groups": n_groups})
    dcn = sum(o["wire_bytes"] for o in ops
              if pod_count > 1 and o["group_size"] == pod_count)
    ici = sum(o["wire_bytes"] for o in ops) - dcn
    by_op: dict = {}
    for o in ops:
        d = by_op.setdefault(o["op"], {"count": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += o["wire_bytes"]
    return {"num_collectives": len(ops), "ici_wire_bytes": ici,
            "dcn_wire_bytes": dcn, "by_op": by_op}


# ---------------------------------------------------------------------------
# per-cell execution defaults (the baseline; --set overrides for hillclimbs)
# ---------------------------------------------------------------------------
def cell_defaults(cfg, shape):
    kw = {"scan_layers": False}           # exact HLO accounting (layer loop)
    if shape.kind == "train":
        kw["remat"] = cfg.remat if cfg.remat != "none" else "full"
        kw["attn_chunk_q"] = cfg.attn_chunk_q or 512
        # lax.scan over q-chunks (memory: one chunk live at a time; flops
        # corrected analytically — validated vs the unrolled variant)
        kw["attn_chunk_unroll"] = False
        if cfg.vocab_size % 16 != 0 and cfg.vocab_size > 10_000:
            kw["ce_chunk"] = cfg.ce_chunk or 512
    elif shape.kind == "prefill":
        kw["attn_chunk_q"] = cfg.attn_chunk_q or 256
        kw["attn_chunk_unroll"] = False   # lax.scan; analytic correction
    return cfg.replace(**kw)


def micro_batch_plan(shape, mesh, micro_tokens=None):
    """(micro_global_batch, n_micro) for train cells."""
    batch_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev = max(1, (micro_tokens or MICRO_TOKENS_PER_DEV) // shape.seq_len)
    micro = min(shape.global_batch, per_dev * batch_shards)
    n_micro = max(1, shape.global_batch // micro)
    return micro, n_micro


def _analyze(compiled, pod_count):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    try:
        ma = compiled.memory_analysis()
        mem = {k: getattr(ma, k) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes")}
    except Exception:
        mem = {}
    coll = parse_collectives(compiled.as_text(), pod_count)
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            mem, coll)


def apply_overrides(cfg, sets):
    for kv in sets or []:
        k, v = kv.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        elif isinstance(cur, tuple):
            v = tuple(v.split(","))
        cfg = cfg.replace(**{k: v})
    return cfg


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------
DIFF_CYCLES = (2, 4)   # lowered depths for the depth-differencing estimator


def _model_program_metrics(cfg, shape, mesh, rules, pod_count,
                           micro_global):
    """Analysis dict {flops, bytes, ici, dcn, temp} for the model-bearing
    program (micro grad step / prefill / decode) at cfg's FULL depth.

    Train and prefill use DEPTH DIFFERENCING: the layer stack is a repeated
    cycle, so lowering the model at 2 and 4 cycles and extrapolating
    per-cycle deltas reproduces the full-depth unrolled counts exactly
    (validated vs a full unroll on llama: <2% — see EXPERIMENTS.md §Dry-run)
    while compiling ~10x faster on this 1-core host. Decode compiles fast
    and is lowered at full depth.
    """
    pattern = len(cfg.block_pattern)
    n_cyc, n_rem = divmod(cfg.num_layers, pattern)

    def lower_at(num_layers, use_cfg):
        c = use_cfg.replace(num_layers=num_layers)
        pdefs = M.param_defs(c)
        pstructs = prm.abstract_params(pdefs, jnp.dtype(c.param_dtype),
                                       rules, mesh)
        if shape.kind == "train":
            mshape = shape.__class__(shape.name, shape.seq_len, micro_global,
                                     "train")
            batch = M.input_specs(c, mshape, rules, mesh)
            gstructs = prm.abstract_params(M.grad_acc_defs(pdefs),
                                           jnp.float32, rules, mesh)
            micro_step = M.make_micro_step(c)

            def fn(params, grad_acc, b):
                with shd.use_sharding(mesh, rules):
                    return micro_step(params, grad_acc, b)

            compiled = jax.jit(fn, donate_argnums=(1,)).lower(
                pstructs, gstructs, batch).compile()
        elif shape.kind == "prefill":
            batch = M.input_specs(c, shape, rules, mesh)

            def fn(params, b):
                with shd.use_sharding(mesh, rules):
                    return M.prefill(c, params, b)

            compiled = jax.jit(fn).lower(pstructs, batch).compile()
        else:
            specs = M.input_specs(c, shape, rules, mesh)

            def fn(params, token, caches, cur_index):
                with shd.use_sharding(mesh, rules):
                    return M.decode_step(c, params, token, caches, cur_index)

            compiled = jax.jit(fn, donate_argnums=(2,)).lower(
                pstructs, specs["token"], specs["caches"],
                specs["cur_index"]).compile()
        flops, bts, mem, coll = _analyze(compiled, pod_count)
        cm = CellModel(c, shape, dict(mesh.shape), micro_global)
        return {"flops": flops + cm.corrections_dev(),
                "bytes": bts + cm.bytes_corrections_dev(),
                "ici": coll["ici_wire_bytes"],
                "dcn": coll["dcn_wire_bytes"],
                "temp": mem.get("temp_size_in_bytes", 0),
                "coll_detail": coll}

    if shape.kind == "decode" or n_cyc <= max(DIFF_CYCLES):
        return lower_at(cfg.num_layers, cfg)

    lo, hi = DIFF_CYCLES
    a = lower_at(lo * pattern + n_rem, cfg)
    b = lower_at(hi * pattern + n_rem, cfg)
    out = {}
    for k in ("flops", "bytes", "ici", "dcn", "temp"):
        per_cycle = (b[k] - a[k]) / (hi - lo)
        out[k] = a[k] + per_cycle * (n_cyc - lo)
    out["coll_detail"] = b["coll_detail"]
    out["diff_estimator"] = {"lo_cycles": lo, "hi_cycles": hi,
                             "n_cycles": n_cyc}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, sets=None,
             tag: str = "", out_dir: str = OUT_DIR, verbose: bool = True,
             micro_tokens: int = 0):
    shape = SHAPES[shape_name]
    cfg = apply_overrides(cell_defaults(get_config(arch), shape), sets)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.shape.values())
    pod_count = mesh.shape.get("pod", 1)
    rules = shd.rules_for(shape.kind, multi_pod=multi_pod,
                          seq_shard_attn=cfg.seq_shard_attn,
                          seq_shard_resid=cfg.seq_shard_resid)
    t0 = time.time()

    if shape.kind == "train":
        micro_global, n_micro = micro_batch_plan(shape, mesh,
                                                 micro_tokens or None)
    else:
        micro_global, n_micro = shape.global_batch, 1

    model_m = _model_program_metrics(cfg, shape, mesh, rules, pod_count,
                                     micro_global)

    apply_m = None
    if shape.kind == "train":
        pdefs = M.param_defs(cfg)
        pstructs = prm.abstract_params(pdefs, jnp.dtype(cfg.param_dtype),
                                       rules, mesh)
        opt = AdamW()
        ostructs = prm.abstract_params(opt.state_defs(pdefs), jnp.float32,
                                       rules, mesh)
        gstructs = prm.abstract_params(M.grad_acc_defs(pdefs), jnp.float32,
                                       rules, mesh)
        apply_step = M.make_apply_step(cfg, opt, n_micro)

        def apply_fn(params, opt_state, grad_acc, step):
            with shd.use_sharding(mesh, rules):
                return apply_step(params, opt_state, grad_acc, step)

        c_apply = jax.jit(apply_fn, donate_argnums=(0, 1, 2)).lower(
            pstructs, ostructs, gstructs,
            jax.ShapeDtypeStruct((), jnp.int32)).compile()
        af, ab, am, ac = _analyze(c_apply, pod_count)
        apply_m = {"flops": af, "bytes": ab, "ici": ac["ici_wire_bytes"],
                   "dcn": ac["dcn_wire_bytes"],
                   "temp": am.get("temp_size_in_bytes", 0)}

    t_compile = time.time() - t0
    cm = CellModel(cfg, shape, dict(mesh.shape), micro_global)
    corr = cm.corrections_dev()

    # aggregate per full step (n_micro x model program + apply)
    flops_dev = model_m["flops"] * n_micro
    bytes_dev = model_m["bytes"] * n_micro
    ici = model_m["ici"] * n_micro
    dcn = model_m["dcn"] * n_micro
    peak_temp = model_m["temp"]
    if apply_m:
        flops_dev += apply_m["flops"]
        bytes_dev += apply_m["bytes"]
        ici += apply_m["ici"]
        dcn += apply_m["dcn"]
        peak_temp = max(peak_temp, apply_m["temp"])

    t_compute = flops_dev / meshlib.PEAK_FLOPS_BF16
    t_memory = bytes_dev / meshlib.HBM_BW
    t_coll = ici / meshlib.ICI_BW + dcn / meshlib.DCN_BW

    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * cfg.active_param_count() * tokens
    useful = model_flops / max(flops_dev * n_dev, 1.0)

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod_2x16x16" if multi_pod else "pod_16x16",
        "tag": tag, "overrides": list(sets or []),
        "n_devices": n_dev, "n_micro": n_micro,
        "micro_global_batch": micro_global,
        "compile_s": round(t_compile, 2),
        "flops_per_dev_step": flops_dev,
        "bytes_per_dev_step": bytes_dev,
        "scan_correction_flops_dev": corr,
        "diff_estimator": model_m.get("diff_estimator"),
        "collectives": {
            "ici_wire_bytes": ici, "dcn_wire_bytes": dcn,
            "by_op": model_m["coll_detail"]["by_op"]},
        "peak_temp_bytes": peak_temp,
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll,
            "bottleneck": max(
                (("compute", t_compute), ("memory", t_memory),
                 ("collective", t_coll)), key=lambda kv: kv[1])[0],
            "step_s_lower_bound": max(t_compute, t_memory, t_coll),
        },
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "analytic_flops_dev": cm.model_flops_analytic_dev() * n_micro,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch.replace('.', '_')}__{shape_name}__" \
           f"{'mp' if multi_pod else 'sp'}{('__' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(res, f, indent=1)
    if verbose:
        print(f"[dryrun] {arch} {shape_name} "
              f"{'mp' if multi_pod else 'sp'} "
              f"compile={t_compile:.1f}s n_micro={n_micro} "
              f"compute={t_compute*1e3:.2f}ms memory={t_memory*1e3:.2f}ms "
              f"collective={t_coll*1e3:.2f}ms "
              f"bottleneck={res['roofline']['bottleneck']} "
              f"useful={useful:.2%} peak_temp={peak_temp/2**30:.2f}GiB",
              flush=True)
    return res


def live_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--set", action="append", dest="sets", default=[])
    ap.add_argument("--tag", default="")
    ap.add_argument("--micro-tokens", type=int, default=0,
                    help="override microbatch tokens/device (perf lever)")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args(argv)

    cells = list(live_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, args.sets, args.tag, args.out_dir,
                         micro_tokens=args.micro_tokens)
            except Exception:
                failures.append((arch, shape, mp))
                traceback.print_exc()
            finally:
                jax.clear_caches()   # keep the 62-cell sweep bounded in RAM
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print(f"dry-run OK: {len(cells) * len(meshes)} cells")


if __name__ == "__main__":
    main()
