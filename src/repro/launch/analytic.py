"""Analytic FLOP model: inner-scan corrections + full-model cross-check.

XLA's ``cost_analysis`` counts a while-loop body ONCE, not x trip-count
(verified in scripts/probe_dryrun.py). The dry-run therefore unrolls the
layer loop (exact accounting for matmuls AND collectives), and the only
loops left in the lowered programs are:

  - the q-chunk attention scan (``attn_chunk_unroll=False``, long prefills)
  - the Mamba-2 SSD chunk scan

Both have closed-form per-trip FLOPs, so the dry-run adds
``body_flops x (trips - 1)`` per instance. ``model_flops_analytic`` is the
independent full-model estimate used to validate HLO counts on small
unrolled configs (tests/test_dryrun.py) and to compute the useful-FLOPs
ratio 6·N_active·D / total.
"""
from __future__ import annotations


from repro.configs.base import ArchConfig, ShapeSpec


def _shards(n: int, ax: int) -> int:
    """Ways an n-sized dim actually shards over an ax-way mesh axis."""
    return ax if (ax and n % ax == 0) else 1


class CellModel:
    """Closed-form per-device FLOPs for one (arch, shape, mesh) cell."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, mesh_shape: dict,
                 micro_global_batch: int = 0):
        self.cfg, self.shape = cfg, shape
        self.model_ax = mesh_shape.get("model", 1)
        self.batch_shards = (mesh_shape.get("data", 1)
                             * mesh_shape.get("pod", 1))
        B = micro_global_batch or shape.global_batch
        self.B_d = max(1, B // self.batch_shards)
        self.T = shape.seq_len
        # train multiplier: fwd + remat-refwd + 2x bwd (full remat)
        self.mult = {"train": 4.0, "prefill": 1.0, "decode": 1.0}[shape.kind]

    # -- attention ------------------------------------------------------------
    def attn_layer_flops_dev(self, window=None) -> float:
        """Per-device quadratic-attention FLOPs for ONE layer, fwd only."""
        cfg = self.cfg
        H_d = self.cfg.num_heads // _shards(cfg.num_heads, self.model_ax)
        T_d = self.T // (_shards(self.T, self.model_ax)
                         if cfg.seq_shard_attn else 1)
        if cfg.seq_shard_attn:      # seq- and head-sharding are alternatives
            H_d = cfg.num_heads
        chunk = cfg.attn_chunk_q or self.T
        S_eff = self.T if window is None else min(self.T, window + chunk)
        return 4.0 * self.B_d * H_d * T_d * S_eff * cfg.head_dim

    def attn_scan_correction_dev(self, n_layers_global, n_layers_local) -> float:
        """Extra FLOPs XLA missed for scanned q-chunk attention."""
        cfg = self.cfg
        if cfg.attn_chunk_unroll or not cfg.attn_chunk_q \
                or self.T <= cfg.attn_chunk_q:
            return 0.0
        n = self.T // cfg.attn_chunk_q
        f = (n_layers_global * self.attn_layer_flops_dev(None)
             + n_layers_local * self.attn_layer_flops_dev(cfg.local_window))
        return f * (n - 1) / n * self.mult

    # -- mamba2 SSD -------------------------------------------------------------
    def ssd_layer_flops_dev(self) -> float:
        cfg = self.cfg
        H = cfg.ssm_heads
        H_d = H // _shards(cfg.d_inner, self.model_ax)  # act_inner sharding
        P, N, Q = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
        B, T = self.B_d, self.T
        # CB (2TQN) + y_intra (2TQ H P) + states (2T H P N) + y_inter (4T H P N)
        return B * T * (2.0 * Q * N + 2.0 * Q * H_d * P
                        + 6.0 * H_d * P * N)

    def ssd_scan_correction_dev(self, n_ssd_layers: int) -> float:
        cfg = self.cfg
        if not n_ssd_layers or self.T <= cfg.ssm_chunk:
            return 0.0
        nc = self.T // cfg.ssm_chunk
        return (self.ssd_layer_flops_dev() * (nc - 1) / nc * n_ssd_layers
                * self.mult)

    def corrections_dev(self) -> float:
        kinds = self.cfg.layer_kinds()
        if self.shape.kind == "decode":
            return 0.0
        return (self.attn_scan_correction_dev(
                    kinds.count("global"), kinds.count("local"))
                + self.ssd_scan_correction_dev(kinds.count("ssd")))

    def bytes_corrections_dev(self) -> float:
        """HBM-byte corrections for loop bodies XLA counted once.

        Uses FLASH-ATTENTION I/O semantics for the q-chunk loop (the TPU
        target never writes O(T^2) scores to HBM — our Pallas kernel keeps
        score tiles in VMEM): each extra chunk re-reads K/V and does one
        q/o chunk r/w. The SSD loop correction uses an arithmetic-intensity
        heuristic (~8 flop/byte for its small einsums).
        """
        cfg = self.cfg
        if self.shape.kind == "decode":
            return 0.0
        kinds = cfg.layer_kinds()
        total = 0.0
        if (not cfg.attn_chunk_unroll and cfg.attn_chunk_q
                and self.T > cfg.attn_chunk_q):
            n = self.T // cfg.attn_chunk_q
            K_d = cfg.num_kv_heads // _shards(cfg.num_kv_heads, self.model_ax)
            H_d = cfg.num_heads // _shards(cfg.num_heads, self.model_ax)
            kv = 2.0 * self.B_d * self.T * K_d * cfg.head_dim * 2  # bf16
            qo = 2.0 * self.B_d * self.T * H_d * cfg.head_dim * 2
            n_attn = kinds.count("global") + kinds.count("local")
            total += (n - 1) * (kv + qo / n) * n_attn * self.mult
        if kinds.count("ssd") and self.T > cfg.ssm_chunk:
            nc = self.T // cfg.ssm_chunk
            total += (self.ssd_layer_flops_dev() / 8.0 * (nc - 1) / nc
                      * kinds.count("ssd") * self.mult)
        return total

    # -- HBM traffic model --------------------------------------------------------
    def hbm_bytes_dev(self, n_micro: int = 1, params_total: int = 0) -> float:
        """Analytic per-device HBM bytes for ONE FULL STEP (n_micro micro
        steps + apply for train). XLA's 'bytes accessed' is a pre-fusion
        upper bound (measured 10-100x the touched bytes on the CPU backend),
        so the roofline memory term uses this model instead; the raw XLA
        number is recorded alongside as the upper bound.

        Model: weights are FSDP-gathered per pass (bf16, /model-shards
        resident view), activations make ~2 HBM round-trips per major tensor
        per pass, 3 passes for train (fwd + remat-refwd + bwd), 1 otherwise;
        KV caches are written at prefill and read at decode; flash-attention
        K/V reloads are already in bytes_corrections_dev.
        """
        cfg = self.cfg
        mx = self.model_ax
        P = params_total or cfg.param_count()
        passes = 3.0 if self.shape.kind == "train" else 1.0
        T = 1 if self.shape.kind == "decode" else self.T
        tok = self.B_d * T

        # weights touched per pass: gathered over data, still sharded over
        # model where the axes divide (~dominant matrices do)
        w_pass = 2.0 * P / mx
        weights = passes * w_pass * n_micro
        if self.shape.kind == "train":
            weights += n_micro * 8.0 * P / (mx * self.batch_shards)  # grad acc
            weights += 28.0 * P / (mx * self.batch_shards)           # apply

        # activations: bytes per token per layer (bf16, ~2 r/w per tensor)
        kinds = cfg.layer_kinds()
        act_per_tok = 0.0
        for k in kinds:
            D = cfg.d_model
            c = 8.0 * D                                   # residual stream
            if k in ("global", "local"):
                H_d = cfg.num_heads // _shards(cfg.num_heads, mx)
                K_d = cfg.num_kv_heads // _shards(cfg.num_kv_heads, mx)
                c += 4.0 * (H_d + K_d) * cfg.head_dim
            elif k == "ssd":
                c += 6.0 * cfg.d_inner / _shards(cfg.d_inner, mx)
            elif k == "rglru":
                W = cfg.lru_width or D
                c += 6.0 * W / _shards(W, mx)
            if cfg.d_ff and k != "ssd":
                if cfg.num_experts:
                    c += 4.0 * cfg.top_k * D              # dispatch+combine
                    c += 4.0 * cfg.d_ff * cfg.top_k / _shards(cfg.d_ff, mx)
                else:
                    c += 4.0 * cfg.d_ff / _shards(cfg.d_ff, mx)
            act_per_tok += c
        act = passes * tok * act_per_tok * 2.0 * n_micro  # bf16

        # logits / CE (train): bf16 logits + f32 softmax r/w
        V_d = cfg.vocab_size / _shards(cfg.vocab_size, mx)
        logits = (tok * V_d * 10.0 * n_micro
                  if self.shape.kind == "train" else self.B_d * V_d * 6.0)

        # caches
        cache = 0.0
        for k in kinds:
            if k in ("global", "local"):
                S = self.shape.seq_len if k == "global" else min(
                    cfg.local_window, self.shape.seq_len)
                S_d = S / _shards(S, mx)
                per = 2.0 * self.B_d * S_d * cfg.num_kv_heads * cfg.head_dim \
                    * 2.0
                if self.shape.kind == "prefill":
                    cache += per                           # write k,v
                elif self.shape.kind == "decode":
                    cache += per                           # read k,v
            elif k in ("ssd", "rglru") and self.shape.kind != "train":
                cache += 4.0 * self.B_d * (cfg.d_inner if k == "ssd"
                                           else (cfg.lru_width or cfg.d_model))
        return weights + act + logits + cache + self.bytes_corrections_dev()

    # -- full model (validation / useful-ratio) ---------------------------------
    def model_flops_analytic_dev(self) -> float:
        """Independent per-device estimate of the whole step, fwd-only base
        x train multiplier. Matmul terms only (elementwise is noise)."""
        cfg = self.cfg
        B, T = self.B_d, self.T if self.shape.kind != "decode" else 1
        mx = self.model_ax
        kinds = cfg.layer_kinds()
        f = 0.0
        # per-layer projections + mixers
        for k in kinds:
            if k in ("global", "local"):
                H, K, hd, D = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                               cfg.d_model)
                H_s, K_s = _shards(H, mx), _shards(K, mx)
                f += 2.0 * B * T * D * (H * hd / H_s + 2 * K * hd / K_s
                                        + H * hd / H_s)
                if self.shape.kind == "decode":
                    S = self.shape.seq_len if k == "global" else \
                        min(cfg.local_window, self.shape.seq_len)
                    S_d = S / _shards(S, mx)   # cache seq-sharded
                    f += 4.0 * B * (H / 1) * S_d * hd
                else:
                    f += self.attn_layer_flops_dev(
                        None if k == "global" else cfg.local_window)
            elif k == "ssd":
                D, din, N, Hh = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                                 cfg.ssm_heads)
                proj = 2 * din + 2 * N + Hh
                f += 2.0 * B * T * D * (proj + din) / _shards(din, mx)
                if self.shape.kind == "decode":
                    f += 6.0 * B * Hh * cfg.ssm_head_dim * N / _shards(din, mx)
                else:
                    f += self.ssd_layer_flops_dev()
            elif k == "rglru":
                D, W = cfg.d_model, cfg.lru_width or cfg.d_model
                W_s = _shards(W, mx)
                f += 2.0 * B * T * (3.0 * D * W / W_s + 2.0 * W * W / W_s)
            if cfg.d_ff and k != "ssd":
                D, F = cfg.d_model, cfg.d_ff
                if cfg.num_experts:
                    # top-k active experts per token (+ router)
                    f += 2.0 * B * T * D * cfg.num_experts / _shards(
                        cfg.num_experts, mx)
                    cap = cfg.top_k * cfg.capacity_factor
                    eff = max(_shards(cfg.num_experts, mx), _shards(F, mx))
                    f += 6.0 * B * T * D * F * cap / eff
                else:
                    f += 6.0 * B * T * D * F / _shards(F, mx)
        # embed (gather ~ free) + unembed matmul
        V = cfg.vocab_size
        if self.shape.kind == "train":
            f += 2.0 * B * T * cfg.d_model * V / _shards(V, self.model_ax)
        else:
            Tl = 1  # prefill emits last-position logits only; decode T=1
            f += 2.0 * B * Tl * cfg.d_model * V / _shards(V, self.model_ax)
        return f * self.mult
