"""HLO text introspection: per-dot FLOP attribution by source op_name.

Used by the dry-run debugging/perf loop: XLA's cost_analysis only reports
totals, but the optimized HLO names every fusion/dot with the jaxpr path
(op_name metadata), so we can attribute FLOPs to model components
(attention / mlp / unembed / optimizer) and catch redundant compute
(e.g. attention replicated over the model axis because heads don't divide).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]")
# operands may carry an inline type, e.g. dot(f32[64,128]{1,0} %lhs, ...)
# (jaxlib >= 0.4.36 prints it; older versions print bare %names)
_OPERAND = r"(?:[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?\s+)?%([\w.\-]+)"
_DOT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*[a-z0-9]+\[([\d,]*)\][^=]*"
    r"\bdot\(" + _OPERAND + r",\s*" + _OPERAND + r"\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _dims(s: str):
    return [int(x) for x in s.split(",") if x] if s else []


def dot_flops_by_opname(hlo_text: str) -> dict:
    """{op_name_prefix: flops} summed over all dot ops (per device)."""
    shapes = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = _dims(m.group(3))
    out = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _DOT_RE.match(line)
        if not m:
            continue
        out_shape = _dims(m.group(2))
        lhs = shapes.get(m.group(3), [])
        cm = _CONTRACT_RE.search(line)
        contract = 1
        if cm and lhs:
            for d in _dims(cm.group(1)):
                if d < len(lhs):
                    contract *= lhs[d]
        flops = 2.0 * math.prod(out_shape) * contract if out_shape else 0.0
        om = _OPNAME_RE.search(line)
        name = om.group(1) if om else "?"
        # strip to a readable component path
        name = re.sub(r"jit\([^)]*\)/", "", name)
        out[name] += flops
    return dict(out)


def top_dot_flops(hlo_text: str, n: int = 25):
    d = dot_flops_by_opname(hlo_text)
    return sorted(d.items(), key=lambda kv: -kv[1])[:n]


def total_dot_flops(hlo_text: str) -> float:
    return sum(dot_flops_by_opname(hlo_text).values())
