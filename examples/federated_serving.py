"""Federated serving: prefill/decode disaggregation as a GeoFF workflow.

Two "pods" (platforms): a prefill pod and a decode pod. Each request is a
two-step workflow — prefill builds the KV cache and ships it by reference;
the decode pod (pre-warmed via the poke) streams tokens with continuous
batching. The placement optimizer decides whether decode should run on the
pod holding the cache (function shipping, §4.3/§5.3).

    PYTHONPATH=src python examples/federated_serving.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    Deployment,
    Platform,
    PlatformRegistry,
    PlacementCosts,
    StepSpec,
    WorkflowSpec,
    place_chain,
)
from repro.configs.registry import smoke_config
from repro.models import model as M
from repro.serving import Request, ServingEngine, pad_cache


def main():
    cfg = smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    MAXLEN = 64

    reg = PlatformRegistry()
    reg.register(Platform("prefill-pod", "us-east", native_prefetch=True))
    reg.register(Platform("decode-pod", "us-west", native_prefetch=True))
    with Deployment(reg) as dep:
        dep.store.network.set_link("us-east", "us-west", 0.02, 200e6)

        _prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))
        _decode = jax.jit(lambda p, t, c, i: M.decode_step(cfg, p, t, c, i))

        def prefill_fn(payload, data):
            prompt = payload
            logits, caches = _prefill(params, {"tokens": jnp.asarray(prompt)[None]})
            caches = pad_cache(caches, MAXLEN, len(prompt), cfg=cfg)
            key = f"kv/{hash(prompt.tobytes()) & 0xFFFF}"
            dep.store.put(
                key, jax.tree_util.tree_map(np.asarray, caches), region="us-east"
            )
            return {
                "first_tok": int(jnp.argmax(logits[0])),
                "kv_key": key,
                "pos": len(prompt),
            }

        def decode_fn(payload, data):
            host_caches, _ = dep.store.get(payload["kv_key"], "us-west")
            caches = jax.tree_util.tree_map(jnp.asarray, host_caches)
            tok, cur = payload["first_tok"], payload["pos"]
            toks = [tok]
            for _ in range(7):
                logits, caches = _decode(
                    params,
                    jnp.asarray([[tok]], jnp.int32),
                    caches,
                    jnp.asarray(cur, jnp.int32),
                )
                tok = int(jnp.argmax(logits[0]))
                toks.append(tok)
                cur += 1
            return toks

        dep.deploy("prefill", prefill_fn, ["prefill-pod"])
        dep.deploy("decode", decode_fn, ["prefill-pod", "decode-pod"])

        # --- placement: should decode run where the KV cache lives? ---------
        spec = WorkflowSpec(
            (StepSpec("prefill", "prefill-pod"), StepSpec("decode", "decode-pod")),
            "serve",
        )
        costs = PlacementCosts(
            # cache ships over DCN if decode runs remote from the cache
            fetch_s=lambda n, p, d: (
                0.15 if (n, p) == ("decode", "decode-pod") else 0.01
            ),
            compute_s=lambda n, p: 0.2,
            transfer_s=lambda a, b, s: 0.0 if a == b else 0.02,
        )
        placed = place_chain(spec, {"decode": ["prefill-pod", "decode-pod"]}, costs)
        print(
            f"placement optimizer: decode -> {placed.steps[1].platform} "
            "(ships the function to the cache)"
        )

        # --- run a few requests through the disaggregated workflow ----------
        rng = np.random.default_rng(0)
        for i in range(3):
            prompt = rng.integers(1, 200, size=8).astype(np.int32)
            r = dep.run(placed, prompt)
            print(f"req {i}: {r.total_s * 1e3:7.1f} ms tokens={r.outputs}")

        # --- same model under the continuous-batching engine -----------------
        print("\ncontinuous batching on one pod:")
        eng = ServingEngine(cfg, params, max_batch=3, max_len=MAXLEN)
        for i in range(6):
            eng.submit(
                Request(
                    i, rng.integers(1, 200, size=6).astype(np.int32), max_new_tokens=6
                )
            )
        t0 = time.perf_counter()
        stats = eng.run()
        dt = time.perf_counter() - t0
        print(
            f"  {stats['done']} requests in {dt * 1e3:.0f} ms "
            f"({stats['decode_steps']} decode steps, "
            f"{stats['prefills']} prefills, mean TTFT "
            f"{np.mean(stats['ttft_s']) * 1e3:.0f} ms)"
        )


if __name__ == "__main__":
    main()
