"""The paper's document-processing workflow (§4.2) on the real middleware:
check -> virus -> ocr -> e_mail across three platforms, with REAL handlers
(hash checks, byte scans, a toy JAX "OCR" conv model) and enforced network
latencies — then the same workflow without pre-fetching, for the Fig-4
comparison, and a function-shipping variant (§4.3).

    PYTHONPATH=src python examples/document_workflow.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (DataRef, Deployment, Platform, PlatformRegistry,
                        StepSpec, WorkflowSpec)


def main():
    reg = PlatformRegistry()
    reg.register(Platform("tinyfaas-edge", "eu", kind="edge",
                          native_prefetch=True))
    reg.register(Platform("gcf", "eu", kind="cloud"))
    reg.register(Platform("lambda-us", "us", kind="cloud"))
    reg.register(Platform("lambda-eu", "eu2", kind="cloud"))
    dep = Deployment(reg)
    dep.store.enforce_latency = True
    for a, b in [("eu", "us"), ("eu2", "us"), ("eu", "eu2")]:
        dep.store.network.set_link(a, b, 0.06, 12e6)

    # the "PDF" and the reference data the steps need
    rng = np.random.default_rng(7)
    pdf = b"%PDF-1.7 " + rng.bytes(int(1.2e6))
    dep.store.put("signatures/db", rng.bytes(2_000_000), region="us")
    dep.store.put("ocr/weights",
                  rng.normal(size=(512, 8, 16)).astype(np.float32),
                  region="us")
    dep.store.put("mail/template", b"Dear user, your document: ",
                  region="us")

    def check(payload, data):
        assert payload[:5] == b"%PDF-", "not a pdf"
        time.sleep(0.12)              # render/validate the document
        return payload

    def virus(payload, data):
        db = data["signatures/db"]
        # byte-scan against the signature db (real work)
        sig = db[:64]
        time.sleep(0.1)               # scan engine startup
        return {"pdf": payload, "clean": payload.find(sig) < 0}

    def ocr(payload, data):
        w = jnp.asarray(data["ocr/weights"][:8])
        img = jnp.asarray(
            np.frombuffer(payload["pdf"][:64 * 64], np.uint8)
            .reshape(64, 64).astype(np.float32))
        # toy conv "OCR" on the rendered page
        patches = img.reshape(8, 8, 8, 8).transpose(0, 2, 1, 3).reshape(64, 64)
        feats = jnp.einsum("pq,qkc->pkc", patches[:, :8], w)
        return {"text": float(jnp.sum(jax.nn.relu(feats))),
                "clean": payload["clean"]}

    def e_mail(payload, data):
        template = data["mail/template"]
        return template.decode() + f"{payload['text']:.1f} " \
            f"(clean={payload['clean']})"

    dep.deploy("check", check, ["tinyfaas-edge"])
    dep.deploy("virus", virus, ["gcf"])
    dep.deploy("ocr", ocr, ["lambda-us", "lambda-eu"])
    dep.deploy("e_mail", e_mail, ["lambda-us"])

    def wf(prefetch=True, ocr_platform="lambda-us"):
        return WorkflowSpec((
            StepSpec("check", "tinyfaas-edge", prefetch=prefetch),
            StepSpec("virus", "gcf",
                     data_deps=(DataRef("signatures/db", "eu"),),
                     prefetch=prefetch),
            StepSpec("ocr", ocr_platform,
                     data_deps=(DataRef("ocr/weights", "us"),),
                     prefetch=prefetch),
            StepSpec("e_mail", "lambda-us",
                     data_deps=(DataRef("mail/template", "us"),),
                     prefetch=prefetch)), "docflow")

    for spec, label in [(wf(True), "geoff (pre-fetching)"),
                        (wf(False), "baseline (sequential)")]:
        dep.run(spec, pdf)              # warm
        ts = [dep.run(spec, pdf).total_s for _ in range(3)]
        print(f"{label:26s} median {np.median(ts)*1e3:7.1f} ms")

    # function shipping: OCR far from its data vs close (paper §4.3)
    for plat, label in [("lambda-eu", "ocr far from data (eu)"),
                        ("lambda-us", "ocr close to data (us)")]:
        spec = wf(True, plat)
        dep.run(spec, pdf)
        ts = [dep.run(spec, pdf).total_s for _ in range(3)]
        print(f"{label:26s} median {np.median(ts)*1e3:7.1f} ms")
    print("prefetch stats:", dep.prefetcher.stats)
    dep.shutdown()


if __name__ == "__main__":
    main()
