"""The paper's document-processing workflow (§4.2) on the real middleware —
restructured as a real fan-out DAG: after ``check``, the virus scan and the
OCR don't depend on each other, so they run in PARALLEL and join at
``e_mail`` (check -> virus || ocr -> e_mail). REAL handlers (hash checks,
byte scans, a toy JAX "OCR" conv model) and enforced network latencies.

Compares, on the same deployment:
  - the DAG with pre-fetching (branches overlap + fetches hidden),
  - the DAG without pre-fetching (parallel branches only),
  - the chain serialization of the same steps (the paper's §4.2 shape),
and the automated DAG placement (``place_dag`` wired into ``DagSpec``) that
ships OCR next to its data (§4.3/§5.3).

    PYTHONPATH=src python examples/document_workflow.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DataRef, Deployment, Platform, PlatformRegistry
from repro.core.shipping import PlacementCosts
from repro.core.workflow import StepSpec, WorkflowSpec
from repro.dag import DagDeployment, DagSpec, DagStep, place_dag_spec


def build_platforms():
    reg = PlatformRegistry()
    reg.register(Platform("tinyfaas-edge", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("gcf", "eu", kind="cloud"))
    reg.register(Platform("lambda-us", "us", kind="cloud"))
    reg.register(Platform("lambda-eu", "eu2", kind="cloud"))
    return reg


def seed_store(store, rng):
    store.put("signatures/db", rng.bytes(2_000_000), region="us")
    store.put(
        "ocr/weights",
        rng.normal(size=(512, 8, 16)).astype(np.float32),
        region="us",
    )
    store.put("mail/template", b"Dear user, your document: ", region="us")


def check(payload, data):
    assert payload[:5] == b"%PDF-", "not a pdf"
    time.sleep(0.12)  # render/validate the document
    return payload


def virus(payload, data):
    db = data["signatures/db"]
    sig = db[:64]  # byte-scan against the signature db
    time.sleep(0.1)  # scan engine startup
    return {"clean": payload.find(sig) < 0}


def ocr(payload, data):
    w = jnp.asarray(data["ocr/weights"][:8])
    page = 64 * 64
    img = jnp.asarray(
        np.frombuffer(payload[:page], np.uint8).reshape(64, 64).astype(np.float32)
    )
    # toy conv "OCR" on the rendered page
    patches = img.reshape(8, 8, 8, 8).transpose(0, 2, 1, 3).reshape(64, 64)
    feats = jnp.einsum("pq,qkc->pkc", patches[:, :8], w)
    return {"text": float(jnp.sum(jax.nn.relu(feats)))}


def e_mail(payload, data):
    # fan-in: payload = {"virus": ..., "ocr": ...}
    template = data["mail/template"]
    return (
        template.decode()
        + f"{payload['ocr']['text']:.1f} (clean={payload['virus']['clean']})"
    )


def dag_spec(prefetch=True, ocr_platform="lambda-us"):
    return DagSpec(
        (
            DagStep("check", "tinyfaas-edge", prefetch=prefetch),
            DagStep(
                "virus",
                "gcf",
                data_deps=(DataRef("signatures/db", "us", 2_000_000),),
                prefetch=prefetch,
            ),
            DagStep(
                "ocr",
                ocr_platform,
                data_deps=(DataRef("ocr/weights", "us", 256 * 1024),),
                prefetch=prefetch,
            ),
            DagStep(
                "e_mail",
                "lambda-us",
                data_deps=(DataRef("mail/template", "us"),),
                prefetch=prefetch,
            ),
        ),
        (
            ("check", "virus"),
            ("check", "ocr"),
            ("virus", "e_mail"),
            ("ocr", "e_mail"),
        ),
        "docflow-dag",
    )


def deploy_all(dep):
    dep.store.enforce_latency = True
    for a, b in [("eu", "us"), ("eu2", "us"), ("eu", "eu2")]:
        dep.store.network.set_link(a, b, 0.06, 12e6)
    dep.deploy("check", check, ["tinyfaas-edge"])
    dep.deploy("virus", virus, ["gcf"])
    dep.deploy("ocr", ocr, ["lambda-us", "lambda-eu"])
    dep.deploy("e_mail", e_mail, ["lambda-us"])
    return dep


def main():
    rng = np.random.default_rng(7)
    pdf = b"%PDF-1.7 " + rng.bytes(int(1.2e6))

    # --- the DAG on the dataflow engine --------------------------------------
    with deploy_all(DagDeployment(build_platforms())) as dag:
        seed_store(dag.store, np.random.default_rng(11))
        for spec, label in [
            (dag_spec(True), "dag geoff (pre-fetching)"),
            (dag_spec(False), "dag baseline (no poke)"),
        ]:
            dag.run(spec, pdf)  # warm
            ts = [dag.run(spec, pdf).total_s for _ in range(3)]
            print(f"{label:28s} median {np.median(ts) * 1e3:7.1f} ms")
        print(
            "fan-in joins:",
            dag.stats["joins"],
            " pokes:",
            dict(sorted(dag.stats["pokes"].items())),
        )
        # per-edge slack (the timing controller's learning signal): each of
        # e_mail's two in-edges carries its own gap — virus finishes early,
        # ocr late — which is exactly what per-edge poke delays exploit
        edges = dag.timing.report()["edges"]
        for name in sorted(edges):
            print(f"  edge {name:18s} slack={edges[name]['slack_s'] * 1e3:7.1f} ms")

        # automated placement: ship OCR next to its data (§4.3, exact DP)
        ocr_fetch = {("ocr", "lambda-eu"): 1.9, ("ocr", "lambda-us"): 0.25}
        costs = PlacementCosts(
            fetch_s=lambda name, p, deps: ocr_fetch.get((name, p), 0.0),
            compute_s=lambda name, p: 0.15,
            transfer_s=lambda a, b, size: 0.05 if a == b else 0.4,
        )
        placed = place_dag_spec(
            dag_spec(True, "lambda-eu"), {"ocr": ["lambda-eu", "lambda-us"]}, costs
        )
        print("place_dag ships ocr to:", placed.node("ocr").platform)
        ts = [dag.run(placed, pdf).total_s for _ in range(3)]
        print(f"{'dag auto-placed':28s} median {np.median(ts) * 1e3:7.1f} ms")

        # where did the milliseconds go? trace one request and attribute
        # its critical path to cold/fetch/compute/transfer/poke-slack
        from repro.obs import Tracer, extract_critical_path, instrument

        tracer = instrument(dag, Tracer())
        dag.run(dag_spec(True), pdf)
        print(extract_critical_path(tracer.last()).format())

    # --- the chain serialization (a facade over the same dataflow core) ------
    with deploy_all(Deployment(build_platforms())) as chain:
        seed_store(chain.store, np.random.default_rng(11))

        def chain_email(payload, data):  # chain has no fan-in: adapt the join
            return e_mail({"virus": {"clean": True}, "ocr": payload}, data)

        def chain_virus(payload, data):  # chain threads the pdf through virus
            virus(payload, data)
            return payload

        chain.deploy("e_mail", chain_email, ["lambda-us"])
        chain.deploy("virus", chain_virus, ["gcf"])
        spec = WorkflowSpec(
            (
                StepSpec("check", "tinyfaas-edge"),
                StepSpec("virus", "gcf", data_deps=(DataRef("signatures/db", "us"),)),
                StepSpec(
                    "ocr", "lambda-us", data_deps=(DataRef("ocr/weights", "us"),)
                ),
                StepSpec(
                    "e_mail", "lambda-us", data_deps=(DataRef("mail/template", "us"),)
                ),
            ),
            "docflow",
        )
        chain.run(spec, pdf)
        ts = [chain.run(spec, pdf).total_s for _ in range(3)]
        print(f"{'chain serialization':28s} median {np.median(ts) * 1e3:7.1f} ms")

    # --- the same workflow at paper scale, simulated ---------------------------
    # one ExperimentSpec, three backends: the numpy backend replays the
    # paper's 30-minute stream in milliseconds; the jax backend compiles a
    # whole (seeds x placements x requests) sweep into one program
    from dataclasses import replace as dc_replace

    from repro.core import simulator as sm

    steps = sm.document_workflow_fig4()
    simspec = sm.ExperimentSpec(steps, n_requests=1800, seeds=(0, 1, 2))
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=0)
    totals = simulator.simulate(simspec, backend="numpy")  # (3, 1800)
    print(
        f"{'simulated (numpy, 3 seeds)':28s} median"
        f" {np.median(totals) * 1e3:7.1f} ms"
    )
    candidates = [
        steps,
        [dc_replace(s, platform="gcf") if s.name == "ocr" else s for s in steps],
    ]
    swept = simulator.simulate_placements(simspec, candidates)  # (3, 2, 1800)
    for cand, label in zip(swept.transpose(1, 0, 2), ("ocr@lambda", "ocr@gcf")):
        print(f"{'  placement ' + label:28s} median {np.median(cand) * 1e3:7.1f} ms")


if __name__ == "__main__":
    main()
