"""End-to-end training driver: train a (reduced) qwen3-family LM for a few
hundred steps with the full production stack — GeoFF-prefetched data
pipeline, pre-warmed compile, async checkpointing, straggler detection, and
a mid-run checkpoint/restart drill.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen3-1.7b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import smoke_config
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(d_model=128, num_heads=4,
                                          head_dim=32, d_ff=512)
    tcfg = TrainerConfig(
        seq_len=args.seq_len, global_batch=args.batch,
        total_steps=args.steps, checkpoint_every=50,
        checkpoint_dir=args.ckpt_dir,
        adamw=AdamWConfig(peak_lr=1e-3, warmup_steps=20,
                          total_steps=args.steps))
    tr = Trainer(cfg, tcfg)

    half = args.steps // 2
    print(f"training {args.arch} (reduced) for {half} steps...")
    tr.run(half)
    print(f"  step {tr.step}: loss={tr.metrics_log[-1]['loss']:.4f}")

    # ---- fault-tolerance drill: 'crash' and restart from the checkpoint ----
    print("simulating failure: dropping the live trainer, restarting from "
          "the latest checkpoint...")
    tr2 = Trainer(cfg, tcfg)
    tr2.run(args.steps - half)
    log = tr2.metrics_log

    first = np.mean([m["loss"] for m in log[:10]])
    last = np.mean([m["loss"] for m in log[-10:]])
    print(f"resumed at step {args.steps - half + tr2.step - len(log)}; "
          f"finished at step {tr2.step}")
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'did not decrease'})")
    print(f"stragglers detected: {len(tr2.stragglers)}")
    print(f"checkpoint stats: {tr2.ckpt.stats}")
    assert last < first, "loss should fall on the synthetic corpus"


if __name__ == "__main__":
    main()
