"""Quickstart: deploy a federated GeoFF workflow and watch pre-fetching work.

Three steps across three platforms (edge -> cloud A -> cloud B), the middle
one a real JAX model forward. Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DataRef,
    Deployment,
    Platform,
    PlatformRegistry,
    StepSpec,
    WorkflowSpec,
    bind_sharding,
)
from repro.configs.registry import smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M


def main():
    # --- platforms (the federation) ----------------------------------------
    # Heterogeneous sharding configs: the edge node stays single-device
    # (bind_sharding drops the mesh), cloud regions carry a mesh over this
    # host's devices + the decode sharding rules — the platform wrapper
    # binds them as the ambient use_sharding context around every step.
    mesh = make_host_mesh(model_parallel=1)
    reg = PlatformRegistry()
    reg.register(
        bind_sharding(Platform("edge-berlin", "eu", kind="edge", native_prefetch=True))
    )
    reg.register(bind_sharding(Platform("cloud-us", "us", kind="cloud"), mesh=mesh))
    reg.register(bind_sharding(Platform("cloud-eu", "eu", kind="cloud"), mesh=mesh))
    with Deployment(reg) as dep:
        dep.store.enforce_latency = True  # real (slept) transfer time
        dep.store.network.set_link("eu", "us", 0.08, 10e6)

        # --- external data dependency (lives in the US) ---------------------
        rng = np.random.default_rng(0)
        dep.store.put(
            "emb/table", rng.normal(size=(256, 64)).astype(np.float32), region="us"
        )

        # --- one model, written once, deployable anywhere -------------------
        cfg = smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, jax.random.PRNGKey(0))

        def tokenize(payload, data):
            toks = np.frombuffer(payload.encode(), np.uint8).astype(np.int32)
            return toks % (cfg.vocab_size - 1) + 1

        def forward(payload, data):
            logits, _ = M.prefill(cfg, params, {"tokens": jnp.asarray(payload)[None]})
            return np.asarray(logits[0])

        def project(payload, data):
            table = data["emb/table"]  # pre-fetched while forward ran
            return float(payload[:64] @ table[:64, 0])

        dep.deploy("tokenize", tokenize, ["edge-berlin"])
        dep.deploy("forward", forward, ["cloud-us", "cloud-eu"])
        dep.deploy("project", project, ["cloud-us"])

        # --- the per-request workflow spec (ad-hoc recomposition!) ----------
        wf = WorkflowSpec(
            (
                StepSpec("tokenize", "edge-berlin"),
                StepSpec("forward", "cloud-us"),
                StepSpec(
                    "project", "cloud-us", data_deps=(DataRef("emb/table", "us"),)
                ),
            ),
            "quickstart",
        )

        r1 = dep.run(wf, "hello federated serverless world")  # cold
        r2 = dep.run(wf, "hello federated serverless world")  # warm + prefetch
        print(f"cold run:  {r1.total_s * 1e3:8.1f} ms   result={r1.outputs:.4f}")
        print(f"warm run:  {r2.total_s * 1e3:8.1f} ms   result={r2.outputs:.4f}")
        print("per-step timeline (warm):")
        for step, t in r2.timeline.items():
            print(
                f"  {step:10s} warm={t['warm_s'] * 1e3:7.2f}ms "
                f"fetch={t['fetch_s'] * 1e3:7.2f}ms "
                f"compute={t['compute_s'] * 1e3:7.2f}ms"
            )

        # reroute the forward step to the EU cloud — no redeployment
        r3 = dep.run(wf.reroute("forward", "cloud-eu"), "hello again")
        print(f"rerouted:  {r3.total_s * 1e3:8.1f} ms   (forward now on cloud-eu)")
        print("prefetcher:", dep.prefetcher.stats)
        print("compile cache:", dep.cache.stats)


if __name__ == "__main__":
    main()
