"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
same pallas_call with interpret=False on real hardware).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# -- flash attention ----------------------------------------------------------
@pytest.mark.parametrize("B,T,S,H,K,d", [
    (1, 128, 128, 4, 4, 64),     # MHA
    (2, 256, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 256, 4, 1, 128),    # MQA, T != S
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_flash_attention_sweep(B, T, S, H, K, d, dtype, causal, window):
    ks = jax.random.split(jax.random.fold_in(KEY, T * H + d), 3)
    q = jax.random.normal(ks[0], (B, T, H, d), dtype)
    k = jax.random.normal(ks[1], (B, S, K, d), dtype)
    v = jax.random.normal(ks[2], (B, S, K, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@given(st.sampled_from([32, 64, 128]), st.sampled_from([16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_flash_attention_block_shape_invariance(bq, bk):
    """Output must not depend on the BlockSpec tiling."""
    q = jax.random.normal(KEY, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


# -- ssd scan -------------------------------------------------------------------
@pytest.mark.parametrize("B,L,H,P,N,Q,bh", [
    (1, 64, 2, 16, 8, 16, 2),
    (2, 128, 4, 32, 16, 32, 2),   # head-blocked
    (1, 96, 3, 16, 8, 32, 1),     # H not a power of two
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, L, H, P, N, Q, bh, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, L + H), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=8.0))
    Bm = jax.random.normal(ks[3], (B, L, N), dtype)
    Cm = jax.random.normal(ks[4], (B, L, N), dtype)
    y, s = ops.ssd_scan(x, dt, A_log, Bm, Cm, Q, block_h=bh)
    yr, sr = ref.ssd_scan_ref(x, dt, A_log, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), **tol(dtype))


# -- rg-lru scan ------------------------------------------------------------------
@pytest.mark.parametrize("B,T,W,chunk,bw", [
    (1, 64, 32, 16, 32), (2, 128, 64, 64, 16), (1, 256, 16, 256, 16)])
def test_rglru_scan_sweep(B, T, W, chunk, bw):
    ks = jax.random.split(jax.random.fold_in(KEY, T + W), 2)
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (B, T, W)))
    b = jax.random.normal(ks[1], (B, T, W))
    y, h = ops.rglru_scan(log_a, b, chunk=chunk, block_w=bw)
    yr, hr = ref.rglru_scan_ref(log_a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-5,
                               atol=1e-5)


# -- rmsnorm ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(7, 64), (3, 5, 128), (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (shape[-1],)) * 0.1
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_model_paths_agree_with_pallas():
    """cfg.use_pallas=True must reproduce the jnp model end to end."""
    from repro.configs.registry import smoke_config
    from repro.models import model as M
    for arch in ("qwen3-1.7b", "mamba2-370m", "recurrentgemma-9b"):
        cfg = smoke_config(arch).replace(attn_chunk_q=0)
        params = M.init_params(cfg, jax.random.PRNGKey(11))
        batch = {"tokens": jax.random.randint(KEY, (2, 32), 1, 255),
                 "labels": jax.random.randint(KEY, (2, 32), 0, 255)}
        l_jnp, _ = M.forward_train(cfg, params, batch)
        l_pls, _ = M.forward_train(cfg.replace(use_pallas=True), params,
                                   batch)
        np.testing.assert_allclose(float(l_jnp), float(l_pls), rtol=5e-3), arch
