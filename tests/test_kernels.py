"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
same pallas_call with interpret=False on real hardware).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.cold_scan import cold_scan_parallel

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        else dict(rtol=2e-5, atol=2e-5)
    )


# -- flash attention ----------------------------------------------------------
@pytest.mark.parametrize(
    "B,T,S,H,K,d",
    [
        (1, 128, 128, 4, 4, 64),  # MHA
        (2, 256, 256, 8, 2, 64),  # GQA 4:1
        (1, 128, 256, 4, 1, 128),  # MQA, T != S
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96), (False, None)])
def test_flash_attention_sweep(B, T, S, H, K, d, dtype, causal, window):
    ks = jax.random.split(jax.random.fold_in(KEY, T * H + d), 3)
    q = jax.random.normal(ks[0], (B, T, H, d), dtype)
    k = jax.random.normal(ks[1], (B, S, K, d), dtype)
    v = jax.random.normal(ks[2], (B, S, K, d), dtype)
    out = ops.flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64
    )
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@given(st.sampled_from([32, 64, 128]), st.sampled_from([16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_flash_attention_block_shape_invariance(bq, bk):
    """Output must not depend on the BlockSpec tiling."""
    q = jax.random.normal(KEY, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


# -- ssd scan -------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,L,H,P,N,Q,bh",
    [
        (1, 64, 2, 16, 8, 16, 2),
        (2, 128, 4, 32, 16, 32, 2),  # head-blocked
        (1, 96, 3, 16, 8, 32, 1),  # H not a power of two
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, L, H, P, N, Q, bh, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, L + H), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=8.0))
    Bm = jax.random.normal(ks[3], (B, L, N), dtype)
    Cm = jax.random.normal(ks[4], (B, L, N), dtype)
    y, s = ops.ssd_scan(x, dt, A_log, Bm, Cm, Q, block_h=bh)
    yr, sr = ref.ssd_scan_ref(x, dt, A_log, Bm, Cm, Q)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), **tol(dtype))


# -- rg-lru scan ------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,T,W,chunk,bw", [(1, 64, 32, 16, 32), (2, 128, 64, 64, 16), (1, 256, 16, 256, 16)]
)
def test_rglru_scan_sweep(B, T, W, chunk, bw):
    ks = jax.random.split(jax.random.fold_in(KEY, T + W), 2)
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (B, T, W)))
    b = jax.random.normal(ks[1], (B, T, W))
    y, h = ops.rglru_scan(log_a, b, chunk=chunk, block_w=bw)
    yr, hr = ref.rglru_scan_ref(log_a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-5, atol=1e-5)


# -- rmsnorm ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(7, 64), (3, 5, 128), (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (shape[-1],)) * 0.1
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


def test_model_paths_agree_with_pallas():
    """cfg.use_pallas=True must reproduce the jnp model end to end."""
    from repro.configs.registry import smoke_config
    from repro.models import model as M

    for arch in ("qwen3-1.7b", "mamba2-370m", "recurrentgemma-9b"):
        cfg = smoke_config(arch).replace(attn_chunk_q=0)
        params = M.init_params(cfg, jax.random.PRNGKey(11))
        batch = {
            "tokens": jax.random.randint(KEY, (2, 32), 1, 255),
            "labels": jax.random.randint(KEY, (2, 32), 0, 255),
        }
        l_jnp, _ = M.forward_train(cfg, params, batch)
        l_pls, _ = M.forward_train(cfg.replace(use_pallas=True), params, batch)
        np.testing.assert_allclose(float(l_jnp), float(l_pls), rtol=5e-3), arch


# -- cold-start scan (simulator) -----------------------------------------------
def _cold_case(key, B, T, interarrival, keep_warm, spread=0.3):
    """Arrival times plus warm/cold end-time hypotheses around them."""
    k1, k2, k3 = jax.random.split(key, 3)
    gaps = interarrival * (0.5 + jax.random.uniform(k1, (T,)))
    t0 = jnp.cumsum(gaps)
    dur = spread * jax.random.uniform(k2, (B, T))
    cold_extra = spread * jax.random.uniform(k3, (B, T))
    warm_end = t0[None, :] + dur
    return t0, warm_end, warm_end + cold_extra, jnp.float32(keep_warm)


@pytest.mark.parametrize("B,T", [(1, 64), (3, 257), (130, 300)])
@pytest.mark.parametrize(
    "interarrival,keep_warm",
    [
        (1.0, 900.0),  # paper regime: warm after request 0
        (10.0, 1.0),  # every request cold
        (1.0, 0.95),  # straddling: the mask genuinely recurses
        (1.0, jnp.inf),  # never cold
    ],
)
def test_cold_scan_kernel_and_parallel_match_ref(B, T, interarrival, keep_warm):
    t0, warm, cold, kw = _cold_case(
        jax.random.PRNGKey(7), B, T, interarrival, keep_warm
    )
    want = ref.cold_scan_ref(t0, warm, cold, kw)
    got_pl = ops.cold_scan(t0, warm, cold, kw)  # interpret mode on CPU
    got_par = cold_scan_parallel(t0, warm, cold, kw)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_par), np.asarray(want))


def test_cold_scan_flip_heavy_regime():
    """keep_warm between the warm and cold gaps on most requests: the
    affine maps are nearly all 'flip', the worst case for the early-out
    doubling loop (it must run to full depth and still be exact)."""
    T = 97
    t0 = 0.7 * jnp.arange(T, dtype=jnp.float32)
    warm = t0[None, :] + 0.02
    cold = warm + 0.5  # warm gap 0.68 > kw=0.6, cold gap 0.18 < kw -> flip
    kw = jnp.float32(0.6)
    want = ref.cold_scan_ref(t0, warm, cold, kw)
    np.testing.assert_array_equal(
        np.asarray(cold_scan_parallel(t0, warm, cold, kw)), np.asarray(want)
    )
    np.testing.assert_array_equal(
        np.asarray(ops.cold_scan(t0, warm, cold, kw)), np.asarray(want)
    )


def test_cold_scan_parallel_under_vmap():
    """The while_loop gate must lift over vmap (any lane still flipping)."""
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    cases = [_cold_case(k, 2, 50, 1.0, 0.95) for k in keys]
    t0 = jnp.stack([c[0] for c in cases])
    warm = jnp.stack([c[1] for c in cases])
    cold = jnp.stack([c[2] for c in cases])
    got = jax.vmap(lambda a, b, c: cold_scan_parallel(a, b, c, 0.95))(t0, warm, cold)
    for i in range(4):
        want = ref.cold_scan_ref(t0[i], warm[i], cold[i], 0.95)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))
