"""Dry-run accounting: HLO collective parser + analytic-FLOPs validation.

The full 512-device sweep runs via launch/dryrun.py (subprocess; results in
experiments/dryrun/). Here we validate the ACCOUNTING MACHINERY itself on
single-device lowers: the analytic model must agree with XLA's exact counts
when nothing is scanned, and the scan corrections must close the gap when
it is.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.dryrun import parse_collectives
from repro.launch.hlo_tools import dot_flops_by_opname, total_dot_flops


def test_collective_parser_on_known_hlo():
    hlo = """
  %all-gather = f32[4096,512]{1,0} all-gather(%x), replica_groups=[16,16]<=[16,16]T(1,0)
  %ar = bf16[256,64]{1,0} all-reduce(%y), replica_groups=[128,2]<=[256]
  %rs.1 = f32[16,16]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256]
  %done = f32[4,4]{1,0} add(%a, %b)
"""
    c = parse_collectives(hlo, pod_count=2)
    assert c["num_collectives"] == 3
    ag = 4096 * 512 * 4 * 15 / 16
    ar = 2 * 256 * 64 * 2 * 1 / 2
    rs = 16 * 16 * 4 * 15
    assert c["dcn_wire_bytes"] == pytest.approx(ar)      # group size == pods
    assert c["ici_wire_bytes"] == pytest.approx(ag + rs)


def test_dot_parser_matches_cost_analysis():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2
    x = jnp.zeros((64, 128))
    w1 = jnp.zeros((128, 256))
    w2 = jnp.zeros((256, 32))
    c = jax.jit(f).lower(x, w1, w2).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    want = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 32
    assert total_dot_flops(c.as_text()) == pytest.approx(want, rel=0.01)
    assert ca["flops"] == pytest.approx(want, rel=0.05)


def test_scan_correction_closes_flop_gap():
    """Unrolled chunked attention (exact) vs scanned + analytic correction
    — the dry-run's accounting assumption, verified end-to-end on a small
    model."""
    from repro.configs.registry import smoke_config
    from repro.configs.base import ShapeSpec
    from repro.launch.analytic import CellModel
    from repro.models import model as M
    from repro.optim import AdamW

    cfg0 = smoke_config("qwen3-1.7b").replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        remat="full", scan_layers=False)
    shape = ShapeSpec("t", 256, 2, "train")
    batch = {"tokens": jnp.zeros((2, 256), jnp.int32),
             "labels": jnp.zeros((2, 256), jnp.int32)}

    def flops_of(cfg):
        opt = AdamW()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        micro = M.make_micro_step(cfg)
        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        c = jax.jit(micro).lower(params, g0, batch).compile()
        ca = c.cost_analysis()
        return (ca[0] if isinstance(ca, list) else ca)["flops"]

    exact = flops_of(cfg0.replace(attn_chunk_q=64, attn_chunk_unroll=True))
    counted = flops_of(cfg0.replace(attn_chunk_q=64, attn_chunk_unroll=False))
    cfg_s = cfg0.replace(attn_chunk_q=64, attn_chunk_unroll=False)
    corr = CellModel(cfg_s, shape, {"data": 1, "model": 1}).corrections_dev()
    assert counted < exact                      # XLA counts the body once
    got = counted + corr
    assert got == pytest.approx(exact, rel=0.15), (exact, counted, corr)


def test_sweep_artifacts_if_present():
    """If the 62-cell sweep has produced artifacts, check their invariants."""
    import glob
    import json
    import os
    files = glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                   "experiments", "dryrun", "*.json"))
    if not files:
        pytest.skip("sweep not run in this environment")
    for f in files:
        d = json.load(open(f))
        r = d["roofline"]
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert d["flops_per_dev_step"] > 0
        assert r["step_s_lower_bound"] >= max(r["compute_s"], 1e-12) - 1e-12
        assert d["n_devices"] in (256, 512)
