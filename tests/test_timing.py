"""Learned poke-delay controller (paper §5.5): less double-billing at ~equal
workflow duration."""

import math

import numpy as np
import pytest

from repro.core.timing import EWMA, PokeTimingController
from repro.core import simulator as S


def test_ewma_converges():
    e = EWMA(0.3)
    for _ in range(60):
        e.update(2.0)
    assert e.value == pytest.approx(2.0, abs=1e-3)


def test_configured_alpha_reaches_all_ewmas():
    """Regression: the slack EWMA must use the configured alpha too (it
    silently fell back to the default 0.25)."""
    c = PokeTimingController("learned", alpha=0.5)
    e = c._entry("s")
    assert e.compute.alpha == 0.5
    assert e.prepare.alpha == 0.5
    assert e.slack.alpha == 0.5


def test_eager_mode_zero_delay():
    c = PokeTimingController("eager")
    c.record_compute("a", 5.0)
    c.record_prepare("b", 0.5)
    assert c.poke_delay("a", "b") == 0.0


def test_learned_delay_formula():
    c = PokeTimingController("learned", margin_s=0.1)
    for _ in range(5):
        c.record_compute("a", 5.0)
        c.record_prepare("b", 0.5)
    assert c.poke_delay("a", "b") == pytest.approx(4.4, abs=1e-6)
    # slack observations take precedence once available
    for _ in range(30):
        c.record_slack("b", 2.0)
    assert c.poke_delay("a", "b") == pytest.approx(1.9, abs=0.05)
    # no data -> eager
    assert c.poke_delay("x", "y") == 0.0


def test_learned_timing_cuts_double_billing_in_sim():
    """Fig-4 workflow replayed with the learned delay: duration ~unchanged,
    double-billing cut hard (the §5.5 trade-off, measured)."""
    from benchmarks.timing_bench import run

    t_e, d_e = run("eager", n=400)
    t_l, d_l = run("learned", n=400)
    assert d_e > 0.5  # eager really does double-bill
    assert t_l <= t_e * 1.07  # duration kept (within noise+margin)
    assert d_l < d_e * 0.35, (d_l, d_e)  # idle cut by >65%
