"""Learned poke-delay controller (paper §5.5): per-edge slack, less
double-billing at ~equal workflow duration."""

import pytest

from repro.core.timing import EWMA, PokeTimingController


def test_ewma_converges():
    e = EWMA(0.3)
    for _ in range(60):
        e.update(2.0)
    assert e.value == pytest.approx(2.0, abs=1e-3)


def test_configured_alpha_reaches_all_ewmas():
    """Regression: every EWMA — per-step compute/prepare AND per-edge
    slack — must use the configured alpha (slack once silently fell back
    to the default 0.25)."""
    c = PokeTimingController("learned", alpha=0.5)
    s = c._step("s")
    assert s.compute.alpha == 0.5
    assert s.prepare.alpha == 0.5
    assert c._edge("a", "b").slack.alpha == 0.5


def test_eager_mode_zero_delay():
    c = PokeTimingController("eager")
    c.record_compute("a", 5.0)
    c.record_prepare("b", 0.5)
    assert c.poke_delay("a", "b") == 0.0


def test_learned_delay_formula():
    c = PokeTimingController("learned", margin_s=0.1)
    for _ in range(5):
        c.record_compute("a", 5.0)
        c.record_prepare("b", 0.5)
    assert c.poke_delay("a", "b") == pytest.approx(4.4, abs=1e-6)
    # slack observations take precedence once available
    for _ in range(30):
        c.record_slack("a", "b", 2.0)
    assert c.poke_delay("a", "b") == pytest.approx(1.9, abs=0.05)
    # no data -> eager
    assert c.poke_delay("x", "y") == 0.0


def test_fan_in_learns_distinct_slack_per_edge():
    """The tentpole re-key: a join with two predecessors of very different
    dwell must delay each predecessor's poke by ITS edge's gap, not one
    blended per-step number."""
    c = PokeTimingController("learned", margin_s=0.1)
    for _ in range(30):
        c.record_slack("fast_branch", "join", 3.0)  # long idle gap
        c.record_slack("slow_branch", "join", 0.2)  # payload nearly late
    assert c.poke_delay("fast_branch", "join") == pytest.approx(2.9, abs=0.05)
    assert c.poke_delay("slow_branch", "join") == pytest.approx(0.1, abs=0.05)
    # per-edge stats surfaced for both engine and simulator reporting
    rep = c.report()
    assert "fast_branch->join" in rep["edges"]
    assert rep["edges"]["fast_branch->join"]["double_billed_s"] > 0


def test_negative_slack_counts_as_exposed_wait():
    c = PokeTimingController("learned")
    c.record_slack("a", "b", -0.4)
    rep = c.report()["edges"]["a->b"]
    assert rep["exposed_wait_s"] == pytest.approx(0.4)
    assert rep["double_billed_s"] == 0.0


def test_learned_timing_cuts_double_billing_in_sim():
    """Fig-4 workflow replayed with the learned per-edge delay wired into
    the unified simulator: duration ~unchanged, double-billing cut hard
    (the §5.5 trade-off, measured)."""
    from benchmarks.timing_bench import run

    t_e, d_e = run("eager", n=400)
    t_l, d_l = run("learned", n=400)
    assert d_e > 0.5  # eager really does double-bill
    assert t_l <= t_e * 1.07  # duration kept (within noise+margin)
    assert d_l < d_e * 0.35, (d_l, d_e)  # idle cut by >65%
