"""Chain/DAG parity: the chain stack is a facade over the single dataflow
core. The same spec through the old `Deployment.run` API and through an
explicit `from_chain` + `DagDeployment.run` must behave identically, and
the unified simulator must reproduce the pre-refactor chain recurrence
draw for draw."""

import math

import numpy as np
import pytest

from repro.core import (
    DataRef,
    Deployment,
    Platform,
    PlatformRegistry,
    StepSpec,
    WorkflowSpec,
)
from repro.core import simulator as S
from repro.dag import DagDeployment, DagSpec
from repro.dag.engine import DagDeployment as EngineDagDeployment


def make_registry():
    reg = PlatformRegistry()
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us", kind="cloud"))
    return reg


def deploy_handlers(dep):
    dep.deploy("a", lambda p, d: p + 1, ["edge-eu"])
    dep.deploy("b", lambda p, d: float(np.sum(d["w"])) * p, ["cloud-us"])
    dep.deploy("c", lambda p, d: p * 10, ["cloud-us"])
    rng = np.random.default_rng(3)
    dep.store.put("w", rng.normal(size=32), region="eu")
    return dep


CHAIN = WorkflowSpec(
    (
        StepSpec("a", "edge-eu"),
        StepSpec("b", "cloud-us", data_deps=(DataRef("w", "eu"),)),
        StepSpec("c", "cloud-us"),
    ),
    "parity",
)


# ---------------------------------------------------------------------------
# engine parity: facade vs explicit dataflow run
# ---------------------------------------------------------------------------
def test_chain_facade_is_the_dataflow_engine():
    """Structural acceptance: Deployment IS a DagDeployment — the chain
    stack no longer carries its own poke/payload execution loop."""
    assert issubclass(Deployment, EngineDagDeployment)
    assert Deployment.deploy is EngineDagDeployment.deploy
    assert Deployment.shutdown is EngineDagDeployment.shutdown
    assert "_run_node" not in Deployment.__dict__  # only the engine executes
    import repro.core.choreographer as chore

    assert not hasattr(chore, "Middleware")


def test_chain_api_matches_explicit_from_chain_run():
    """Identical outputs and equivalent timelines through both APIs."""
    with deploy_handlers(Deployment(make_registry())) as chain:
        r_chain = chain.run(CHAIN, 2.0)
    with deploy_handlers(DagDeployment(make_registry())) as dag:
        r_dag = dag.run(DagSpec.from_chain(CHAIN), 2.0)
    assert r_chain.outputs == pytest.approx(r_dag.outputs)
    assert set(r_chain.timeline) == set(r_dag.timeline) == {"a", "b", "c"}
    keys = {"warm_s", "fetch_s", "compute_s", "payload_wait_s", "transfer_s"}
    for step in r_chain.timeline:
        assert set(r_chain.timeline[step]) == keys
        assert set(r_dag.timeline[step]) == keys


def test_chain_facade_records_per_edge_slack():
    """The facade rides the engine's per-edge timing: a poked chain hop
    with data deps appears as a (pred -> succ) edge in the report."""
    import time

    with deploy_handlers(Deployment(make_registry())) as dep:
        dep.deploy("a", lambda p, d: time.sleep(0.15) or p + 1, ["edge-eu"])
        for _ in range(3):  # the poke must land before b fires: a dwells
            dep.run(CHAIN, 1.0)
        edges = dep.timing.report()["edges"]
    assert "a->b" in edges
    assert edges["a->b"]["slack_s"] != 0.0


def test_deployment_context_manager_idempotent_shutdown():
    dep = Deployment(make_registry())
    with dep as d:
        assert d is dep
        d.deploy("a", lambda p, d_: p, ["edge-eu"])
        assert d.run(WorkflowSpec((StepSpec("a", "edge-eu"),)), 7).outputs == 7
    dep.shutdown()  # second shutdown after __exit__: must be a no-op
    with DagDeployment(make_registry()) as dag:
        dag.shutdown()
        dag.shutdown()


# ---------------------------------------------------------------------------
# simulator parity: unified recurrence vs the pre-refactor chain recurrence
# ---------------------------------------------------------------------------
class _PreRefactorChainSim:
    """Frozen copy of the chain-only simulator's run_request (the code that
    lived in core/simulator.py before the unification), kept verbatim as
    the draw-for-draw reference."""

    def __init__(
        self, platforms, msg_latency_s=0.045, payload_size_bytes=1.5e6, seed=0
    ):
        self.platforms = {p.name: p for p in platforms}
        self.msg = msg_latency_s
        self.obj = S.ObjectLatency()
        self.payload_size = payload_size_bytes
        self.rng = np.random.default_rng(seed)
        self._last_use = {}

    def _transfer_s(self, src, dst):
        if dst.native_prefetch and dst.allows_sync and src.region == dst.region:
            return self.msg * 0.1
        return self.obj.op_s(
            src.region, dst.region, self.payload_size
        ) + self.obj.op_s(dst.region, dst.region, self.payload_size)

    def _cold(self, step, t):
        plat = self.platforms[step.platform]
        last = self._last_use.get((step.name, step.platform), -math.inf)
        cold = (t - last) > plat.keep_warm_s
        return plat.cold_start.sample(self.rng) if cold else 0.0

    def run_request(self, steps, t0, prefetch):
        n = len(steps)
        poke = [math.inf] * n
        prepare = [0.0] * n
        payload = [0.0] * n
        start = [0.0] * n
        end = [0.0] * n
        double_billed = 0.0
        if prefetch:
            poke[0] = t0
            for i in range(1, n):
                poke[i] = poke[i - 1] + self.msg if steps[i].prefetch else math.inf
        payload[0] = t0 + self.msg / 2
        for i, step in enumerate(steps):
            cold = self._cold(step, t0)
            fetch = step.fetch.sample(self.rng)
            if prefetch and poke[i] < math.inf:
                prepare[i] = poke[i] + cold + fetch
                start[i] = max(payload[i], prepare[i])
                double_billed += max(0.0, start[i] - prepare[i])
            else:
                start[i] = payload[i] + cold + fetch
            end[i] = start[i] + step.compute.sample(self.rng)
            self._last_use[(step.name, step.platform)] = end[i]
            if i + 1 < n:
                src = self.platforms[step.platform]
                dst = self.platforms[steps[i + 1].platform]
                payload[i + 1] = end[i] + self._transfer_s(src, dst)
        return end[-1] - t0, start, end, prepare, payload, double_billed


@pytest.mark.parametrize("prefetch", [True, False])
@pytest.mark.parametrize("seed", [0, 7, 23])
def test_unified_sim_matches_prerefactor_chain_recurrence(prefetch, seed):
    """Same seed, same steps: every sampled draw lands in the same place."""
    steps = S.document_workflow_fig4()
    ref = _PreRefactorChainSim(S.paper_platforms(), seed=seed)
    uni = S.WorkflowSimulator(S.paper_platforms(), seed=seed)
    for k in range(20):  # warm/cold transitions included
        t0 = k * 1.0
        want_total, w_start, w_end, w_prep, w_pay, w_db = ref.run_request(
            steps, t0, prefetch
        )
        tr = uni.run_request(steps, t0, prefetch)
        assert tr.total_s == pytest.approx(want_total, abs=1e-12)
        assert tr.start == pytest.approx(w_start)
        assert tr.end == pytest.approx(w_end)
        assert tr.prepare == pytest.approx(w_prep)
        assert tr.payload == pytest.approx(w_pay)
        assert tr.double_billed_s == pytest.approx(w_db)


def test_unified_sim_chain_equals_dag_on_degenerate_graph():
    """run_request and run_dag_request are the SAME recurrence: a chain
    expressed as an edge list reproduces the positional chain trace."""
    steps = S.document_workflow_fig4()
    edges = [(steps[i].name, steps[i + 1].name) for i in range(len(steps) - 1)]
    for prefetch in (True, False):
        a = S.WorkflowSimulator(S.paper_platforms(), seed=13)
        b = S.WorkflowSimulator(S.paper_platforms(), seed=13)
        tr_chain = a.run_request(steps, 0.0, prefetch)
        tr_dag = b.run_dag_request(steps, edges, 0.0, prefetch)
        assert tr_dag.total_s == pytest.approx(tr_chain.total_s, abs=1e-12)
        for i, s in enumerate(steps):
            assert tr_dag.end[s.name] == pytest.approx(tr_chain.end[i])


def test_unified_sim_supports_duplicate_chain_step_names():
    """Chains may invoke the same function twice; positional keying keeps
    that working after the unification."""
    plat = S.SimPlatform("p", "r", native_prefetch=True, cold_start=S.Dist(0.0))
    steps = [
        S.SimStep("f", "p", compute=S.Dist(0.2, 0.0)),
        S.SimStep("f", "p", compute=S.Dist(0.2, 0.0)),
        S.SimStep("f", "p", compute=S.Dist(0.2, 0.0)),
    ]
    sim = S.WorkflowSimulator([plat], msg_latency_s=0.0, seed=0)
    tr = sim.run_request(steps, 0.0, prefetch=True)
    assert tr.total_s == pytest.approx(0.6, abs=1e-6)


def test_engine_cascade_consults_per_edge_delay():
    """Regression: the poke cascade must consult the learned delay for
    EVERY edge it crosses (it used to poke successors eagerly, so learned
    delays only ever applied to the first hop)."""
    calls = []
    with deploy_handlers(Deployment(make_registry())) as dep:
        dep.timing.poke_delay = lambda p, s: calls.append((p, s)) or 0.0
        dep.run(CHAIN, 1.0)
    assert ("a", "b") in calls and ("b", "c") in calls


def test_chain_invoking_same_function_twice_still_runs():
    """Chains are positional and may repeat a function; the facade lifts
    repeated names to unique ``f@i`` nodes with ``fn`` pointing back at the
    deployed function (regression: from_chain used to reject these)."""
    with Deployment(make_registry()) as dep:
        dep.deploy("inc", lambda p, d: p + 1, ["edge-eu", "cloud-us"])
        dep.deploy("dbl", lambda p, d: p * 2, ["cloud-us"])
        wf = WorkflowSpec(
            (
                StepSpec("inc", "edge-eu"),
                StepSpec("dbl", "cloud-us"),
                StepSpec("inc", "cloud-us"),
            )
        )
        r = dep.run(wf, 1)
    assert r.outputs == 5  # ((1 + 1) * 2) + 1
    assert set(r.timeline) == {"inc@0", "dbl", "inc@2"}


def test_from_chain_duplicate_names_json_roundtrip():
    wf = WorkflowSpec((StepSpec("f", "p"), StepSpec("g", "p"), StepSpec("f", "p")))
    dag = DagSpec.from_chain(wf)
    assert [s.name for s in dag.steps] == ["f@0", "g", "f@2"]
    assert [s.fn for s in dag.steps] == ["f", "", "f"]
    assert DagSpec.from_json(dag.to_json()) == dag


def test_dag_sim_per_edge_slack_does_not_chase_feedback():
    """Fan-in regression: slack is recorded against the undelayed cascade,
    so learned per-edge delays converge instead of inflating each other
    (the delay embedded in a join's prepare is the argmin edge's, not each
    recorded edge's)."""
    from repro.core.timing import PokeTimingController
    from repro.dag import document_dag_fig4

    steps, edges = document_dag_fig4()
    ctrl = PokeTimingController("learned", margin_s=0.1)
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=3, timing=ctrl)
    for k in range(200):
        sim.run_dag_request(steps, edges, k * 1.0, prefetch=True)
    slacks = {k: v["slack_s"] for k, v in ctrl.report()["edges"].items()}
    # e_mail's two in-edges learn distinct, finite gaps (ocr arrives later
    # than virus); a feedback loop would have inflated them past any bound
    assert 0.0 < slacks["virus->e_mail"] < slacks["ocr->e_mail"] < 3.0


# ---------------------------------------------------------------------------
# drift injection: inert schedules are draw-neutral; active ones rescale
# ---------------------------------------------------------------------------
def _trace_tuple(tr):
    return (
        tr.total_s,
        tuple(tr.start),
        tuple(tr.end),
        tuple(tr.prepare),
        tuple(tr.payload),
        tr.double_billed_s,
        tr.exposed_fetch_s,
    )


@pytest.mark.parametrize(
    "drift",
    [
        S.DriftSchedule(),
        S.DriftSchedule([S.DriftEvent(10**9, "gcf", compute_scale=9.0)]),
    ],
)
def test_drift_disabled_is_bit_for_bit_identical(drift):
    """With no drift in range, every sampled value is EXACTLY (==, not
    approx) what the plain simulator draws — attaching a schedule must not
    perturb rng consumption or float arithmetic."""
    steps = S.document_workflow_fig4()
    for prefetch in (True, False):
        plain = S.WorkflowSimulator(S.paper_platforms(), seed=5)
        drifty = S.WorkflowSimulator(S.paper_platforms(), seed=5, drift=drift)
        for k in range(20):
            a = plain.run_request(steps, k * 1.0, prefetch)
            b = drifty.run_request(steps, k * 1.0, prefetch)
            assert _trace_tuple(a) == _trace_tuple(b)


def test_telemetry_tap_is_draw_neutral():
    """Feeding a TelemetryHub must not change the sampled trace either."""
    from repro.adapt import TelemetryHub

    steps = S.document_workflow_fig4()
    plain = S.WorkflowSimulator(S.paper_platforms(), seed=9)
    tapped = S.WorkflowSimulator(S.paper_platforms(), seed=9, telemetry=TelemetryHub())
    for k in range(10):
        a = _trace_tuple(plain.run_request(steps, k * 1.0, True))
        b = _trace_tuple(tapped.run_request(steps, k * 1.0, True))
        assert a == b
    snap = tapped.telemetry.snapshot()
    assert "ocr@lambda-us-east-1" in snap["compute_s"]


def test_drift_rescales_target_platform_from_request_k():
    """From request k on, the named platform's compute draws scale; other
    platforms and earlier requests are untouched."""
    plats = [
        S.SimPlatform("p", "r", native_prefetch=True, cold_start=S.Dist(0.0)),
        S.SimPlatform("q", "r", native_prefetch=True, cold_start=S.Dist(0.0)),
    ]
    steps = [
        S.SimStep("a", "p", compute=S.Dist(0.1, 0.0)),
        S.SimStep("b", "q", compute=S.Dist(0.2, 0.0)),
    ]
    drift = S.DriftSchedule([S.DriftEvent(2, "q", compute_scale=3.0)])
    sim = S.WorkflowSimulator(plats, msg_latency_s=0.0, seed=0, drift=drift)
    totals = [sim.run_request(steps, k * 1.0, True).total_s for k in range(4)]
    assert totals[0] == pytest.approx(0.3, abs=1e-9)
    assert totals[1] == pytest.approx(0.3, abs=1e-9)
    assert totals[2] == pytest.approx(0.1 + 0.6, abs=1e-9)  # only q scaled
    assert totals[3] == pytest.approx(0.7, abs=1e-9)


def test_drift_transfer_scale_applies_to_links_touching_platform():
    plats = [
        S.SimPlatform("p", "r1", cold_start=S.Dist(0.0)),
        S.SimPlatform("q", "r2", cold_start=S.Dist(0.0)),
    ]
    steps = [
        S.SimStep("a", "p", compute=S.Dist(0.1, 0.0)),
        S.SimStep("b", "q", compute=S.Dist(0.2, 0.0)),
    ]
    base = S.WorkflowSimulator(plats, seed=0)
    tr = base._transfer_s(plats[0], plats[1])
    drift = S.DriftSchedule([S.DriftEvent(0, "q", transfer_scale=2.0)])
    sim = S.WorkflowSimulator(plats, seed=0, drift=drift)
    t_plain = base.run_request(steps, 0.0, False).total_s
    t_drift = sim.run_request(steps, 0.0, False).total_s
    assert t_drift == pytest.approx(t_plain + tr, abs=1e-9)


# ---------------------------------------------------------------------------
# satellite: descriptive object-store errors
# ---------------------------------------------------------------------------
def test_store_missing_key_error_is_descriptive():
    from repro.core import ObjectStore

    store = ObjectStore()
    store.put("__payload__/req1/a->b", b"x", region="eu")
    store.put("__payload__/req1/a->c", b"x", region="eu")
    with pytest.raises(KeyError) as exc:
        store.get("__payload__/req1/a->d", "us")
    msg = str(exc.value)
    assert "__payload__/req1/a->d" in msg  # the missing key
    assert "'us'" in msg  # the requesting region
    assert "a->b" in msg and "a->c" in msg  # nearby keys under the prefix


def test_store_missing_key_error_without_prefix_match():
    from repro.core import ObjectStore

    store = ObjectStore()
    store.put("other/key", b"x", region="eu")
    with pytest.raises(KeyError, match="store holds 1 keys"):
        store.get("nothing/here", "eu")
