"""Calibrated simulator: paper medians + protocol properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import simulator as S

TOL = 0.08  # 8% relative tolerance on the paper's medians


@pytest.fixture(scope="module")
def sim():
    return S.WorkflowSimulator(S.paper_platforms(), seed=7)


def test_fig4_document_workflow(sim):
    steps = S.document_workflow_fig4()
    base = S.median(sim.run_experiment(steps, 1800, prefetch=False))
    geo = S.median(sim.run_experiment(steps, 1800, prefetch=True))
    assert base == pytest.approx(4.65, rel=TOL), base
    assert geo == pytest.approx(2.19, rel=TOL), geo
    improv = (base - geo) / base
    assert improv == pytest.approx(0.5302, abs=0.06), improv


def test_fig6_function_shipping(sim):
    far = S.median(sim.run_experiment(
        S.shipping_workflow_fig6("lambda-eu-central-1"), 1800))
    close = S.median(sim.run_experiment(
        S.shipping_workflow_fig6("lambda-us-east-1"), 1800))
    assert far == pytest.approx(10.47, rel=TOL), far
    assert close == pytest.approx(7.65, rel=TOL), close
    assert (far - close) / far == pytest.approx(0.2690, abs=0.05)


def test_fig8_native_prefetch(sim):
    steps = S.native_prefetch_workflow_fig8()
    base = S.median(sim.run_experiment(steps, 1800, prefetch=False))
    geo = S.median(sim.run_experiment(steps, 1800, prefetch=True))
    assert base == pytest.approx(5.87, rel=TOL), base
    assert geo == pytest.approx(5.08, rel=TOL), geo


compute_st = st.floats(0.05, 3.0)
fetch_st = st.floats(0.0, 3.0)


@given(st.lists(st.tuples(compute_st, fetch_st), min_size=2, max_size=5),
       st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_prefetch_never_slower(steps_raw, seed):
    """Protocol property: with identical sampled durations, the GeoFF
    schedule is never slower than the sequential baseline."""
    plats = S.paper_platforms()
    steps = [S.SimStep(f"s{i}", plats[i % len(plats)].name,
                       compute=S.Dist(c, 0.0), fetch=S.Dist(f, 0.0))
             for i, (c, f) in enumerate(steps_raw)]
    sim = S.WorkflowSimulator(plats, seed=seed)
    base = sim.run_request(steps, 1e6, prefetch=False).total_s
    sim2 = S.WorkflowSimulator(plats, seed=seed)
    geo = sim2.run_request(steps, 1e6, prefetch=True).total_s
    assert geo <= base + 1e-9


@given(st.lists(st.tuples(compute_st, fetch_st), min_size=2, max_size=5))
@settings(max_examples=30, deadline=None)
def test_hiding_bounded_by_total_fetch(steps_raw):
    """The saving can never exceed the total fetch + cold-start time."""
    plats = S.paper_platforms()
    steps = [S.SimStep(f"s{i}", "tinyfaas-edge", compute=S.Dist(c, 0.0),
                       fetch=S.Dist(f, 0.0)) for i, (c, f) in
             enumerate(steps_raw)]
    total_fetch = sum(f for _, f in steps_raw)
    sim = S.WorkflowSimulator(plats, seed=0)
    tr_base = sim.run_request(steps, 1e6, prefetch=False)
    sim2 = S.WorkflowSimulator(plats, seed=0)
    tr_geo = sim2.run_request(steps, 1e6, prefetch=True)
    # first request is cold; both schedules pay it somewhere
    assert tr_base.total_s - tr_geo.total_s <= total_fetch + 5.0 + 1e-6


def test_double_billing_accounting(sim):
    """Eager pokes produce double-billing exactly when preparation finishes
    before the payload arrives."""
    steps = [S.SimStep("a", "tinyfaas-edge", compute=S.Dist(2.0, 0.0)),
             S.SimStep("b", "tinyfaas-edge", compute=S.Dist(0.1, 0.0),
                       fetch=S.Dist(0.2, 0.0))]
    tr = sim.run_request(steps, 1e6, prefetch=True)
    # b prepared after ~0.25s, payload after ~2s -> ~1.75s idle
    assert tr.double_billed_s == pytest.approx(1.75, abs=0.3)
