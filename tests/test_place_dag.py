"""shipping.place_dag: the exact DP (series-parallel + exhaustive) against
brute force and the greedy baseline; spec wiring; fallbacks."""

import itertools
import random

import pytest

from benchmarks.placement_bench import costs_from_tables, diamond_correlated
from repro.core.shipping import (
    PlacementCosts,
    dag_cost,
    place_dag,
    place_dag_greedy,
)
from repro.core.workflow import DataRef, StepSpec
from repro.dag import DagSpec, DagStep, place_dag_spec


def diamond_nodes():
    return {
        "a": StepSpec("a", "p1"),
        "b": StepSpec("b", "p1"),
        "c": StepSpec("c", "p1"),
        "d": StepSpec("d", "p1"),
    }


DIAMOND = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]


def test_respects_topological_order():
    """Every node is placed, and placement decisions see all predecessor
    placements even when the edge list is shuffled out of topo order."""
    nodes = diamond_nodes()
    transfer = {
        ("p1", "p1"): 0.0,
        ("p1", "p2"): 5.0,
        ("p2", "p2"): 0.0,
        ("p2", "p1"): 5.0,
    }
    for edges in (DIAMOND, list(reversed(DIAMOND))):
        placement = place_dag(
            nodes,
            edges,
            {n: ["p1", "p2"] for n in nodes},
            costs_from_tables(transfer=transfer),
        )
        assert set(placement) == set(nodes)
        # everything colocates: any cross-platform hop costs 5s
        assert len(set(placement.values())) == 1


def test_fan_in_sums_transfers_from_all_predecessors():
    """The join 'd' must pay transfer from BOTH b (on pb) and c (on pc):
    the platform minimizing the SUM wins, not the one closest to a single
    predecessor."""
    nodes = diamond_nodes()
    # pin the branches apart; d chooses among px (cheap sum) and py (cheap
    # from b only — a single-predecessor scorer would wrongly pick it)
    candidates = {"a": ["p1"], "b": ["pb"], "c": ["pc"], "d": ["px", "py"]}
    transfer = {
        ("pb", "px"): 1.0,
        ("pc", "px"): 1.0,  # sum 2.0
        ("pb", "py"): 0.0,
        ("pc", "py"): 3.0,  # sum 3.0
    }
    placement = place_dag(
        nodes, DIAMOND, candidates, costs_from_tables(transfer=transfer)
    )
    assert placement["b"] == "pb" and placement["c"] == "pc"
    assert placement["d"] == "px"


def test_fallback_to_own_platform_without_candidates():
    nodes = {"a": StepSpec("a", "p-own"), "b": StepSpec("b", "p-other")}
    placement = place_dag(nodes, [("a", "b")], {}, costs_from_tables())
    assert placement == {"a": "p-own", "b": "p-other"}


def test_fetch_vs_transfer_tradeoff():
    """A data-heavy node ships to the platform where its data is cheap even
    when that platform is farther from the predecessor (§4.3 generalized)."""
    nodes = {
        "a": StepSpec("a", "p1"),
        "b": StepSpec("b", "p1", data_deps=(DataRef("blob", "us", int(30e6)),)),
    }
    fetch = {("b", "p1"): 4.0, ("b", "us"): 0.4}
    transfer = {("p1", "p1"): 0.0, ("p1", "us"): 0.8}
    placement = place_dag(
        nodes,
        [("a", "b")],
        {"b": ["p1", "us"]},
        costs_from_tables(fetch=fetch, transfer=transfer),
        prefetch=False,
    )
    assert placement["b"] == "us"


def _brute_force_cost(nodes, edges, candidates, costs, prefetch=True):
    names = list(nodes)
    cand = [candidates.get(n, [nodes[n].platform]) for n in names]
    return min(
        dag_cost(nodes, edges, dict(zip(names, combo)), costs, prefetch)
        for combo in itertools.product(*cand)
    )


def _random_case(rnd, topology):
    plats = ["p0", "p1", "p2"]
    if topology == "chain":
        names = [f"s{i}" for i in range(rnd.randint(2, 4))]
        edges = [(names[i], names[i + 1]) for i in range(len(names) - 1)]
    elif topology == "diamond":
        names = ["a", "b", "c", "d"]
        edges = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]
    else:  # non-series-parallel: exercises the exhaustive fallback
        names = ["a", "b", "c", "d"]
        edges = [("a", "b"), ("a", "c"), ("b", "c"), ("b", "d"), ("c", "d")]
    nodes = {n: StepSpec(n, "p0") for n in names}
    fetch = {(n, p): rnd.uniform(0, 2) for n in names for p in plats}
    compute = {(n, p): rnd.uniform(0.1, 2) for n in names for p in plats}
    transfer = {
        (a, b): 0.0 if a == b else rnd.uniform(0.05, 1.0)
        for a in plats
        for b in plats
    }
    costs = PlacementCosts(
        fetch_s=lambda name, p, deps: fetch[(name, p)],
        compute_s=lambda name, p: compute[(name, p)],
        transfer_s=lambda a, b, size: transfer[(a, b)],
        payload_size=1.0,
    )
    return nodes, edges, {n: plats for n in names}, costs


@pytest.mark.parametrize("topology", ["chain", "diamond", "braid"])
def test_dp_matches_bruteforce(topology):
    """The tentpole guarantee: place_dag minimizes dag_cost exactly — on
    series-parallel shapes via the reduction DP, on the non-SP braid via
    the exhaustive fallback — for both prefetch modes."""
    rnd = random.Random(20240801)
    for trial in range(15):
        nodes, edges, cand, costs = _random_case(rnd, topology)
        for prefetch in (True, False):
            placed = place_dag(nodes, edges, cand, costs, prefetch)
            got = dag_cost(nodes, edges, placed, costs, prefetch)
            want = _brute_force_cost(nodes, edges, cand, costs, prefetch)
            assert got == pytest.approx(want, rel=1e-9), (topology, trial)


@pytest.mark.parametrize("topology", ["chain", "diamond", "braid"])
def test_dp_never_worse_than_greedy(topology):
    rnd = random.Random(7)
    for _ in range(15):
        nodes, edges, cand, costs = _random_case(rnd, topology)
        exact = dag_cost(nodes, edges, place_dag(nodes, edges, cand, costs), costs)
        greedy_pl = place_dag_greedy(nodes, edges, cand, costs)
        greedy = dag_cost(nodes, edges, greedy_pl, costs)
        assert exact <= greedy + 1e-9


def test_dp_beats_greedy_on_correlated_diamond():
    """Acceptance: branches whose data homes are platform-correlated trap
    the greedy (each branch ships to its local optimum, the join pays a
    cross-platform fan-in); the exact DP is strictly better."""
    nodes, edges, cand, costs = diamond_correlated()
    exact = dag_cost(nodes, edges, place_dag(nodes, edges, cand, costs), costs)
    greedy_pl = place_dag_greedy(nodes, edges, cand, costs)
    greedy = dag_cost(nodes, edges, greedy_pl, costs)
    assert exact < greedy - 0.5, (exact, greedy)


def test_isolated_nodes_placed_independently():
    nodes = {
        "a": StepSpec("a", "p1"),
        "b": StepSpec("b", "p1"),
        "lonely": StepSpec("lonely", "p1"),
    }
    fetch = {("lonely", "p1"): 3.0, ("lonely", "p2"): 0.1}
    placement = place_dag(
        nodes,
        [("a", "b")],
        {"lonely": ["p1", "p2"]},
        costs_from_tables(fetch=fetch),
        prefetch=False,
    )
    assert placement["lonely"] == "p2"
    assert placement["a"] == "p1" and placement["b"] == "p1"


def test_place_dag_spec_wires_routes():
    """place_dag output lands back in DagSpec routes (apply_placement)."""
    spec = DagSpec(
        (
            DagStep("a", "p1"),
            DagStep("b", "p1"),
            DagStep("c", "p1"),
            DagStep("d", "p1"),
        ),
        tuple(DIAMOND),
    )
    transfer = {
        ("pb", "px"): 1.0,
        ("pc", "px"): 1.0,
        ("pb", "py"): 0.0,
        ("pc", "py"): 3.0,
    }
    placed = place_dag_spec(
        spec,
        {"a": ["p1"], "b": ["pb"], "c": ["pc"], "d": ["px", "py"]},
        costs_from_tables(transfer=transfer),
    )
    assert placed.node("d").platform == "px"
    assert placed.edges == spec.edges
    assert placed.node("a").platform == "p1"
