"""shipping.place_dag: topological scoring, fan-in transfer sums, fallback."""

from repro.core.shipping import PlacementCosts, place_dag
from repro.core.workflow import DataRef, StepSpec
from repro.dag import DagSpec, DagStep, place_dag_spec


def costs_from_tables(fetch=None, compute=None, transfer=None):
    fetch = fetch or {}
    compute = compute or {}
    transfer = transfer or {}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: fetch.get((name, p), 0.0),
        compute_s=lambda name, p: compute.get((name, p), 0.1),
        transfer_s=lambda a, b, size: transfer.get((a, b), 0.0),
        payload_size=1.0,
    )


def diamond_nodes():
    return {
        "a": StepSpec("a", "p1"),
        "b": StepSpec("b", "p1"),
        "c": StepSpec("c", "p1"),
        "d": StepSpec("d", "p1"),
    }


DIAMOND = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]


def test_respects_topological_order():
    """Every node is placed, and placement decisions see all predecessor
    placements even when the edge list is shuffled out of topo order."""
    nodes = diamond_nodes()
    transfer = {
        ("p1", "p1"): 0.0,
        ("p1", "p2"): 5.0,
        ("p2", "p2"): 0.0,
        ("p2", "p1"): 5.0,
    }
    for edges in (DIAMOND, list(reversed(DIAMOND))):
        placement = place_dag(
            nodes,
            edges,
            {n: ["p1", "p2"] for n in nodes},
            costs_from_tables(transfer=transfer),
        )
        assert set(placement) == set(nodes)
        # everything colocates: any cross-platform hop costs 5s
        assert len(set(placement.values())) == 1


def test_fan_in_sums_transfers_from_all_predecessors():
    """The join 'd' must pay transfer from BOTH b (on pb) and c (on pc):
    the platform minimizing the SUM wins, not the one closest to a single
    predecessor."""
    nodes = diamond_nodes()
    # pin the branches apart; d chooses among px (cheap sum) and py (cheap
    # from b only — a single-predecessor scorer would wrongly pick it)
    candidates = {"a": ["p1"], "b": ["pb"], "c": ["pc"], "d": ["px", "py"]}
    transfer = {
        ("pb", "px"): 1.0,
        ("pc", "px"): 1.0,  # sum 2.0
        ("pb", "py"): 0.0,
        ("pc", "py"): 3.0,  # sum 3.0
    }
    placement = place_dag(
        nodes, DIAMOND, candidates, costs_from_tables(transfer=transfer)
    )
    assert placement["b"] == "pb" and placement["c"] == "pc"
    assert placement["d"] == "px"


def test_fallback_to_own_platform_without_candidates():
    nodes = {"a": StepSpec("a", "p-own"), "b": StepSpec("b", "p-other")}
    placement = place_dag(nodes, [("a", "b")], {}, costs_from_tables())
    assert placement == {"a": "p-own", "b": "p-other"}


def test_fetch_vs_transfer_tradeoff():
    """A data-heavy node ships to the platform where its data is cheap even
    when that platform is farther from the predecessor (§4.3 generalized)."""
    nodes = {
        "a": StepSpec("a", "p1"),
        "b": StepSpec("b", "p1", data_deps=(DataRef("blob", "us", int(30e6)),)),
    }
    fetch = {("b", "p1"): 4.0, ("b", "us"): 0.4}
    transfer = {("p1", "p1"): 0.0, ("p1", "us"): 0.8}
    placement = place_dag(
        nodes,
        [("a", "b")],
        {"b": ["p1", "us"]},
        costs_from_tables(fetch=fetch, transfer=transfer),
        prefetch=False,
    )
    assert placement["b"] == "us"


def test_place_dag_spec_wires_routes():
    """place_dag output lands back in DagSpec routes (apply_placement)."""
    spec = DagSpec(
        (
            DagStep("a", "p1"),
            DagStep("b", "p1"),
            DagStep("c", "p1"),
            DagStep("d", "p1"),
        ),
        tuple(DIAMOND),
    )
    transfer = {
        ("pb", "px"): 1.0,
        ("pc", "px"): 1.0,
        ("pb", "py"): 0.0,
        ("pc", "py"): 3.0,
    }
    placed = place_dag_spec(
        spec,
        {"a": ["p1"], "b": ["pb"], "c": ["pc"], "d": ["px", "py"]},
        costs_from_tables(transfer=transfer),
    )
    assert placed.node("d").platform == "px"
    assert placed.edges == spec.edges
    assert placed.node("a").platform == "p1"
