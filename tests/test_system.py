"""End-to-end system tests: the paper's architecture running real JAX work.

A federated deployment of a 3-step ML workflow (preprocess on an edge
platform -> model forward on a cloud platform -> postprocess) exercising
every GeoFF mechanism at once: per-request specs, cascading pokes, compile
pre-warming, data pre-fetching, object-store payload buffering, wrapper
overhead, and re-composition — with results identical to a local run.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DataRef, Deployment, Platform, PlatformRegistry,
                        StepSpec, WorkflowSpec)
from repro.configs.registry import smoke_config
from repro.models import model as M


@pytest.fixture(scope="module")
def system():
    cfg = smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    reg = PlatformRegistry()
    reg.register(Platform("edge", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("pod-a", "us", kind="cloud"))
    reg.register(Platform("pod-b", "us", kind="cloud"))
    dep = Deployment(reg)
    dep.store.network.set_link("eu", "us", 0.02, 100e6)

    vocab = cfg.vocab_size
    rng = np.random.default_rng(0)
    dep.store.put("norm/table", rng.normal(size=(vocab,)).astype(np.float32),
                  region="us")

    def preprocess(payload, data):
        toks = np.asarray(payload) % (vocab - 1) + 1
        return toks.astype(np.int32)

    def forward(payload, data):
        logits, _ = M.prefill(cfg, params,
                              {"tokens": jnp.asarray(payload)[None]})
        return np.asarray(logits[0])

    def postprocess(payload, data):
        table = data["norm/table"]
        return int(np.argmax(payload + 0.01 * table))

    dep.deploy("preprocess", preprocess, ["edge"])
    dep.deploy("forward", forward, ["pod-a", "pod-b"])
    dep.deploy("postprocess", postprocess, ["pod-a", "pod-b", "edge"])
    yield cfg, params, dep, vocab
    dep.shutdown()


def spec(fw_platform="pod-a", post_platform="pod-a"):
    return WorkflowSpec((
        StepSpec("preprocess", "edge"),
        StepSpec("forward", fw_platform),
        StepSpec("postprocess", post_platform,
                 data_deps=(DataRef("norm/table", "us"),))), "e2e")


def test_end_to_end_result_matches_local(system):
    cfg, params, dep, vocab = system
    x = np.arange(12)
    out = dep.run(spec(), x).outputs
    toks = (x % (vocab - 1) + 1).astype(np.int32)
    logits, _ = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)[None]})
    table, _ = dep.store.get("norm/table", "us")
    want = int(np.argmax(np.asarray(logits[0]) + 0.01 * table))
    assert out == want


def test_per_request_rerouting(system):
    cfg, params, dep, vocab = system
    x = np.arange(8)
    a = dep.run(spec("pod-a", "pod-a"), x).outputs
    b = dep.run(spec("pod-b", "edge"), x).outputs
    assert a == b       # same function, different platforms, same result


def test_timelines_cover_all_steps(system):
    cfg, params, dep, vocab = system
    r = dep.run(spec(), np.arange(8))
    assert set(r.timeline) == {"preprocess", "forward", "postprocess"}
    for t in r.timeline.values():
        assert set(t) == {
            "warm_s",
            "fetch_s",
            "compute_s",
            "payload_wait_s",
            "transfer_s",
        }


def test_prefetch_stats_accumulate(system):
    cfg, params, dep, vocab = system
    before = dep.prefetcher.stats["prefetched"]
    dep.run(spec(), np.arange(8))
    assert dep.prefetcher.stats["prefetched"] > before
