"""WorkflowSpec / StepSpec / DataRef: serialization + recomposition."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.workflow import DataRef, StepSpec, WorkflowSpec

names = st.text(st.characters(min_codepoint=97, max_codepoint=122),
                min_size=1, max_size=8)


def make_step(name, platform, nd):
    return StepSpec(name, platform,
                    tuple(DataRef(f"k{i}", "eu", 100 * i) for i in range(nd)),
                    prefetch=bool(nd % 2), sync=False,
                    params={"x": nd})


@given(st.lists(st.tuples(names, names, st.integers(0, 3)), min_size=1,
                max_size=6))
@settings(max_examples=50, deadline=None)
def test_json_roundtrip(steps_raw):
    spec = WorkflowSpec(tuple(make_step(n, p, d) for n, p, d in steps_raw),
                        "wf")
    again = WorkflowSpec.from_json(spec.to_json())
    assert again == spec


def test_successor_chain():
    spec = WorkflowSpec(tuple(make_step(f"s{i}", "p", 0) for i in range(4)))
    assert spec.successor(0).name == "s1"
    assert spec.successor(3) is None


def test_reroute_is_pure_recomposition():
    spec = WorkflowSpec((make_step("a", "p1", 1), make_step("b", "p1", 2)))
    moved = spec.reroute("b", "p2")
    assert moved.steps[1].platform == "p2"
    assert moved.steps[1].data_deps == spec.steps[1].data_deps
    assert spec.steps[1].platform == "p1"          # original untouched
    assert moved.steps[0] == spec.steps[0]


def test_empty_workflow_rejected():
    with pytest.raises(AssertionError):
        WorkflowSpec(())
