"""Per-arch smoke tests + model-level equivalence properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.configs.base import applicable_shapes, SHAPES
from repro.models import model as M


def make_batch(cfg, B=2, T=32, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.input_kind == "frames":
        return {"frames": jax.random.normal(key, (B, T, cfg.d_model)),
                "labels": jnp.zeros((B, T), jnp.int32)}
    if cfg.input_kind == "tokens+patches":
        P = cfg.num_patches
        return {"tokens": jnp.ones((B, T - P), jnp.int32),
                "patches": jax.random.normal(key, (B, P, cfg.d_model)),
                "labels": jnp.zeros((B, T - P), jnp.int32)}
    return {"tokens": jax.random.randint(key, (B, T), 1, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """REDUCED same-family config: one forward + one optimizer step on CPU,
    asserting output shapes and no NaNs (mandated per-arch smoke)."""
    from repro.optim import AdamW, AdamWConfig
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg)
    loss, metrics = M.forward_train(cfg, params, batch)
    assert jnp.isfinite(loss), arch
    opt = AdamW(AdamWConfig(warmup_steps=1, total_steps=10))
    step = M.make_train_step(cfg, opt)
    p2, o2, m2 = step(params, opt.init(params), batch, jnp.int32(0))
    assert jnp.isfinite(m2["loss"])
    assert jnp.isfinite(m2["grad_norm"])
    # params actually changed
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                               params, p2)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-370m",
                                  "recurrentgemma-9b", "gemma3-27b"])
def test_decode_matches_full_forward(arch):
    """Prefill + N decode steps produce the same final logits as one full
    forward over the whole sequence (KV cache / SSM state correctness)."""
    cfg = smoke_config(arch)
    if not cfg.supports_decode:
        pytest.skip("encoder-only")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    T0, N = 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T0 + N), 1, 255)
    _, caches = M.prefill(cfg, params, {"tokens": toks[:, :T0]})
    from repro.serving.engine import pad_cache
    caches = pad_cache(caches, T0 + N, T0, cfg=cfg)
    logits = None
    for i in range(N):
        logits, caches = M.decode_step(cfg, params, toks[:, T0 + i:T0 + i + 1],
                                       caches, jnp.int32(T0 + i))
    full_logits, _ = M.prefill(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_chunked_attention_equals_full():
    cfg = smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    batch = make_batch(cfg, B=2, T=64)
    l_full, _ = M.forward_train(cfg.replace(attn_chunk_q=0), params, batch)
    l_unroll, _ = M.forward_train(
        cfg.replace(attn_chunk_q=16, attn_chunk_unroll=True), params, batch)
    l_scan, _ = M.forward_train(
        cfg.replace(attn_chunk_q=16, attn_chunk_unroll=False), params, batch)
    np.testing.assert_allclose(float(l_full), float(l_unroll), rtol=1e-5)
    np.testing.assert_allclose(float(l_full), float(l_scan), rtol=1e-5)


def test_banded_local_attention_equals_masked():
    """Sliding-window attention via banded K/V slices == full-score mask."""
    cfg = smoke_config("gemma3-27b").replace(local_window=8)
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    batch = make_batch(cfg, B=1, T=64)
    l_full, _ = M.forward_train(cfg.replace(attn_chunk_q=0), params, batch)
    l_band, _ = M.forward_train(cfg.replace(attn_chunk_q=16), params, batch)
    np.testing.assert_allclose(float(l_full), float(l_band), rtol=1e-5)


def test_scan_equals_unrolled_layers():
    cfg = smoke_config("recurrentgemma-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    batch = make_batch(cfg, B=2, T=24)
    l_scan, _ = M.forward_train(cfg.replace(scan_layers=True), params, batch)
    l_unroll, _ = M.forward_train(cfg.replace(scan_layers=False), params,
                                  batch)
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)


def test_ce_chunking_equals_full():
    cfg = smoke_config("granite-moe-3b-a800m")
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    batch = make_batch(cfg, B=2, T=32)
    l_full, _ = M.forward_train(cfg.replace(ce_chunk=0), params, batch)
    l_chunk, _ = M.forward_train(cfg.replace(ce_chunk=8), params, batch)
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=1e-5)


def test_moe_matches_dense_loop_reference():
    """Group-local scatter dispatch == a naive per-token loop over experts
    (capacity large enough that nothing drops)."""
    from repro.models import layers as L
    cfg = smoke_config("granite-moe-3b-a800m").replace(capacity_factor=8.0)
    key = jax.random.PRNGKey(8)
    p = {k: v for k, v in zip(
        ["router", "w_gate", "w_up", "w_down"],
        [jax.random.normal(jax.random.fold_in(key, i), s) * 0.2
         for i, s in enumerate([
             (cfg.d_model, cfg.num_experts),
             (cfg.num_experts, cfg.d_model, cfg.d_ff),
             (cfg.num_experts, cfg.d_model, cfg.d_ff),
             (cfg.num_experts, cfg.d_ff, cfg.d_model)])])}
    x = jax.random.normal(jax.random.fold_in(key, 9), (2, 8, cfg.d_model))
    out, aux = L.moe(cfg, p, x)

    # naive reference
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gw, gi = jax.lax.top_k(probs, cfg.top_k)
    gw = gw / gw.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for b in range(2):
        for t in range(8):
            acc = jnp.zeros(cfg.d_model)
            for j in range(cfg.top_k):
                e = int(gi[b, t, j])
                h = jax.nn.silu(x[b, t] @ p["w_gate"][e]) * (
                    x[b, t] @ p["w_up"][e])
                acc = acc + gw[b, t, j] * (h @ p["w_down"][e])
            ref = ref.at[b, t].set(acc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_applicable_shapes_per_family():
    cells = {a: [s.name for s in applicable_shapes(get_config(a))]
             for a in ARCH_IDS}
    assert "long_500k" in cells["mamba2-370m"]
    assert "long_500k" in cells["recurrentgemma-9b"]
    assert "long_500k" not in cells["llama3.2-3b"]
    assert "decode_32k" not in cells["hubert-xlarge"]
    assert sum(len(v) for v in cells.values()) == 31


def test_param_counts_in_expected_range():
    """Sanity: analytic param counts are in the family ballpark."""
    expect = {"llama3.2-3b": (2.5e9, 4.5e9), "qwen3-32b": (28e9, 36e9),
              "gemma3-27b": (22e9, 30e9), "mamba2-370m": (0.3e9, 0.45e9),
              "recurrentgemma-9b": (7e9, 11e9),
              # the assigned 48L/64e config is ~28B total; its ACTIVE count
              # (~4B with top-6) is what matches the "A3B" name
              "moonshot-v1-16b-a3b": (26e9, 30e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
    active = get_config("moonshot-v1-16b-a3b").active_param_count()
    assert 2.5e9 < active < 5.5e9, active
