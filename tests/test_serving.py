"""Serving engine: continuous batching, cache handoff, disaggregation."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import smoke_config
from repro.models import model as M
from repro.serving import Request, ServingEngine, pad_cache


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_completes_all(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=2, max_len=48)
    rng = np.random.default_rng(0)
    n = 5
    for i in range(n):
        eng.submit(Request(i, rng.integers(1, 200, size=6).astype(np.int32),
                           max_new_tokens=4))
    stats = eng.run()
    assert stats["done"] == n
    assert stats["prefills"] == n
    # slots were reused: more requests than slots
    assert eng.max_batch < n


def test_greedy_decode_matches_full_context(setup):
    """Engine tokens == argmax of a full-context forward at each position."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, 200, size=8).astype(np.int32)
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64)
    req = Request(0, prompt, max_new_tokens=4)
    eng.submit(req)
    eng.run()
    ctx = list(prompt)
    for tok in req.tokens:
        logits, _ = M.prefill(cfg, params,
                              {"tokens": jnp.asarray(ctx)[None]})
        assert int(jnp.argmax(logits[0])) == tok
        ctx.append(tok)


def test_pad_cache_preserves_prefix(setup):
    cfg, params = setup
    toks = jnp.arange(1, 9)[None]
    _, caches = M.prefill(cfg, params, {"tokens": toks})
    padded = pad_cache(caches, 32, 8, cfg=cfg)
    k_small = jax.tree_util.tree_leaves(caches)[0]
    k_big = jax.tree_util.tree_leaves(padded)[0]
    assert k_big.shape[2] == 32 and k_small.shape[2] == 8
    np.testing.assert_allclose(np.asarray(k_big[:, :, :8]),
                               np.asarray(k_small))


def test_disaggregated_prefill_decode_workflow(setup):
    """Prefill on one GeoFF platform, decode on another; the KV cache ships
    through the object store (the serving use of function/data shipping)."""
    cfg, params = setup
    from repro.core import (DataRef, Deployment, Platform, PlatformRegistry,
                            StepSpec, WorkflowSpec)
    reg = PlatformRegistry()
    reg.register(Platform("prefill-pod", "us", native_prefetch=True))
    reg.register(Platform("decode-pod", "us", native_prefetch=True))
    dep = Deployment(reg)

    rng = np.random.default_rng(2)
    prompt = rng.integers(1, 200, size=8).astype(np.int32)

    def prefill_fn(payload, data):
        logits, caches = M.prefill(cfg, params,
                                   {"tokens": jnp.asarray(payload)[None]})
        caches = pad_cache(caches, 32, len(payload), cfg=cfg)
        key = "kv/req0"
        dep.store.put(key, jax.tree_util.tree_map(np.asarray, caches),
                      region="us")
        return {"first_tok": int(jnp.argmax(logits[0])), "kv_key": key,
                "pos": len(payload)}

    def decode_fn(payload, data):
        # the KV cache is an INTERMEDIATE product (created mid-workflow), so
        # it is shipped by reference in the payload and fetched here — only
        # pre-existing external deps are pre-fetchable (GeoFF semantics)
        host_caches, _ = dep.store.get(payload["kv_key"], "us")
        caches = jax.tree_util.tree_map(jnp.asarray, host_caches)
        tok = payload["first_tok"]
        toks = [tok]
        cur = payload["pos"]
        for _ in range(3):
            logits, caches = M.decode_step(
                cfg, params, jnp.asarray([[tok]], jnp.int32), caches,
                jnp.int32(cur))
            tok = int(jnp.argmax(logits[0]))
            toks.append(tok)
            cur += 1
        return toks

    dep.deploy("prefill", prefill_fn, ["prefill-pod"])
    dep.deploy("decode", decode_fn, ["decode-pod"])
    wf = WorkflowSpec((
        StepSpec("prefill", "prefill-pod"),
        StepSpec("decode", "decode-pod")))
    out = dep.run(wf, prompt).outputs

    # reference: single-host greedy chain
    ctx = list(prompt)
    want = []
    for _ in range(4):
        logits, _ = M.prefill(cfg, params, {"tokens": jnp.asarray(ctx)[None]})
        t = int(jnp.argmax(logits[0]))
        want.append(t)
        ctx.append(t)
    assert out == want
    dep.shutdown()
