"""The two-phase choreography middleware: overlap, pre-warm, wrapper."""
import time

import numpy as np
import pytest

from repro.core import (DataRef, Deployment, Platform, PlatformRegistry,
                        StepSpec, WorkflowSpec)


def make_dep(enforce=True):
    reg = PlatformRegistry()
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us", kind="cloud"))
    dep = Deployment(reg)
    dep.store.enforce_latency = enforce
    dep.store.network.set_link("eu", "us", 0.05, 5e6)
    return dep


def slow_handler(duration):
    def h(payload, data):
        time.sleep(duration)
        return payload
    return h


def consume_handler(payload, data):
    # touches its prefetched dependency
    assert "dep" in data
    return float(np.sum(data["dep"])) + (payload or 0.0)


def test_prefetch_hides_data_latency():
    """Data lives FAR from step_b; with pre-fetching the fetch overlaps
    step_a's compute, without it the fetch is serial."""
    dep = make_dep()
    dep.store.put("dep", np.ones(int(2e6 // 8)), region="eu")  # 2MB in eu
    dep.deploy("a", slow_handler(0.5), ["edge-eu"])
    dep.deploy("b", consume_handler, ["cloud-us"])     # b runs in us

    deps = (DataRef("dep", "eu"),)
    wf_pf = WorkflowSpec((StepSpec("a", "edge-eu"),
                          StepSpec("b", "cloud-us", data_deps=deps)))
    wf_np = WorkflowSpec((StepSpec("a", "edge-eu", prefetch=False),
                          StepSpec("b", "cloud-us", data_deps=deps,
                                   prefetch=False)))
    # warm both paths once (compile/thread pools)
    dep.run(wf_pf, 1.0)
    dep.run(wf_np, 1.0)
    t_pf = min(dep.run(wf_pf, 1.0).total_s for _ in range(2))
    t_np = min(dep.run(wf_np, 1.0).total_s for _ in range(2))
    # fetch is ~0.43s (2MB @5MB/s + rtt); step_a runs 0.5s -> fully hidden
    assert t_pf < t_np - 0.2, (t_pf, t_np)
    dep.shutdown()


def test_results_identical_with_and_without_prefetch():
    dep = make_dep(enforce=False)
    rng = np.random.default_rng(0)
    dep.store.put("dep", rng.normal(size=100), region="eu")
    dep.deploy("a", lambda p, d: p * 2, ["edge-eu"])
    dep.deploy("b", consume_handler, ["cloud-us"])
    deps = (DataRef("dep", "eu"),)
    wf_pf = WorkflowSpec((StepSpec("a", "edge-eu"),
                          StepSpec("b", "cloud-us", data_deps=deps)))
    wf_np = WorkflowSpec((StepSpec("a", "edge-eu", prefetch=False),
                          StepSpec("b", "cloud-us", data_deps=deps,
                                   prefetch=False)))
    r1 = dep.run(wf_pf, 3.0).outputs
    r2 = dep.run(wf_np, 3.0).outputs
    assert r1 == pytest.approx(r2)
    dep.shutdown()


def test_prewarm_hides_compile():
    """With a compile_fn registered, the poke pre-compiles; the payload path
    then hits the cache."""
    import jax
    import jax.numpy as jnp
    dep = make_dep(enforce=False)

    def stepfn(x):
        return jnp.tanh(x @ x.T).sum()

    abstract = (jax.ShapeDtypeStruct((64, 64), jnp.float32),)
    dep.deploy("a", slow_handler(0.3), ["edge-eu"])
    dep.deploy("b", lambda p, d: float(stepfn(jnp.asarray(p))), ["cloud-us"],
               abstract_args=abstract, compile_fn=stepfn)
    wf = WorkflowSpec((StepSpec("a", "edge-eu"), StepSpec("b", "cloud-us")))
    x = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    r1 = dep.run(wf, x)
    # the poke started the compile (a prewarm, never a cold miss), and at
    # least part of it was hidden behind step a's 0.3 s compute
    assert dep.cache.stats["prewarms"] >= 1
    assert dep.cache.stats["misses"] == 0
    assert dep.cache.stats["hidden_compile_s"] > 0
    assert r1.timeline["b"]["warm_s"] < dep.cache.stats["hidden_compile_s"] \
        + 0.3
    # second request: fully warm
    r2 = dep.run(wf, x)
    assert r2.timeline["b"]["warm_s"] < 0.05
    dep.shutdown()


def test_wrapper_overhead_below_1ms():
    """Paper §4.1: the platform wrapper adds < 1 ms per call."""
    from repro.core.platform import PlatformWrapper
    plat = Platform("edge-eu", "eu")
    w = PlatformWrapper(plat, lambda p, d: p, "noop")
    for _ in range(200):
        w(1, {})
    assert w.overhead_s / w.calls < 1e-3


def test_adhoc_recomposition_no_redeploy():
    """The same deployment serves a rerouted spec without redeploying."""
    dep = make_dep(enforce=False)
    dep.deploy("a", lambda p, d: p + 1, ["edge-eu", "cloud-us"])
    dep.deploy("b", lambda p, d: p * 10, ["edge-eu", "cloud-us"])
    wf = WorkflowSpec((StepSpec("a", "edge-eu"), StepSpec("b", "cloud-us")))
    assert dep.run(wf, 1).outputs == 20
    assert dep.run(wf.reroute("b", "edge-eu"), 1).outputs == 20
    dep.shutdown()


def test_missing_deployment_raises():
    dep = make_dep(enforce=False)
    dep.deploy("a", lambda p, d: p, ["edge-eu"])
    wf = WorkflowSpec((StepSpec("a", "cloud-us"),))
    with pytest.raises(KeyError):
        dep.run(wf, 0)
    dep.shutdown()
