"""repro.adapt: telemetry hub, observed costs, recomposition controller,
and the AdaptiveDeployment hot-swap over the real dataflow engine."""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (
    AdaptiveDeployment,
    PlacementScorer,
    RecompositionController,
    RouteTable,
    TelemetryHub,
    attach,
    observed_costs,
)
from repro.core import DataRef, Platform, PlatformRegistry
from repro.core.shipping import PlacementCosts
from repro.dag import DagDeployment, DagSpec, DagStep


def fallback_costs(compute=None, transfer_cross=0.5):
    compute = compute or {}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.25 * len(deps),
        compute_s=lambda name, p: compute.get((name, p), 0.1),
        transfer_s=lambda a, b, size: 0.0 if a == b else transfer_cross,
        payload_size=1.5e6,
    )


# ---------------------------------------------------------------------------
# TelemetryHub
# ---------------------------------------------------------------------------
def test_hub_ewma_and_min_samples():
    hub = TelemetryHub(alpha=0.5)
    assert hub.compute_s("f", "p") is None
    hub.record_compute("f", "p", 1.0)
    assert hub.compute_s("f", "p") == pytest.approx(1.0)
    assert hub.compute_s("f", "p", min_samples=2) is None
    hub.record_compute("f", "p", 2.0)
    assert hub.compute_s("f", "p", min_samples=2) == pytest.approx(1.5)


def test_hub_transfer_is_observed_seconds_not_rescaled():
    """The transfer estimate is the link's observed per-transfer EWMA; it
    must NOT be linearly rescaled to the queried size (latency-dominated
    links would explode a 64 B observation to a 1.5 MB query)."""
    hub = TelemetryHub()
    hub.record_transfer("eu", "us", 64, 0.05)
    hub.record_transfer("eu", "us", 64, 0.05)
    assert hub.transfer_s("eu", "us", 1.5e6) == pytest.approx(0.05)
    assert hub.transfer_s("us", "eu", 64) is None  # directional


def test_hub_cold_start_rate_and_snapshot():
    hub = TelemetryHub()
    assert hub.cold_start_rate("f", "p") is None
    hub.record_cold_start("f", "p")
    hub.record_warm_hit("f", "p")
    hub.record_warm_hit("f", "p")
    assert hub.cold_start_rate("f", "p") == pytest.approx(1 / 3)
    hub.record_fetch("k", "eu", 0.2)
    snap = hub.snapshot()
    assert snap["cold_starts"]["f@p"] == 1
    assert snap["warm_hits"]["f@p"] == 2
    assert snap["fetch_s"]["k@eu"] == pytest.approx(0.2)


def test_hub_is_thread_safe_under_contention():
    hub = TelemetryHub(alpha=0.5)

    def hammer():
        for _ in range(500):
            hub.record_compute("f", "p", 1.0)
            hub.record_transfer("a", "b", 10, 0.1)
            hub.record_cold_start("f", "p")

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert hub.snapshot()["cold_starts"]["f@p"] == 4000
    assert hub.compute_s("f", "p") == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# observed_costs
# ---------------------------------------------------------------------------
def test_observed_costs_falls_back_when_unobserved():
    hub = TelemetryHub()
    costs = observed_costs(hub, fallback_costs(), min_samples=2)
    assert costs.compute_s("f", "p") == pytest.approx(0.1)
    assert costs.transfer_s("p", "q", 100) == pytest.approx(0.5)
    assert costs.fetch_s("f", "p", (DataRef("k"),)) == pytest.approx(0.25)


def test_observed_costs_prefers_measurements():
    hub = TelemetryHub(alpha=1.0)
    for _ in range(2):
        hub.record_compute("f", "p", 3.0)
        hub.record_transfer("ra", "rb", 100, 0.9)
        hub.record_fetch("k", "rb", 0.7)
    regions = {"p": "ra", "q": "rb"}
    costs = observed_costs(hub, fallback_costs(), regions=regions, min_samples=2)
    assert costs.compute_s("f", "p") == pytest.approx(3.0)
    assert costs.compute_s("f", "q") == pytest.approx(0.1)  # unobserved cell
    assert costs.transfer_s("p", "q", 100) == pytest.approx(0.9)
    assert costs.transfer_s("q", "p", 100) == pytest.approx(0.5)  # fallback
    # fetch observed at q's region for key k
    assert costs.fetch_s("f", "q", (DataRef("k"),)) == pytest.approx(0.7)


def test_observed_costs_fetch_is_all_or_fallback():
    """A half-observed dep set falls back entirely (mixed scales lie)."""
    hub = TelemetryHub(alpha=1.0)
    hub.record_fetch("k1", "p", 0.7)
    costs = observed_costs(hub, fallback_costs(), min_samples=1)
    deps = (DataRef("k1"), DataRef("k2"))
    assert costs.fetch_s("f", "p", deps) == pytest.approx(0.5)  # 0.25 * 2
    hub.record_fetch("k2", "p", 0.1)
    assert costs.fetch_s("f", "p", deps) == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# cold-start-rate-aware placement
# ---------------------------------------------------------------------------
def test_ewma_update_many_matches_batch_weight():
    from repro.core.timing import EWMA

    e = EWMA(alpha=0.5)
    e.update_many(2.0, 3)  # first batch seeds the value
    assert e.value == pytest.approx(2.0) and e.n == 3
    e.update_many(4.0, 2)  # weight = 1 - 0.5**2 = 0.75
    assert e.value == pytest.approx(0.25 * 2.0 + 0.75 * 4.0)
    assert e.n == 5
    e.update_many(9.0, 0)  # empty batch: no-op
    assert e.n == 5


def test_hub_cold_penalty_semantics():
    hub = TelemetryHub(alpha=1.0)
    assert hub.cold_penalty_s("f", "p") is None  # never invoked
    hub.record_warm_hit("f", "p")
    assert hub.cold_penalty_s("f", "p") == 0.0  # warm-only: free
    hub.record_cold_start("f", "p")  # legacy call: count, no duration
    assert hub.cold_penalty_s("f", "p") is None  # rate known, price unknown
    hub.record_cold_start("f", "p", 2.0)
    # 2 cold / 3 total, cold EWMA 2.0
    assert hub.cold_penalty_s("f", "p") == pytest.approx(2 / 3 * 2.0)


def test_hub_record_cold_start_batch():
    hub = TelemetryHub(alpha=1.0)
    hub.record_cold_start_batch("f", "p", 2, 6, np.array([1.0, 3.0]))
    assert hub.cold_start_rate("f", "p") == pytest.approx(0.25)
    assert hub.cold_penalty_s("f", "p") == pytest.approx(0.25 * 2.0)
    assert hub.snapshot()["cold_s"]["f@p"] == pytest.approx(2.0)


def test_observed_costs_fold_cold_rate_into_compute():
    hub = TelemetryHub(alpha=1.0)
    for _ in range(2):
        hub.record_compute("f", "p", 0.5)
    hub.record_cold_start_batch("f", "p", 5, 5, np.array([2.0]))
    costs = observed_costs(hub, fallback_costs(), min_samples=2)
    assert costs.compute_s("f", "p") == pytest.approx(0.5 + 0.5 * 2.0)
    off = observed_costs(hub, fallback_costs(), min_samples=2, cold_starts=False)
    assert off.compute_s("f", "p") == pytest.approx(0.5)


def test_high_cold_rate_platform_loses_placement_it_wins_on_compute():
    """pA computes faster than pB but keeps going cold; once the hub has
    priced the cold starts, the DP moves the step to steady pB."""
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(compute={("work", "pA"): 0.3, ("work", "pB"): 0.4})
    ctrl = RecompositionController(
        hub, fb, {"work": ["pA", "pB"]}, every_n=1, min_samples=1
    )
    spec = chain_spec("pA")
    assert ctrl.tick(spec) is None  # on compute alone pA wins
    # pA misses its warm pool on half the requests, 1.2 s per miss
    hub.record_cold_start_batch("work", "pA", 5, 5, np.array([1.2]))
    placement = ctrl.tick(spec)
    assert placement is not None and placement["work"] == "pB"


# ---------------------------------------------------------------------------
# RouteTable + RecompositionController
# ---------------------------------------------------------------------------
def chain_spec(work_platform="pA"):
    return DagSpec(
        (
            DagStep("ingest", "edge"),
            DagStep("work", work_platform),
            DagStep("deliver", "edge"),
        ),
        (("ingest", "work"), ("work", "deliver")),
        "t",
    )


def test_route_table_versions_and_history():
    table = RouteTable(chain_spec())
    assert table.version == 0
    v1 = table.swap(chain_spec("pB"))
    assert v1 == 1 and table.spec.node("work").platform == "pB"
    assert [v for v, _ in table.history] == [0, 1]
    version, spec = table.current()
    assert version == 1 and spec.node("work").platform == "pB"


def test_controller_recomposes_on_every_n_boundary():
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(compute={("work", "pA"): 0.1, ("work", "pB"): 0.2})
    ctrl = RecompositionController(
        hub, fb, {"work": ["pA", "pB"]}, every_n=4, min_samples=2
    )
    spec = chain_spec("pA")
    # pA degrades: observed compute way past pB's modeled cost
    for _ in range(3):
        hub.record_compute("work", "pA", 5.0)
        assert ctrl.tick(spec) is None  # ticks 1..3: not on the boundary
    placement = ctrl.tick(spec)  # tick 4: recompute -> move to pB
    assert placement is not None and placement["work"] == "pB"
    assert ctrl.stats["recomputes"] == 1 and ctrl.stats["swaps"] == 1


def test_controller_drift_trigger_fires_between_boundaries():
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(compute={("work", "pA"): 0.1, ("work", "pB"): 0.2})
    ctrl = RecompositionController(
        hub, fb, {"work": ["pA", "pB"]}, every_n=100, drift_ratio=1.5, min_samples=1
    )
    spec = chain_spec("pA")
    hub.record_compute("work", "pA", 0.1)
    # seed the drift reference: force one recompute on a boundary
    ctrl.every_n = 1
    assert ctrl.tick(spec) is None  # placement already optimal
    ctrl.every_n = 100
    # now degrade pA 20x: the NEXT tick must trigger off drift alone
    hub.record_compute("work", "pA", 2.0)
    placement = ctrl.tick(spec)
    assert placement == {"ingest": "edge", "work": "pB", "deliver": "edge"}
    assert ctrl.stats["drift_triggers"] == 1


def test_controller_stable_placement_returns_none():
    hub = TelemetryHub()
    fb = fallback_costs(compute={("work", "pA"): 0.1, ("work", "pB"): 0.2})
    ctrl = RecompositionController(hub, fb, {"work": ["pA", "pB"]}, every_n=1)
    spec = chain_spec("pA")
    for _ in range(5):
        assert ctrl.tick(spec) is None  # pA stays optimal: never a swap
    assert ctrl.stats["recomputes"] == 5 and ctrl.stats["swaps"] == 0


# ---------------------------------------------------------------------------
# controller hysteresis: cooldown + minimum improvement
# ---------------------------------------------------------------------------
def _flapping_hub_controller(**kwargs):
    """pA's observed compute flaps between awful and great every other
    tick — the pathological alternating drift."""
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(compute={("work", "pA"): 0.1, ("work", "pB"): 0.2})
    ctrl = RecompositionController(
        hub, fb, {"work": ["pA", "pB"]}, every_n=1, min_samples=1, **kwargs
    )
    return hub, ctrl


def _run_flapping(hub, ctrl, ticks=40):
    spec, swaps = chain_spec("pA"), 0
    for k in range(ticks):
        hub.record_compute("work", "pA", 3.0 if (k // 2) % 2 == 0 else 0.05)
        placement = ctrl.tick(spec)
        if placement is not None:
            swaps += 1
            spec = spec.apply_placement(placement)
    return swaps


def test_controller_without_hysteresis_thrashes_under_alternating_drift():
    hub, ctrl = _flapping_hub_controller()
    assert _run_flapping(hub, ctrl) >= 10  # the failure mode being fixed


def test_controller_hysteresis_damps_oscillation():
    """Regression: cooldown + minimum improvement must stop the route
    table thrashing under alternating drift."""
    hub, ctrl = _flapping_hub_controller(cooldown_requests=16, min_improvement=0.3)
    swaps = _run_flapping(hub, ctrl)
    assert swaps <= 3, swaps
    assert ctrl.stats["cooldown_skips"] > 0


def test_controller_cooldown_suppresses_recompute_window():
    hub, ctrl = _flapping_hub_controller(cooldown_requests=8)
    hub.record_compute("work", "pA", 3.0)
    assert ctrl.tick(chain_spec("pA")) is not None  # swap -> cooldown opens
    recomputes = ctrl.stats["recomputes"]
    for _ in range(7):  # inside the window: no recompute at all
        assert ctrl.tick(chain_spec("pB")) is None
    assert ctrl.stats["recomputes"] == recomputes
    assert ctrl.stats["cooldown_skips"] == 7


def test_controller_min_improvement_vetoes_marginal_win():
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(compute={("work", "pA"): 0.22, ("work", "pB"): 0.2})
    ctrl = RecompositionController(
        hub,
        fb,
        {"work": ["pA", "pB"]},
        every_n=1,
        min_samples=1,
        min_improvement=0.5,
    )
    # pB is better, but nowhere near 50% better end to end
    assert ctrl.tick(chain_spec("pA")) is None
    assert ctrl.stats["improvement_vetoes"] == 1
    loose = RecompositionController(
        hub, fb, {"work": ["pA", "pB"]}, every_n=1, min_samples=1
    )
    assert loose.tick(chain_spec("pA"))["work"] == "pB"


# ---------------------------------------------------------------------------
# batched candidate-placement scorer
# ---------------------------------------------------------------------------
def scorer_fixture():
    fb = fallback_costs(
        compute={("work", "pA"): 1.0, ("work", "pB"): 0.3}, transfer_cross=0.05
    )
    spec = chain_spec("pA")
    nodes = {s.name: s for s in spec.steps}
    placements = [
        {"ingest": "edge", "work": "pA", "deliver": "edge"},
        {"ingest": "edge", "work": "pB", "deliver": "edge"},
    ]
    return fb, spec, nodes, placements


def test_scorer_distributions_shape_and_ranking():
    fb, spec, nodes, placements = scorer_fixture()
    scorer = PlacementScorer(n_requests=128, quantile=0.95)
    dists = scorer.distributions(nodes, list(spec.edges), placements, fb)
    assert dists.shape == (2, 128)
    q_a, q_b = scorer.quantiles(nodes, list(spec.edges), placements, fb)
    assert q_b < q_a  # pB's distribution dominates
    stats = scorer.score(nodes, list(spec.edges), placements[0], fb)
    assert stats["median_s"] <= stats["p95_s"] <= stats["p99_s"]
    assert stats["quantile_s"] == pytest.approx(stats["p95_s"])


def test_scorer_is_deterministic_common_random_numbers():
    fb, spec, nodes, placements = scorer_fixture()
    scorer = PlacementScorer(n_requests=64, seed=9)
    a = scorer.distributions(nodes, list(spec.edges), placements, fb)
    b = scorer.distributions(nodes, list(spec.edges), placements, fb)
    assert np.array_equal(a, b)


def test_controller_with_scorer_swaps_on_distribution_win():
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(
        compute={("work", "pA"): 0.1, ("work", "pB"): 0.2}, transfer_cross=0.05
    )
    ctrl = RecompositionController(
        hub,
        fb,
        {"work": ["pA", "pB"]},
        every_n=1,
        min_samples=1,
        scorer=PlacementScorer(n_requests=128),
    )
    for _ in range(2):
        hub.record_compute("work", "pA", 4.0)  # pA degrades hard
    placement = ctrl.tick(chain_spec("pA"))
    assert placement is not None and placement["work"] == "pB"


def test_controller_with_scorer_vetoes_distribution_tie():
    """The DP's point estimate prefers pB by a hair, but the simulated
    distributions are too close at the quantile: no swap."""
    hub = TelemetryHub(alpha=1.0)
    fb = fallback_costs(
        compute={("work", "pA"): 0.21, ("work", "pB"): 0.2}, transfer_cross=0.05
    )
    ctrl = RecompositionController(
        hub,
        fb,
        {"work": ["pA", "pB"]},
        every_n=1,
        min_samples=1,
        scorer=PlacementScorer(n_requests=128, sigma=0.4),
        min_improvement=0.2,
    )
    assert ctrl.tick(chain_spec("pA")) is None
    assert ctrl.stats["improvement_vetoes"] == 1


# ---------------------------------------------------------------------------
# AdaptiveDeployment on the real engine
# ---------------------------------------------------------------------------
def make_registry():
    reg = PlatformRegistry()
    reg.register(Platform("edge", "edge", kind="edge", native_prefetch=True))
    reg.register(Platform("pA", "region-a", kind="cloud"))
    reg.register(Platform("pB", "region-b", kind="cloud"))
    return reg


def platform_of_current_thread():
    name = threading.current_thread().name
    return name.split("plat-")[1].rsplit("_", 1)[0] if "plat-" in name else name


def deploy_chain(engine, ran_on, work=None):
    def passthrough(p, d):
        return p

    def default_work(p, d):
        ran_on.append(platform_of_current_thread())
        return p * 2

    engine.deploy("ingest", passthrough, ["edge"])
    engine.deploy("work", work or default_work, ["pA", "pB"])
    engine.deploy("deliver", passthrough, ["edge"])
    return engine


def test_adaptive_deployment_rejects_undeployed_candidates():
    with deploy_chain(DagDeployment(make_registry()), []) as engine:
        with pytest.raises(ValueError, match="'pC'"):
            AdaptiveDeployment(
                engine, chain_spec(), {"work": ["pA", "pC"]}, fallback_costs()
            )


def test_adaptive_deployment_swaps_and_serves():
    """Degrade pA mid-stream: the controller swaps the route to pB and
    every request (before, during, after) returns the right answer."""
    ran_on = []
    slow = {"scale": 1.0}

    def work(p, d):
        plat = platform_of_current_thread()
        ran_on.append(plat)
        # only pA degrades; pB stays at its nominal 0.03 s
        time.sleep(0.02 * slow["scale"] if plat == "pA" else 0.03)
        return p * 2

    # modeled cross-link cost must be payload-scale (0.05 s) or pB's two
    # unobserved links would mask any compute drift on pA
    fb = fallback_costs(
        compute={("work", "pA"): 0.02, ("work", "pB"): 0.03}, transfer_cross=0.05
    )
    with deploy_chain(DagDeployment(make_registry()), ran_on, work) as engine:
        adapt = AdaptiveDeployment(
            engine,
            chain_spec(),
            {"work": ["pA", "pB"]},
            fb,
            every_n=4,
            drift_ratio=1.5,
            min_samples=2,
        )
        outs = [adapt.run(k).outputs for k in range(6)]
        slow["scale"] = 20.0
        outs += [adapt.run(k).outputs for k in range(6, 16)]
        assert outs == [k * 2 for k in range(16)]  # nothing dropped, ever
        assert adapt.routes.version >= 1
        assert adapt.swaps[0]["moved"]["work"] == ("pA", "pB")
        assert ran_on[0] == "pA" and ran_on[-1] == "pB"
        report = adapt.report()
        assert report["adapt"]["route_version"] == adapt.routes.version
        assert report["adapt"]["controller"]["swaps"] >= 1


def test_in_flight_request_survives_cutover():
    """A request that entered on route v0 finishes on v0's platform while
    the table swaps to v1 underneath it — no drop, no reroute mid-flight."""
    ran_on = []
    started, release = threading.Event(), threading.Event()

    def work(p, d):
        ran_on.append(platform_of_current_thread())
        started.set()
        assert release.wait(5.0)
        return p * 2

    with deploy_chain(DagDeployment(make_registry()), ran_on, work) as engine:
        adapt = AdaptiveDeployment(
            engine, chain_spec(), {"work": ["pA", "pB"]}, fallback_costs()
        )
        results = []
        t = threading.Thread(target=lambda: results.append(adapt.run(21)))
        t.start()
        assert started.wait(5.0)
        version = adapt._cutover({"work": "pB"})  # hot-swap mid-flight
        assert version == 1
        release.set()
        t.join(5.0)
        assert results and results[0].outputs == 42
        assert ran_on == ["pA"]  # the in-flight request kept its route
        release.set()
        assert adapt.run(5).outputs == 10
        assert ran_on[-1] == "pB"  # new arrivals take the new route


def test_cutover_prewarms_moved_step():
    """The moved step's compile cache is warmed on the NEW platform before
    the swap is published (the cutover lands warm)."""
    abstract = (jnp.zeros((4,), jnp.float32),)
    with DagDeployment(make_registry()) as engine:
        engine.deploy("ingest", lambda p, d: p, ["edge"])
        engine.deploy(
            "work",
            lambda p, d: p * 2,
            ["pA", "pB"],
            abstract_args=abstract,
            compile_fn=lambda x: x * 2,
        )
        engine.deploy("deliver", lambda p, d: p, ["edge"])
        adapt = AdaptiveDeployment(
            engine, chain_spec(), {"work": ["pA", "pB"]}, fallback_costs()
        )
        adapt.run(1)
        assert not engine.cache.is_warm("work", "pB", abstract)
        adapt._cutover({"work": "pB"})
        deadline = time.time() + 5.0
        while not engine.cache.is_warm("work", "pB", abstract):
            assert time.time() < deadline, "prewarm never landed"
            time.sleep(0.01)
        assert adapt.routes.spec.node("work").platform == "pB"


def test_cutover_validates_against_deployment_platform_set():
    with deploy_chain(DagDeployment(make_registry()), []) as engine:
        adapt = AdaptiveDeployment(
            engine, chain_spec(), {"work": ["pA", "pB"]}, fallback_costs()
        )
        with pytest.raises(ValueError, match="unknown platform"):
            adapt._cutover({"work": "nowhere"})


# ---------------------------------------------------------------------------
# unified report() + engine telemetry hooks
# ---------------------------------------------------------------------------
def test_deployment_report_merges_all_stats_surfaces():
    ran_on = []
    with deploy_chain(DagDeployment(make_registry()), ran_on) as engine:
        hub = attach(engine)
        engine.store.put("k", np.ones(8), region="region-a")
        spec = DagSpec(
            (
                DagStep("ingest", "edge"),
                DagStep("work", "pA", data_deps=(DataRef("k", "region-a"),)),
                DagStep("deliver", "edge"),
            ),
            (("ingest", "work"), ("work", "deliver")),
        )
        for k in range(3):
            engine.run(spec, k)
        report = engine.report()
    assert set(report) == {
        "engine", "compile", "prefetch", "store", "timing", "telemetry"
    }
    assert report["engine"]["pokes"]["work"] >= 1
    assert report["prefetch"]["prefetched"] >= 1
    assert report["store"]["gets"] >= 1 and report["store"]["misses"] == 0
    assert "steps" in report["timing"] and "edges" in report["timing"]
    # the hub saw the engine's hooks: compute per (step, platform), fetch
    # per (key, region), transfers per region pair
    tel = report["telemetry"]
    assert "work@pA" in tel["compute_s"]
    assert "k@region-a" in tel["fetch_s"]
    assert any("region-a" in k for k in tel["transfer_s"])


def test_store_counts_hits_and_misses():
    from repro.core import ObjectStore

    store = ObjectStore()
    store.put("k", b"v", region="eu")
    store.get("k", "eu")
    with pytest.raises(KeyError):
        store.get("gone", "eu")
    snap = store.stats_snapshot()
    assert snap["gets"] == 1 and snap["misses"] == 1
