"""The jax simulation backend: bit-equality with the numpy backend wherever
randomness cancels (sigma-0, with and without drift, chains and DAGs, cold
regimes), statistical equivalence where it doesn't (its draws come from
jax.random, not the numpy Generator), the CRN property across batched
placements, its own frozen draw-contract reference, and the guard rails."""

import math
from dataclasses import replace

import numpy as np
import pytest

from repro.core import simulator as S
from repro.dag import document_dag_fig4

ATOL = 1e-9  # sigma-0 gap budget: reassociated float ops, not different math


def _zero_sigma(steps):
    return [
        replace(s, compute=S.Dist(s.compute.median, 0.0),
                fetch=S.Dist(s.fetch.median, 0.0))
        for s in steps
    ]


def _zero_platforms(keep_warm=None):
    return [
        replace(p, cold_start=S.Dist(p.cold_start.median, 0.0),
                **({} if keep_warm is None else {"keep_warm_s": keep_warm}))
        for p in S.paper_platforms()
    ]


def _both(sim, spec):
    a = sim.simulate(spec, backend="numpy")
    b = sim.simulate(spec, backend="jax")
    return a, b


# ---------------------------------------------------------------------------
# sigma-0: identical arithmetic, so the backends must agree to float noise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefetch", [True, False])
@pytest.mark.parametrize(
    "make_steps",
    [
        S.document_workflow_fig4,
        lambda: S.shipping_workflow_fig6("lambda-eu-central-1"),
        S.native_prefetch_workflow_fig8,
    ],
)
def test_sigma0_chain_matches_numpy_exactly(make_steps, prefetch):
    sim = S.WorkflowSimulator(_zero_platforms(), seed=0)
    spec = S.ExperimentSpec(_zero_sigma(make_steps()), n_requests=50,
                            prefetch=prefetch, seeds=(0,))
    a, b = _both(sim, spec)
    np.testing.assert_allclose(b, a, atol=ATOL, rtol=0)


@pytest.mark.parametrize("prefetch", [True, False])
def test_sigma0_dag_matches_numpy_exactly(prefetch):
    raw, edges = document_dag_fig4()
    sim = S.WorkflowSimulator(_zero_platforms(), seed=0)
    spec = S.ExperimentSpec(_zero_sigma(raw), edges=edges, n_requests=40,
                            prefetch=prefetch, seeds=(0,))
    a, b = _both(sim, spec)
    np.testing.assert_allclose(b, a, atol=ATOL, rtol=0)


def test_sigma0_mixed_prefetch_flags_dag():
    """A node with prefetch=False inside a prefetch-on experiment: poked
    reachability must flow around it identically on both backends."""
    steps = [
        S.SimStep("a", "tinyfaas-edge", compute=S.Dist(0.2, 0.0)),
        S.SimStep("b", "gcf", compute=S.Dist(0.3, 0.0), fetch=S.Dist(0.4, 0.0)),
        S.SimStep(
            "c",
            "lambda-us-east-1",
            compute=S.Dist(0.5, 0.0),
            fetch=S.Dist(0.6, 0.0),
            prefetch=False,
        ),
        S.SimStep(
            "d",
            "lambda-eu-central-1",
            compute=S.Dist(0.25, 0.0),
            fetch=S.Dist(0.9, 0.0),
        ),
    ]
    edges = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]
    sim = S.WorkflowSimulator(_zero_platforms(), seed=0)
    spec = S.ExperimentSpec(steps, edges=edges, n_requests=60, seeds=(0,))
    a, b = _both(sim, spec)
    np.testing.assert_allclose(b, a, atol=ATOL, rtol=0)


def test_sigma0_cold_regime_matches_numpy_exactly():
    """Arrival gaps straddle keep_warm: the sequential cold recurrence is
    live, exercising the parallel-scan mask end to end."""
    sim = S.WorkflowSimulator(_zero_platforms(keep_warm=2.5), seed=0)
    spec = S.ExperimentSpec(
        _zero_sigma(S.document_workflow_fig4()),
        n_requests=80,
        interarrival_s=3.0,
        seeds=(0,),
    )
    a, b = _both(sim, spec)
    np.testing.assert_allclose(b, a, atol=ATOL, rtol=0)


def test_sigma0_drift_matches_numpy_exactly():
    drift = S.DriftSchedule(
        [
            S.DriftEvent(
                at_request=10,
                platform="gcf",
                compute_scale=3.0,
                transfer_scale=2.0,
                fetch_scale=1.5,
            ),
            S.DriftEvent(
                at_request=25, platform="lambda-us-east-1", transfer_scale=4.0
            ),
        ]
    )
    sim = S.WorkflowSimulator(_zero_platforms(), seed=0, drift=drift)
    spec = S.ExperimentSpec(_zero_sigma(S.document_workflow_fig4()),
                            n_requests=40, seeds=(0,))
    a, b = _both(sim, spec)
    np.testing.assert_allclose(b, a, atol=ATOL, rtol=0)


# ---------------------------------------------------------------------------
# frozen reference: the jax draw contract
# ---------------------------------------------------------------------------
# Per seed: PRNGKey(seed) split into (cold, fetch, compute) streams, one
# (n_nodes, n_requests) standard-normal block each, node-major in topo
# order. Regenerating these numbers requires an intentional, documented
# change to that contract (or to the recurrence itself).
FROZEN_JAX_FIG4 = [
    3.738634870052,
    2.279264033437,
    2.389339194298,
    2.571095607281,
]


def test_frozen_reference_jax_backend():
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=3)
    spec = S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4, seeds=(3,))
    out = sim.simulate(spec, backend="jax")
    assert out[0].tolist() == pytest.approx(FROZEN_JAX_FIG4, abs=1e-9)


def test_frozen_reference_unchanged_by_single_chunk_stream():
    """chunks=1 keeps the compiled program and draws identical: the jax
    backend reproduces the frozen reference bit-for-bit with a degenerate
    StreamConfig attached (via the spec override)."""
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=3)
    spec = S.ExperimentSpec(
        S.document_workflow_fig4(),
        n_requests=4,
        seeds=(3,),
        stream=S.StreamConfig(chunks=1),
    )
    out = sim.simulate(spec, backend="jax")
    base = S.WorkflowSimulator(S.paper_platforms(), seed=3).simulate(
        S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4, seeds=(3,)),
        backend="jax",
    )
    assert np.array_equal(np.asarray(out), np.asarray(base))
    assert out[0].tolist() == pytest.approx(FROZEN_JAX_FIG4, abs=1e-9)


# ---------------------------------------------------------------------------
# statistical equivalence with spread on
# ---------------------------------------------------------------------------
def test_median_and_p99_agree_within_1pct():
    """Different rngs, same distributions: pooled (3 pinned seeds x 4000
    requests) medians and p99s within 1% — deterministic, not flaky."""
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    spec = S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4000,
                            seeds=(0, 1, 2))
    a, b = _both(sim, spec)
    assert np.median(b) == pytest.approx(np.median(a), rel=0.01)
    assert np.percentile(b, 99) == pytest.approx(np.percentile(a, 99), rel=0.01)


# ---------------------------------------------------------------------------
# the placement axis: CRN across a batched candidate set
# ---------------------------------------------------------------------------
def test_batched_placements_share_draws_crn():
    """Placements in one batched sweep share the per-seed draws (CRN):
    the same placement listed twice yields bit-identical rows, so row
    differences are placement effects, not sampling noise. Against a
    SEPARATE solo sweep the rows agree to float32 factor noise — the
    sigma table is pooled across the batch, so the two calls compile
    different programs and XLA's f32 exp fusion may differ at ~1e-7."""
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    fig4 = S.document_workflow_fig4()
    placements = [fig4, _zero_sigma(fig4), fig4]
    spec = S.ExperimentSpec(fig4, n_requests=100, seeds=(5, 6))
    both = sim.simulate_placements(spec, placements)
    assert both.shape == (2, 3, 100)
    assert np.array_equal(both[:, 0, :], both[:, 2, :])  # CRN, bit-exact
    assert not np.array_equal(both[:, 0, :], both[:, 1, :])
    for j, steps in enumerate(placements[:2]):
        solo = sim.simulate_placements(replace(spec, steps=tuple(steps)), [steps])
        np.testing.assert_allclose(both[:, j, :], solo[:, 0, :], rtol=1e-6)


def test_batched_sweep_is_deterministic():
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    fig4 = S.document_workflow_fig4()
    spec = S.ExperimentSpec(fig4, n_requests=64, seeds=(1, 2))
    a = sim.simulate_placements(spec, [fig4, _zero_sigma(fig4)])
    b = sim.simulate_placements(spec, [fig4, _zero_sigma(fig4)])
    assert np.array_equal(a, b)


def test_simulate_placements_default_seed_and_f32():
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=11)
    steps = S.document_workflow_fig4()
    spec = S.ExperimentSpec(steps, n_requests=64)
    out = sim.simulate_placements(spec, [steps])
    assert out.shape == (1, 1, 64)  # seeds=None -> the construction seed
    named = sim.simulate_placements(replace(spec, seeds=(11,)), [steps])
    assert np.array_equal(out, named)
    lo = sim.simulate_placements(spec, [steps], dtype=np.float32)
    assert np.median(lo) == pytest.approx(np.median(out), rel=1e-4)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
def test_jax_rejects_timing_controller():
    from repro.core.timing import PokeTimingController

    sim = S.WorkflowSimulator(
        S.paper_platforms(), seed=0, timing=PokeTimingController()
    )
    with pytest.raises(ValueError, match="timing"):
        sim.simulate(
            S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4), backend="jax"
        )


def test_jax_rejects_telemetry():
    from repro.adapt import TelemetryHub

    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0, telemetry=TelemetryHub())
    with pytest.raises(ValueError, match="telemetry"):
        sim.simulate(
            S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4), backend="jax"
        )


def test_jax_rejects_duplicate_name_platform_nodes():
    steps = [
        S.SimStep("f", "gcf", compute=S.Dist(0.1)),
        S.SimStep("f", "gcf", compute=S.Dist(0.1)),
    ]
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    with pytest.raises(ValueError, match="unique"):
        sim.simulate(S.ExperimentSpec(steps, n_requests=4), backend="jax")


def test_jax_zero_requests_and_infinite_keep_warm():
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    out = sim.simulate(
        S.ExperimentSpec(S.document_workflow_fig4(), n_requests=0), backend="jax"
    )
    assert out.shape == (0,)
    plats = [
        S.SimPlatform(
            "p",
            "r",
            native_prefetch=True,
            cold_start=S.Dist(0.5, 0.0),
            keep_warm_s=math.inf,
        )
    ]
    steps = [S.SimStep("a", "p", compute=S.Dist(0.2, 0.0))]
    sim = S.WorkflowSimulator(plats, seed=0)
    spec = S.ExperimentSpec(steps, n_requests=8, seeds=(0,))
    a, b = _both(sim, spec)
    np.testing.assert_allclose(b, a, atol=ATOL, rtol=0)
