"""use_sharding / current_sharding context semantics: nesting, thread
isolation (each simulated platform executor carries its own context), and
shard() as an exact no-op outside any mesh context (the single-device path
the simulator and edge platforms rely on)."""
import threading

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH_A = FakeMesh({"data": 4, "model": 2})
MESH_B = FakeMesh({"data": 2, "model": 4})


def test_default_is_empty():
    assert shd.current_sharding() == (None, None)


def test_nesting_restores_outer():
    ra, rb = shd.train_rules(), shd.decode_rules()
    with shd.use_sharding(MESH_A, ra):
        assert shd.current_sharding() == (MESH_A, ra)
        with shd.use_sharding(MESH_B, rb):
            assert shd.current_sharding() == (MESH_B, rb)
        assert shd.current_sharding() == (MESH_A, ra)
    assert shd.current_sharding() == (None, None)


def test_context_instance_is_reusable():
    ctx = shd.use_sharding(MESH_A, shd.train_rules())
    for _ in range(2):
        with ctx:
            assert shd.current_sharding()[0] is MESH_A
        assert shd.current_sharding() == (None, None)


def test_exception_unwinds_context():
    try:
        with shd.use_sharding(MESH_A, shd.train_rules()):
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert shd.current_sharding() == (None, None)


def test_thread_isolation():
    """A context bound on one thread must be invisible to another — the
    platform registry runs every platform on its own executor threads."""
    seen = {}
    ready = threading.Event()
    release = threading.Event()

    def worker():
        seen["before"] = shd.current_sharding()
        with shd.use_sharding(MESH_B, shd.decode_rules()):
            seen["inside"] = shd.current_sharding()[0]
            ready.set()
            release.wait(5)
        seen["after"] = shd.current_sharding()

    with shd.use_sharding(MESH_A, shd.train_rules()):
        t = threading.Thread(target=worker)
        t.start()
        assert ready.wait(5)
        # main thread still sees its own context while the worker holds B
        assert shd.current_sharding()[0] is MESH_A
        release.set()
        t.join(5)
    assert seen["before"] == (None, None)   # nothing leaked INTO the thread
    assert seen["inside"] is MESH_B
    assert seen["after"] == (None, None)


def test_shard_noop_outside_context():
    x = jnp.ones((4, 8))
    assert shd.shard(x, "batch", "seq") is x        # identity, not a copy
    assert shd.shard(x, "batch", None) is x


def test_shard_noop_with_partial_context():
    # a context with no mesh (edge platform wrapper) is also a no-op
    x = jnp.ones((2, 2))
    with shd.use_sharding(None, shd.replicated_rules()):
        assert shd.shard(x, "batch", None) is x


def test_platform_rules_heterogeneous():
    """Edge platforms replicate everything; cloud platforms run the mesh
    rules — the heterogeneous federation config (ISSUE tentpole)."""
    edge = shd.rules_for_platform("edge")
    cloud = shd.rules_for_platform("cloud", "train")
    assert edge.lookup("batch") is None
    assert cloud.lookup("batch") == "data"
    assert shd.pspec_for((8, 16), ("batch", "seq"), edge, MESH_A) == P(None,
                                                                       None)
    assert shd.pspec_for((8, 16), ("batch", "seq"), cloud, MESH_A) == \
        P("data", None)


def test_rules_replace_lever():
    rules = shd.train_rules().replace(embed=None)
    assert rules.lookup("embed") is None
    assert rules.lookup("ff") == "model"
