"""repro.obs: histograms, tracer structure, critical-path extraction,
Perfetto export, and the simulator's trace emission on all three backends
— including the draw-neutrality guarantee (tracing must never consume or
reorder a single rng draw)."""

import json

import numpy as np
import pytest

from repro.core import simulator as sm
from repro.obs import (
    BUCKETS,
    LogHistogram,
    MetricsRegistry,
    Tracer,
    extract_critical_path,
    to_chrome_trace,
)


# ---------------------------------------------------------------------------
# LogHistogram / MetricsRegistry
# ---------------------------------------------------------------------------
def test_histogram_quantiles_within_bucket_error():
    h = LogHistogram()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-2.0, sigma=0.8, size=4000)
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        got = h.quantile(q)
        # log-bucketed: relative error bounded by one bucket width (15%)
        assert abs(got - exact) / exact < 0.16, (q, got, exact)
    snap = h.snapshot()
    assert snap["count"] == 4000
    assert snap["sum_s"] == pytest.approx(float(xs.sum()), rel=1e-9)
    assert snap["p99_s"] <= snap["max_s"] == pytest.approx(float(xs.max()))


def test_histogram_empty_and_tiny_values():
    h = LogHistogram()
    assert h.quantile(0.5) == 0.0
    h.observe(0.0)  # underflow slot, not a crash
    h.observe(1e-9)
    assert h.snapshot()["count"] == 2


def test_registry_caps_series_and_reports_drops():
    reg = MetricsRegistry(max_series=4)
    for i in range(8):
        reg.observe(f"s/{i}", 0.1)
    snap = reg.snapshot()
    assert snap["__dropped_series__"] == 4
    assert len([k for k in snap if not k.startswith("__")]) == 4
    p50, p95, p99 = reg.quantiles("s/0")
    assert p50 > 0 and p50 <= p95 <= p99


# ---------------------------------------------------------------------------
# Tracer structure
# ---------------------------------------------------------------------------
def test_tracer_span_tree_and_events():
    tr = Tracer(metrics=MetricsRegistry())
    t = tr.begin(name="req", t0=0.0)
    node = t.span("node:a", kind="node", t_start=0.0, attrs={"node": "a"})
    assert tr.current_span() is None
    tr.event("ignored", {})  # unbound: silent no-op
    with tr.bind(node):
        assert tr.current_span() is node
        tr.event("prefetch.done", {"key": "k"})
    assert tr.current_span() is None
    node.end(0.5)
    tr.finish(t, t_end=0.5)
    assert tr.last() is t
    assert t.root.trace_id == node.trace_id
    assert node.parent_id == t.root.span_id
    assert [name for _t, name, _a in node.events] == ["prefetch.done"]
    # finish fed the span durations into the metrics registry
    assert tr.metrics.quantiles("node_s/a")[0] > 0
    tr.record_event("recompose.decision", {"outcome": "swap"})
    assert tr.events[-1][1] == "recompose.decision"


def test_tracer_ring_is_bounded():
    tr = Tracer(max_traces=4)
    for k in range(10):
        tr.finish(tr.begin(name=f"r{k}", t0=0.0), t_end=1.0)
    assert len(tr.traces()) == 4
    assert tr.last().root.name == "r9"


# ---------------------------------------------------------------------------
# critical path: hand-built exact case
# ---------------------------------------------------------------------------
def _node(trace, name, **attrs):
    base = {
        "node": name,
        "platform": "p",
        "preds": tuple(attrs.get("payload_t") or ()),
        "poke_t": None,
        "prepare_t0": None,
        "prepare_t1": None,
        "cold_s": 0.0,
        "fetch_s": 0.0,
        "compute_s": 0.0,
        "compute_t0": None,
        "payload_t": {},
        "transfer_s": {},
    }
    base.update(attrs)
    s = trace.span(
        f"node:{name}", kind="node", t_start=base.get("t_start", 0.0), attrs=base
    )
    s.end(base["t_end"])
    return s


def test_critical_path_exact_two_node_chain():
    tr = Tracer()
    t = tr.begin(name="req", t0=0.0)
    _node(
        t, "a", poke_t=0.0, prepare_t0=0.0, prepare_t1=0.3, cold_s=0.1,
        fetch_s=0.2, compute_t0=0.3, compute_s=0.2, t_start=0.0, t_end=0.5,
    )
    _node(
        t, "b", poke_t=0.0, prepare_t0=0.0, prepare_t1=0.25, cold_s=0.05,
        fetch_s=0.2, compute_t0=0.7, compute_s=0.3,
        payload_t={"a": 0.7}, transfer_s={"a": 0.2}, t_start=0.0, t_end=1.0,
    )
    tr.finish(t, t_end=1.0)
    cp = extract_critical_path(t)
    assert cp.nodes == ["a", "b"]
    att = cp.attribution
    assert att["compute"] == pytest.approx(0.5)
    assert att["transfer"] == pytest.approx(0.2)
    assert att["fetch"] == pytest.approx(0.2)
    assert att["cold"] == pytest.approx(0.1)
    assert att["poke_slack"] == pytest.approx(0.0, abs=1e-12)
    assert sum(att.values()) == pytest.approx(cp.total_s) == pytest.approx(1.0)
    # segments tile [t0, sink_end] without gaps or overlaps
    segs = sorted(cp.segments, key=lambda s: s.t0)
    for s0, s1 in zip(segs, segs[1:]):
        assert s1.t0 == pytest.approx(s0.t1, abs=1e-12)


def test_critical_path_prepare_bound_terminates_in_poke_slack():
    """A node whose prepare window gates the start and began at its poke
    time attributes the pre-poke idle to poke_slack and stops walking."""
    tr = Tracer()
    t = tr.begin(name="req", t0=0.0)
    _node(
        t, "x", poke_t=0.2, prepare_t0=0.2, prepare_t1=0.8, cold_s=0.4,
        fetch_s=0.2, compute_t0=0.8, compute_s=0.2, t_start=0.2, t_end=1.0,
    )
    tr.finish(t, t_end=1.0)
    att = extract_critical_path(t).attribution
    assert att["compute"] == pytest.approx(0.2)
    assert att["cold"] == pytest.approx(0.4)
    assert att["fetch"] == pytest.approx(0.2)
    assert att["poke_slack"] == pytest.approx(0.2)  # t0 -> poke_t idle


# ---------------------------------------------------------------------------
# simulator trace emission, all three backends
# ---------------------------------------------------------------------------
def _spec(n=6, seeds=None, tracer=None, edges="dag"):
    steps = sm.document_workflow_fig4()
    e = (
        (("check", "virus"), ("check", "ocr"), ("virus", "e_mail"), ("ocr", "e_mail"))
        if edges == "dag"
        else None
    )
    return sm.ExperimentSpec(
        steps, edges=e, n_requests=n, seeds=seeds, tracer=tracer
    )


def _assert_trace_consistent(trace, rel=1e-6):
    cp = extract_critical_path(trace)
    assert cp.nodes, "empty critical path"
    assert sum(cp.attribution.values()) == pytest.approx(cp.total_s, rel=1e-9)
    assert cp.total_s == pytest.approx(trace.total_s, rel=rel)


def test_scalar_traces_sum_to_total():
    tracer = Tracer(sample=4)
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=3)
    totals = simulator.simulate(_spec(n=10, tracer=tracer), backend="scalar")
    traces = tracer.traces()
    assert 1 <= len(traces) <= 4
    for trace in traces:
        assert trace.root.attrs["backend"] == "scalar"
        _assert_trace_consistent(trace)
    ks = [t.root.attrs["request_k"] for t in traces]
    assert any(
        trace.total_s == pytest.approx(totals[k], rel=1e-12)
        for k, trace in zip(ks, traces)
    )


def test_numpy_traces_sum_to_total():
    tracer = Tracer(sample=4)
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=3)
    totals = simulator.simulate(_spec(n=12, tracer=tracer), backend="numpy")
    traces = tracer.traces()
    assert 1 <= len(traces) <= 4
    for trace in traces:
        assert trace.root.attrs["backend"] == "numpy"
        k = trace.root.attrs["request_k"]
        assert trace.total_s == pytest.approx(totals[k], rel=1e-9)
        _assert_trace_consistent(trace)


def test_jax_traces_sum_to_total():
    tracer = Tracer(sample=3)
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=3)
    totals = simulator.simulate(
        _spec(n=10, seeds=(0,), tracer=tracer), backend="jax"
    )
    traces = tracer.traces()
    assert 1 <= len(traces) <= 3
    for trace in traces:
        assert trace.root.attrs["backend"] == "jax"
        k = trace.root.attrs["request_k"]
        assert trace.total_s == pytest.approx(totals[0, k], rel=1e-6)
        _assert_trace_consistent(trace, rel=1e-5)


@pytest.mark.parametrize("backend", ["scalar", "numpy", "jax"])
def test_tracing_is_draw_neutral(backend):
    """The load-bearing guarantee: attaching a tracer must not consume,
    reorder, or perturb a single rng draw — totals are bit-for-bit
    identical with tracing on and off."""
    seeds = (0, 1) if backend == "jax" else None
    off = sm.WorkflowSimulator(sm.paper_platforms(), seed=7).simulate(
        _spec(n=16, seeds=seeds), backend=backend
    )
    sim = sm.WorkflowSimulator(sm.paper_platforms(), seed=7)
    on = sim.simulate(_spec(n=16, seeds=seeds, tracer=Tracer()), backend=backend)
    assert off.dtype == on.dtype
    assert np.array_equal(off, on), "tracing perturbed the draws"
    assert sim.tracer is None  # spec override restored after simulate


def test_chain_spec_traces_too():
    tracer = Tracer(sample=2)
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=0)
    simulator.simulate(_spec(n=4, tracer=tracer, edges=None), backend="scalar")
    for trace in tracer.traces():
        _assert_trace_consistent(trace)


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------
def test_chrome_trace_is_valid_and_complete():
    tracer = Tracer(sample=2)
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=1)
    simulator.simulate(_spec(n=4, tracer=tracer), backend="scalar")
    tracer.record_event("recompose.decision", {"outcome": "swap"})
    doc = to_chrome_trace(tracer.traces(), tracer=tracer)
    text = json.dumps(doc)  # must be serializable as-is
    doc2 = json.loads(text)
    events = doc2["traceEvents"]
    assert events and doc2["displayTimeUnit"] == "ms"
    assert {e["ph"] for e in events} <= {"X", "i", "M"}
    xs = [e for e in events if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    assert any(e["name"] == "recompose.decision" for e in events)
    # one process per trace, metadata names present
    pids = {e["pid"] for e in xs}
    assert len(pids) == len(tracer.traces())
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in events)


def test_buckets_constant_matches_attribution_keys():
    assert set(BUCKETS) == {
        "cold",
        "fetch",
        "compute",
        "transfer",
        "stream_wait",
        "poke_slack",
    }
