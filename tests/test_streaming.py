"""The streaming data plane: chunked store transfers, the pipelined
closed form on all three simulator backends, first/last-byte placement
costs, the engine's cut-through + P2P payload paths, telemetry link fits,
and the stream_wait critical-path bucket.

The load-bearing invariant everywhere: streaming OFF (or chunks=1) is
bit-for-bit the pre-streaming behavior — same draws, same totals, same
store accounting."""

import itertools
import random
import time

import numpy as np
import pytest

from repro.adapt.costs import observed_costs
from repro.adapt.telemetry import TelemetryHub
from repro.core import simulator as S
from repro.core.shipping import PlacementCosts, dag_cost, place_dag
from repro.core.store import ObjectStore, StreamConfig
from repro.core.platform import NetworkModel, Platform, PlatformRegistry
from repro.core.prefetch import Prefetcher
from repro.core.workflow import DataRef, StepSpec
from repro.dag import DagDeployment, DagSpec, DagStep, document_dag_fig4
from repro.obs import Tracer, extract_critical_path


def _zero_platforms():
    return [
        S.SimPlatform(
            p.name,
            p.region,
            p.native_prefetch,
            p.allows_sync,
            S.Dist(p.cold_start.median, 0.0),
            p.keep_warm_s,
        )
        for p in S.paper_platforms()
    ]


def _zero_sigma(steps):
    return [
        S.SimStep(
            s.name,
            s.platform,
            compute=S.Dist(s.compute.median, 0.0),
            fetch=S.Dist(s.fetch.median, 0.0),
            prefetch=s.prefetch,
        )
        for s in steps
    ]


# ---------------------------------------------------------------------------
# StreamConfig / store streaming primitives
# ---------------------------------------------------------------------------
def test_stream_config_validates_chunks():
    with pytest.raises(ValueError):
        StreamConfig(chunks=0)
    assert StreamConfig(chunks=1).p2p_threshold_bytes == 0.0


def test_chunk_dts_sum_exactly_to_whole_transfer():
    store = ObjectStore(NetworkModel())
    store.network.set_link("eu", "us", 0.3, 8e6)
    size = 2_000_000
    whole = store.network.transfer_s("eu", "us", size)
    for chunks in (1, 2, 4, 7, 16):
        dts = store._chunk_dts("eu", "us", size, chunks)
        assert len(dts) == chunks
        assert sum(dts) == pytest.approx(whole, rel=1e-12)
        # only the first chunk carries the fixed latency term
        if chunks > 1:
            assert dts[0] > dts[1]
            assert all(d == pytest.approx(dts[1]) for d in dts[2:])


def test_put_get_stream_roundtrip_and_accounting():
    store = ObjectStore(NetworkModel())
    store.network.set_link("eu", "us", 0.3, 8e6)
    value = np.arange(1000, dtype=np.float64)
    put_dts = list(store.put_stream("k", value, "us", from_region="eu", chunks=4))
    assert len(put_dts) == 4
    got, get_dts = None, []
    for v, dt in store.get_stream("k", "us", chunks=4):
        get_dts.append(dt)
        if v is not None:
            got = v
    # value arrives with the LAST chunk only
    assert got is value and len(get_dts) == 4
    snap = store.stats_snapshot()
    # accounting identical to whole-object put+get: counted ONCE, not 4x
    assert snap["puts"] == 1 and snap["gets"] == 1
    assert snap["bytes_in"] == value.nbytes and snap["bytes_out"] == value.nbytes
    assert snap["bytes_by_pair"] == {"eu->us": value.nbytes, "us->us": value.nbytes}
    assert snap["modeled_put_s"] == pytest.approx(
        store.network.transfer_s("eu", "us", value.nbytes), rel=1e-12
    )


def test_bytes_by_pair_matches_whole_object_path():
    """The pair ledger counts the same bytes whether an edge streamed or
    not — the satellite no-double-count guarantee."""

    def run(streamed):
        store = ObjectStore(NetworkModel())
        v = np.zeros(500, dtype=np.float64)
        if streamed:
            list(store.put_stream("k", v, "us", from_region="eu", chunks=8))
            for _ in store.get_stream("k", "us", chunks=8):
                pass
        else:
            store.put("k", v, "us", from_region="eu")
            store.get("k", "us")
        return store.stats_snapshot()["bytes_by_pair"]

    assert run(True) == run(False)


def test_get_stream_missing_key_raises_eagerly():
    store = ObjectStore(NetworkModel())
    with pytest.raises(KeyError, match="nope"):
        store.get_stream("nope", "us")  # at call time, not at first next()


# ---------------------------------------------------------------------------
# the pipelined closed form == the explicit per-chunk recurrence
# ---------------------------------------------------------------------------
def test_closed_form_equals_explicit_chunk_loop():
    """end = max(start + c, payload_last + c/C) is exactly the per-chunk
    recurrence t_i = max(t_{i-1}, arr_i) + c/C under evenly spaced chunk
    arrivals — the algebra all three backends rely on."""
    rnd = random.Random(11)
    for _ in range(200):
        C = rnd.randint(1, 12)
        first = rnd.uniform(0.01, 1.0)
        # one chunk means the first byte IS the last byte
        last = first + (rnd.uniform(0.0, 2.0) if C > 1 else 0.0)
        c = rnd.uniform(0.01, 2.0)
        prepare = rnd.uniform(0.0, 2.5)
        end_u = rnd.uniform(0.0, 2.0)
        arr = [
            end_u + first + i * ((last - first) / (C - 1) if C > 1 else 0.0)
            for i in range(C)
        ]
        start = max(prepare, arr[0])
        t = start
        for i in range(C):
            t = max(t, arr[i]) + c / C
        closed = max(start + c, end_u + last + c / C)
        assert t == pytest.approx(closed, rel=1e-12)


# ---------------------------------------------------------------------------
# three backends: off == chunks=1 bit-for-bit; sigma-0 exact agreement
# ---------------------------------------------------------------------------
def _run_backend(backend, stream, seeds=(3, 4), n=20, zero=False):
    platforms = _zero_platforms() if zero else S.paper_platforms()
    steps = S.document_workflow_fig4()
    if zero:
        steps = _zero_sigma(steps)
    sim = S.WorkflowSimulator(platforms, seed=3, stream=stream)
    spec = S.ExperimentSpec(steps, n_requests=n, seeds=seeds)
    return np.asarray(sim.simulate(spec, backend=backend))


@pytest.mark.parametrize("backend", ["scalar", "numpy", "jax"])
def test_chunks1_bit_for_bit_identical_to_off(backend):
    off = _run_backend(backend, None)
    on = _run_backend(backend, StreamConfig(chunks=1))
    assert np.array_equal(off, on)


@pytest.mark.parametrize("chunks", [1, 8])
def test_sigma0_streaming_agrees_across_backends(chunks):
    stream = StreamConfig(chunks=chunks)
    sc = _run_backend("scalar", stream, seeds=(0,), zero=True)
    np_ = _run_backend("numpy", stream, seeds=(0,), zero=True)
    jx = _run_backend("jax", stream, seeds=(0,), zero=True)
    np.testing.assert_allclose(np_, sc.reshape(np_.shape), atol=0, rtol=0)
    np.testing.assert_allclose(jx, np_, atol=1e-5, rtol=0)


def test_streaming_reduces_sigma0_totals_on_all_backends():
    for backend in ("scalar", "numpy", "jax"):
        off = _run_backend(backend, None, seeds=(0,), n=6, zero=True)
        on = _run_backend(backend, StreamConfig(chunks=8), seeds=(0,), n=6, zero=True)
        assert np.all(on <= off + 1e-9), backend
        assert on.mean() < off.mean(), backend


def test_p2p_cuts_below_streaming_for_small_payloads():
    sim_kw = dict(payload_size_bytes=100_000.0, seed=0)
    steps = _zero_sigma(S.document_workflow_fig4())
    spec = S.ExperimentSpec(steps, n_requests=5, seeds=(0,))
    totals = {}
    for name, stream in [
        ("stream", StreamConfig(chunks=8)),
        ("p2p", StreamConfig(chunks=8, p2p_threshold_bytes=200_000.0)),
    ]:
        sim = S.WorkflowSimulator(_zero_platforms(), stream=stream, **sim_kw)
        totals[name] = np.asarray(sim.simulate(spec, backend="scalar"))
    assert totals["p2p"].mean() < totals["stream"].mean()


def test_spec_stream_overrides_simulator_stream():
    sim = S.WorkflowSimulator(_zero_platforms(), seed=0)
    steps = _zero_sigma(S.document_workflow_fig4())
    on = sim.simulate(
        S.ExperimentSpec(steps, n_requests=4, seeds=(0,), stream=StreamConfig(8)),
        backend="scalar",
    )
    assert sim.stream is None  # restored after the run
    base = sim.simulate(
        S.ExperimentSpec(steps, n_requests=4, seeds=(0,)), backend="scalar"
    )
    assert np.asarray(on).mean() < np.asarray(base).mean()


# ---------------------------------------------------------------------------
# placement: first/last-byte Pareto DP still matches brute force
# ---------------------------------------------------------------------------
def _random_fl_case(rnd, topology):
    plats = ["p0", "p1", "p2"]
    if topology == "chain":
        names = [f"s{i}" for i in range(rnd.randint(2, 4))]
        edges = [(names[i], names[i + 1]) for i in range(len(names) - 1)]
    elif topology == "diamond":
        names = ["a", "b", "c", "d"]
        edges = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]
    else:  # non-series-parallel braid: the exhaustive fallback
        names = ["a", "b", "c", "d"]
        edges = [("a", "b"), ("a", "c"), ("b", "c"), ("b", "d"), ("c", "d")]
    nodes = {n: StepSpec(n, "p0") for n in names}
    fetch = {(n, p): rnd.uniform(0, 2) for n in names for p in plats}
    compute = {(n, p): rnd.uniform(0.1, 2) for n in names for p in plats}
    fl = {}
    for a in plats:
        for b in plats:
            if a == b:
                fl[(a, b)] = (0.0, 0.0)
            else:
                f = rnd.uniform(0.05, 1.0)
                fl[(a, b)] = (f, f + rnd.uniform(0.0, 1.5))
    costs = PlacementCosts(
        fetch_s=lambda name, p, deps: fetch[(name, p)],
        compute_s=lambda name, p: compute[(name, p)],
        transfer_s=lambda a, b, size: fl[(a, b)][1],
        payload_size=1.0,
        transfer_fl=lambda a, b, size: fl[(a, b)],
        chunks=8,
    )
    return nodes, edges, {n: plats for n in names}, costs


@pytest.mark.parametrize("topology", ["chain", "diamond", "braid"])
def test_place_dag_with_fl_costs_matches_bruteforce(topology):
    rnd = random.Random(20260809)
    for trial in range(12):
        nodes, edges, cand, costs = _random_fl_case(rnd, topology)
        for prefetch in (True, False):
            placed = place_dag(nodes, edges, cand, costs, prefetch)
            got = dag_cost(nodes, edges, placed, costs, prefetch)
            want = min(
                dag_cost(nodes, edges, dict(zip(nodes, combo)), costs, prefetch)
                for combo in itertools.product(*(cand[n] for n in nodes))
            )
            assert got == pytest.approx(want, rel=1e-9), (topology, trial)


def test_pipelined_edges_price_below_whole_object():
    """dag_cost with a first/last split on a data-heavy chain is strictly
    cheaper than the same chain priced whole-object."""
    nodes = {"a": StepSpec("a", "p0"), "b": StepSpec("b", "p1")}
    edges = [("a", "b")]
    kw = dict(
        fetch_s=lambda n, p, d: 0.0,
        compute_s=lambda n, p: 0.5,
        transfer_s=lambda a, b, s: 0.0 if a == b else 1.0,
        payload_size=1.0,
    )
    whole = dag_cost(nodes, edges, {"a": "p0", "b": "p1"}, PlacementCosts(**kw))
    piped = dag_cost(
        nodes,
        edges,
        {"a": "p0", "b": "p1"},
        PlacementCosts(
            **kw,
            transfer_fl=lambda a, b, s: (0.0, 0.0) if a == b else (0.2, 1.0),
            chunks=8,
        ),
    )
    # whole edge+compute: 1.0 + 0.5; piped: first-byte 0.2 gates compute,
    # the tail is max(0.2 + 0.5, 1.0 + 0.5/8)
    assert piped == pytest.approx(whole - 1.5 + max(0.2 + 0.5, 1.0 + 0.5 / 8), rel=1e-9)
    assert piped < whole


# ---------------------------------------------------------------------------
# telemetry: link fits + edge-bytes EWMA feeding observed costs
# ---------------------------------------------------------------------------
def test_transfer_fit_recovers_latency_and_bandwidth():
    hub = TelemetryHub()
    lat, per_byte = 0.12, 1.0 / 8e6
    for b in (1e5, 4e5, 1e6, 2e6, 3e6):
        hub.record_transfer("eu", "us", b, lat + b * per_byte)
    got_lat, got_pb = hub.transfer_fit("eu", "us")
    assert got_lat == pytest.approx(lat, rel=1e-6)
    assert got_pb == pytest.approx(per_byte, rel=1e-6)


def test_transfer_fit_needs_samples_and_spread():
    hub = TelemetryHub()
    assert hub.transfer_fit("a", "b") is None
    for _ in range(6):  # plenty of samples, zero byte spread
        hub.record_transfer("a", "b", 1000, 0.1)
    assert hub.transfer_fit("a", "b") is None
    assert hub.transfer_fit("a", "b", min_samples=99) is None


def test_edge_bytes_ewma_and_snapshot():
    hub = TelemetryHub()
    assert hub.edge_bytes("u", "v") is None
    hub.record_edge_bytes("u", "v", 1000)
    hub.record_edge_bytes("u", "v", 2000)
    assert 1000 < hub.edge_bytes("u", "v") < 2000
    assert "u->v" in hub.snapshot()["edge_bytes"]


def test_observed_costs_attaches_fl_only_when_chunked():
    hub = TelemetryHub()
    for b in (1e5, 1e6, 2e6):
        for _ in range(2):
            hub.record_transfer("eu", "us", b, 0.1 + b / 8e6)
    fb = PlacementCosts(
        fetch_s=lambda s, p, d: 0.0,
        compute_s=lambda s, p: 0.1,
        transfer_s=lambda a, b, sz: 0.9,
        payload_size=2e6,
    )
    plain = observed_costs(hub, fb)
    assert plain.transfer_fl is None and plain.chunks == 1
    oc = observed_costs(hub, fb, chunks=8)
    assert oc.chunks == 8
    first, last = oc.transfer_fl("eu", "us", 2e6)
    assert first == pytest.approx(0.1 + (2e6 / 8) / 8e6, rel=1e-6)
    assert last == pytest.approx(0.1 + 2e6 / 8e6, rel=1e-6)
    # unobserved pair: falls back to the whole-object estimate, degenerate
    f2, l2 = oc.transfer_fl("xx", "yy", 2e6)
    assert f2 == l2 == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# prefetcher: chunked fetches
# ---------------------------------------------------------------------------
def test_prefetcher_streams_when_configured():
    store = ObjectStore(NetworkModel())
    store.put("blob", np.arange(64.0), "us")
    pf = Prefetcher(store, stream=StreamConfig(chunks=4))
    try:
        out, _, modeled = pf.join(pf.start([DataRef("blob", "us", 512)], "eu"))
        assert np.array_equal(out["blob"], np.arange(64.0))
        stats = pf.stats_snapshot()
        assert stats["streamed"] == 1
        assert 0.0 < stats["first_byte_s"] < modeled
    finally:
        pf.shutdown()


# ---------------------------------------------------------------------------
# engine: cut-through streamed edges + direct P2P payloads
# ---------------------------------------------------------------------------
def _engine(stream=None, payload_region=None, telemetry=None, tracer=None):
    reg = PlatformRegistry()
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us", kind="cloud"))
    dep = DagDeployment(
        reg,
        stream=stream,
        payload_region=payload_region,
        telemetry=telemetry,
        tracer=tracer,
    )
    dep.store.enforce_latency = True
    for a, b in (("eu", "us"), ("eu", "mid"), ("mid", "us")):
        dep.store.network.set_link(a, b, 0.01, 2e8)
    return dep


CHAIN3 = DagSpec(
    (DagStep("a", "edge-eu"), DagStep("b", "cloud-us"), DagStep("c", "cloud-us")),
    (("a", "b"), ("b", "c")),
    "chain3",
)


def _handler(s):
    def h(payload, data):
        time.sleep(s)
        return payload

    return h


def _deploy_chain(dep):
    dep.deploy("a", _handler(0.005), ["edge-eu"])
    dep.deploy("b", _handler(0.03), ["cloud-us"])
    dep.deploy("c", _handler(0.005), ["cloud-us"])
    return dep


def test_engine_streamed_edges_preserve_results():
    pay = np.arange(250_000, dtype=np.float64)  # 2 MB
    with _deploy_chain(_engine(payload_region="mid")) as dep:
        want = dep.run(CHAIN3, pay).outputs
        assert dep.stats["buffered_edges"] == 2 and dep.stats["streamed_edges"] == 0
    with _deploy_chain(
        _engine(stream=StreamConfig(chunks=4), payload_region="mid")
    ) as dep:
        r = dep.run(CHAIN3, pay)
        assert np.array_equal(r.outputs, want)
        assert dep.stats["streamed_edges"] == 2 and dep.stats["buffered_edges"] == 0
        assert "stream_wait_s" in r.timeline["b"]
        assert r.timeline["b"]["stream_wait_s"] >= 0.0
        # payload buffers never leak
        assert not dep.store.keys("__payload__")


def test_engine_p2p_path_skips_store_and_learns_edge_bytes():
    hub = TelemetryHub()
    pay = np.arange(1000, dtype=np.float64)  # 8 KB: under threshold
    stream = StreamConfig(chunks=4, p2p_threshold_bytes=1e6)
    with _deploy_chain(_engine(stream=stream, telemetry=hub)) as dep:
        r = dep.run(CHAIN3, pay)
        assert np.array_equal(r.outputs, pay)
        assert dep.stats["p2p_edges"] == 2
        assert dep.stats["streamed_edges"] == dep.stats["buffered_edges"] == 0
    assert hub.edge_bytes("a", "b") == pytest.approx(pay.nbytes)


def test_engine_stream_off_keeps_legacy_stats_shape():
    with _deploy_chain(_engine()) as dep:
        r = dep.run(CHAIN3, 1)
        assert r.outputs == 1
        assert "stream_wait_s" not in r.timeline["b"]
        assert dep.stats["streamed_edges"] == dep.stats["p2p_edges"] == 0
        snap = dep.report()["engine"]
        assert snap["streamed_edges"] == 0 and snap["p2p_edges"] == 0


# ---------------------------------------------------------------------------
# critical path: the stream_wait bucket tiles exactly
# ---------------------------------------------------------------------------
def _assert_tiles(cp):
    segs = sorted(cp.segments, key=lambda s: s.t0)
    for s0, s1 in zip(segs, segs[1:]):
        assert s1.t0 == pytest.approx(s0.t1, abs=1e-9)
    att = cp.attribution
    assert sum(att.values()) == pytest.approx(cp.total_s, rel=1e-9)
    return att


def test_stream_wait_bucket_tiles_simulator_trace():
    tracer = Tracer()
    sim = S.WorkflowSimulator(
        _zero_platforms(),
        seed=0,
        stream=StreamConfig(chunks=8),
        payload_size_bytes=8e6,  # data-heavy: the pipelined tail binds
    )
    steps, edges = document_dag_fig4()
    spec = S.ExperimentSpec(
        _zero_sigma(steps), edges=edges, n_requests=3, seeds=(0,), tracer=tracer
    )
    sim.simulate(spec, backend="scalar")
    waits = []
    for trace in tracer.traces():
        att = _assert_tiles(extract_critical_path(trace))
        waits.append(att["stream_wait"])
    assert max(waits) > 0.0


def test_stream_wait_bucket_tiles_engine_trace():
    tracer = Tracer()
    pay = np.arange(250_000, dtype=np.float64)
    dep = _deploy_chain(
        _engine(stream=StreamConfig(chunks=4), payload_region="mid", tracer=tracer)
    )
    with dep:
        dep.run(CHAIN3, pay)
    (trace,) = tracer.traces()
    att = _assert_tiles(extract_critical_path(trace))
    assert att["stream_wait"] >= 0.0
    assert att["compute"] > 0.0
