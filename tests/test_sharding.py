"""Sharding-rule resolution properties (hypothesis)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import sharding as shd

# a fake 2-axis mesh over 1 real device is enough to test RESOLUTION logic
# (pspec_for only reads mesh.shape)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisibility_fallback():
    rules = shd.train_rules()
    # 24 heads don't divide 16 -> replicated; 64 do -> sharded
    assert shd.pspec_for((3072, 24, 128), ("embed", "heads", "head_dim"),
                         rules, MESH) == P("data", None, None)
    assert shd.pspec_for((5120, 64, 128), ("embed", "heads", "head_dim"),
                         rules, MESH) == P("data", "model", None)


def test_axis_never_used_twice():
    rules = shd.train_rules()
    # cache_seq takes `model` first; act_kv then falls back to replication
    spec = shd.pspec_for((16, 4096, 16, 128),
                         ("batch", "cache_seq", "act_kv", None), rules, MESH)
    assert spec == P("data", "model", None, None)


def test_multipod_batch_axes():
    rules = shd.train_rules(multi_pod=True)
    spec = shd.pspec_for((256, 4096), ("batch", "seq"), rules, MESH_MP)
    assert spec == P(("pod", "data"), None)


def test_seq_shard_attn_lever():
    on = shd.train_rules(seq_shard_attn=True)
    off = shd.train_rules(seq_shard_attn=False)
    shape = (16, 4096, 24, 128)
    axes = ("batch", "attn_seq", "act_heads", None)
    assert shd.pspec_for(shape, axes, on, MESH) == P("data", "model", None,
                                                     None)
    assert shd.pspec_for(shape, axes, off, MESH) == P("data", None, None,
                                                      None)


dims = st.integers(1, 8).map(lambda k: 2 ** k)


@given(st.lists(dims, min_size=1, max_size=4), st.integers(0, 1))
@settings(max_examples=50, deadline=None)
def test_pspec_always_valid(shape, which):
    """Every resolved spec uses only existing axes, never reuses one, and
    only shards dims it divides."""
    rules = shd.train_rules() if which else shd.decode_rules()
    names = ["batch", "seq", "act_heads", "embed", "ff", "vocab", "expert",
             None]
    axes = tuple(names[i % len(names)] for i in range(len(shape)))
    spec = shd.pspec_for(tuple(shape), axes, rules, MESH)
    used = []
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        parts = (part,) if isinstance(part, str) else part
        for p in parts:
            assert p in MESH.shape
            assert p not in used
            used.append(p)
        total = int(np.prod([MESH.shape[p] for p in parts]))
        assert dim % total == 0


def test_shard_noop_outside_ctx():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shd.shard(x, "batch", None) is x
