"""DagSpec: validation, topological order, JSON round-trip, recomposition."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.workflow import DataRef, StepSpec, WorkflowSpec
from repro.dag import DagSpec, DagStep


def diamond(prefetch=True):
    return DagSpec(
        (
            DagStep("a", "p1", prefetch=prefetch),
            DagStep(
                "b",
                "p1",
                data_deps=(DataRef("k", "eu", 10),),
                prefetch=prefetch,
                params={"x": 1},
            ),
            DagStep("c", "p2", prefetch=prefetch),
            DagStep("d", "p2", prefetch=prefetch),
        ),
        (("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")),
        "diamond",
    )


def test_graph_accessors():
    spec = diamond()
    assert spec.sources() == ("a",)
    assert spec.sinks() == ("d",)
    assert spec.successors("a") == ("b", "c")
    assert spec.predecessors("d") == ("b", "c")
    assert spec.topo_order() == ("a", "b", "c", "d")


def test_topo_order_ignores_step_declaration_order():
    spec = DagSpec(
        (DagStep("d", "p"), DagStep("c", "p"), DagStep("b", "p"), DagStep("a", "p")),
        (("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")),
    )
    order = spec.topo_order()
    for a, b in spec.edges:
        assert order.index(a) < order.index(b)


def test_validation_rejects_bad_graphs():
    with pytest.raises(ValueError, match="empty"):
        DagSpec((), ())
    with pytest.raises(ValueError, match="duplicate step"):
        DagSpec((DagStep("a", "p"), DagStep("a", "p")), ())
    with pytest.raises(ValueError, match="unknown step"):
        DagSpec((DagStep("a", "p"),), (("a", "z"),))
    with pytest.raises(ValueError, match="self-edge"):
        DagSpec((DagStep("a", "p"),), (("a", "a"),))
    with pytest.raises(ValueError, match="duplicate edge"):
        DagSpec((DagStep("a", "p"), DagStep("b", "p")), (("a", "b"), ("a", "b")))
    with pytest.raises(ValueError, match="cycle"):
        DagSpec(
            (DagStep("a", "p"), DagStep("b", "p"), DagStep("c", "p")),
            (("a", "b"), ("b", "c"), ("c", "a")),
        )


def test_json_roundtrip_diamond():
    spec = diamond()
    again = DagSpec.from_json(spec.to_json())
    assert again == spec
    assert again.node("b").data_deps == spec.node("b").data_deps
    assert again.node("b").params == {"x": 1}


names = st.text(
    st.characters(min_codepoint=97, max_codepoint=122), min_size=1, max_size=6
)


@given(st.lists(names, min_size=1, max_size=7), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_json_roundtrip_random_dags(raw_nodes, seed):
    """Random DAGs (edges only forward in a random order) survive JSON."""
    import random

    nodes = list(dict.fromkeys(raw_nodes))  # unique, order-preserving
    rnd = random.Random(seed)
    edges = tuple(
        (nodes[i], nodes[j])
        for i in range(len(nodes))
        for j in range(i + 1, len(nodes))
        if rnd.random() < 0.5
    )
    spec = DagSpec(
        tuple(DagStep(n, f"p{rnd.randint(0, 2)}") for n in nodes), edges, "wf"
    )
    assert DagSpec.from_json(spec.to_json()) == spec


def test_reroute_is_pure_recomposition():
    spec = diamond()
    moved = spec.reroute("c", "p9")
    assert moved.node("c").platform == "p9"
    assert spec.node("c").platform == "p2"  # original untouched
    assert moved.edges == spec.edges
    assert moved.node("b").data_deps == spec.node("b").data_deps


def test_apply_placement_moves_many():
    placed = diamond().apply_placement({"a": "px", "d": "py"})
    assert placed.node("a").platform == "px"
    assert placed.node("d").platform == "py"
    assert placed.node("b").platform == "p1"


def test_apply_placement_rejects_unknown_step():
    with pytest.raises(ValueError, match="unknown step 'zzz'"):
        diamond().apply_placement({"zzz": "p1"})


def test_apply_placement_rejects_platform_outside_deployment_set():
    spec = diamond()
    # without a platform set, any target platform is accepted (per-request
    # data; the deployment is not known here)
    assert spec.apply_placement({"a": "p9"}).node("a").platform == "p9"
    with pytest.raises(ValueError, match="unknown platform 'p9'"):
        spec.apply_placement({"a": "p9"}, platforms=["p1", "p2"])
    # valid placements pass with the set given
    placed = spec.apply_placement({"a": "p2"}, platforms=["p1", "p2"])
    assert placed.node("a").platform == "p2"


def test_from_chain_degenerate_dag():
    wf = WorkflowSpec(
        (
            StepSpec("s0", "p0"),
            StepSpec("s1", "p1", data_deps=(DataRef("k", "eu"),)),
            StepSpec("s2", "p0"),
        ),
        "chain",
    )
    dag = DagSpec.from_chain(wf)
    assert dag.topo_order() == ("s0", "s1", "s2")
    assert dag.edges == (("s0", "s1"), ("s1", "s2"))
    assert dag.sources() == ("s0",) and dag.sinks() == ("s2",)
    assert dag.node("s1").data_deps == wf.steps[1].data_deps
    assert dag.workflow_id == "chain"
