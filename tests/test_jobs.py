"""repro.jobs: idempotent submission, engine retry/hedge/dead-letter
semantics, the timeout satellite (cancelled cascade + cleaned buffers),
and the chaos property test — random fault schedules and retry budgets
over chain/diamond/braid graphs must never hang, never mis-count, and
never return a wrong result."""

import random
import threading
import time

import pytest

from repro.core import Platform, PlatformRegistry
from repro.core.faults import (
    FaultEvent,
    FaultSchedule,
    InjectedFault,
    OutageEvent,
    RetryPolicy,
)
from repro.dag import DagDeployment, DagSpec, DagStep
from repro.jobs import DeadLetter, Job, JobManager, job_id
from repro.obs import Tracer

PLATFORMS = ("pA", "pB")


def _registry(sync=True):
    reg = PlatformRegistry()
    for name in PLATFORMS:
        reg.register(
            Platform(
                name=name, region=name, allows_sync=sync, native_prefetch=sync
            )
        )
    return reg


def _handler(payload, data):
    if isinstance(payload, dict):
        return sum(payload.values())
    return payload + 1


GRAPHS = {
    "chain": (("s1", "s2", "s3"), (("s1", "s2"), ("s2", "s3"))),
    "diamond": (
        ("a", "b", "c", "d"),
        (("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")),
    ),
    "braid": (
        ("a", "b", "c", "d", "e"),
        (("a", "b"), ("a", "c"), ("b", "c"), ("b", "d"), ("c", "d"), ("d", "e")),
    ),
}


def _spec(graph: str, rng=None) -> DagSpec:
    nodes, edges = GRAPHS[graph]
    rng = rng or random.Random(0)
    steps = tuple(DagStep(n, rng.choice(PLATFORMS)) for n in nodes)
    return DagSpec(steps=steps, edges=edges)


def _expected(spec: DagSpec, payload):
    """Reference evaluation of the DAG under ``_handler`` (steps are in
    topo order by construction)."""
    val = {}
    for step in spec.steps:
        preds = spec.predecessors(step.name)
        if not preds:
            arg = payload
        elif len(preds) == 1:
            arg = val[preds[0]]
        else:
            arg = {p: val[p] for p in preds}
        val[step.name] = _handler(arg, {})
    sinks = spec.sinks()
    return val[sinks[0]] if len(sinks) == 1 else {s: val[s] for s in sinks}


def _deploy(spec, **kw):
    dep = DagDeployment(registry=_registry(), **kw)
    for name in {s.name for s in spec.steps}:
        dep.deploy(name, _handler, list(PLATFORMS))
    return dep


# ---------------------------------------------------------------------------
# idempotent job ids
# ---------------------------------------------------------------------------
def test_completed_job_dedups_to_recorded_result():
    spec = _spec("chain")
    calls = []

    def counting(payload, data):
        calls.append(1)
        return _handler(payload, data)

    dep = DagDeployment(registry=_registry())
    for name in ("s1", "s2", "s3"):
        dep.deploy(name, counting, list(PLATFORMS))
    with dep:
        jm = JobManager(dep)
        j1 = jm.submit(5, spec=spec)
        n = len(calls)
        j2 = jm.submit(5, spec=spec)
        assert j2 is j1 and len(calls) == n  # no re-execution
        assert j1.result.outputs == _expected(spec, 5)
        assert jm.stats == {
            "submitted": 2,
            "kept": 2,
            "dead_lettered": 0,
            "deduped": 1,
            "executed": 1,
        }


def test_job_identity_is_placement_independent():
    spec_a = _spec("chain")
    other = "pB" if spec_a.node("s2").platform == "pA" else "pA"
    moved = spec_a.apply_placement({"s2": other})
    assert job_id(spec_a, 1) == job_id(moved, 1)
    assert job_id(spec_a, 1) != job_id(spec_a, 2)  # payload participates
    assert job_id(spec_a, 1) != job_id(_spec("diamond"), 1)  # shape too


def test_dead_lettered_job_reexecutes_on_resubmit():
    spec = _spec("chain")
    dead = FaultSchedule([OutageEvent(0, None, platform="pA")], seed=1)
    tracer = Tracer()
    with _deploy(
        spec,
        faults=dead,
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.001),
        tracer=tracer,
    ) as dep:
        jm = JobManager(dep)
        j1 = jm.submit(3, spec=spec)
        assert j1.status == "dead_lettered" and "InjectedFault" in j1.error
        j2 = jm.submit(3, spec=spec)
        assert j2 is not j1  # a dead letter is a record, not a tombstone
        assert len(jm.dead_letters) == 2
        assert all(isinstance(d, DeadLetter) for d in jm.dead_letters)
        assert jm.stats["kept"] + jm.stats["dead_lettered"] == jm.stats["submitted"]
        events = [e for e in tracer.events if e[1] == "job.dead_letter"]
        assert len(events) == 2 and events[0][2]["job_id"] == j1.job_id


# ---------------------------------------------------------------------------
# engine retry / hedge / timeout
# ---------------------------------------------------------------------------
def test_engine_retry_recovers_and_emits_span_events():
    from repro.core.faults import _STREAM_FAIL, _node_salt, hash_u01

    spec = _spec("chain", random.Random(3))
    step0 = spec.steps[0]
    # pick a seed + probability that deterministically fail attempt 0 and
    # pass attempt 1 for request 0 (the hash is the contract, so we can)
    salt = _node_salt(step0.name, step0.platform)
    seed = p = None
    for s in range(100):
        u0 = float(hash_u01(s, salt, 0, _STREAM_FAIL, [0])[0])
        u1 = float(hash_u01(s, salt, 1, _STREAM_FAIL, [0])[0])
        if u0 < u1:
            seed, p = s, (u0 + u1) / 2
            break
    fs = FaultSchedule(
        [FaultEvent(step0.platform, p_error=p, step=step0.name, to_request=1)],
        seed=seed,
    )
    tracer = Tracer()
    with _deploy(
        spec,
        faults=fs,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.001),
        tracer=tracer,
    ) as dep:
        r = dep.run(spec, 10)
        assert r.status == "ok" and r.outputs == _expected(spec, 10)
        assert r.timeline[step0.name]["attempts"] == 2
        assert dep.stats["retries"] == 1 and dep.stats["attempt_errors"] == 1
        trace = tracer.last()
        evs = [e for s in trace.spans for e in s.events if e[1] == "retry"]
        assert len(evs) == 1
        assert evs[0][2]["injected"] and evs[0][2]["backoff_s"] > 0
        # telemetry learned the failed attempt
        assert dep.report()["engine"]["retries"] == 1


def test_engine_budget_exhaustion_raises_injected_fault():
    spec = _spec("chain")
    fs = FaultSchedule([OutageEvent(0, None, platform="pA")], seed=0)
    with _deploy(
        spec, faults=fs, retry=RetryPolicy(max_attempts=2, backoff_base_s=0.001)
    ) as dep:
        with pytest.raises(InjectedFault):
            dep.run(spec, 1)


def test_engine_hedging_first_finisher_wins():
    spec = DagSpec(steps=(DagStep("s1", "pA"),), edges=())
    lock = threading.Lock()
    calls = {"n": 0}

    def straggler(payload, data):
        with lock:
            calls["n"] += 1
            k = calls["n"]
        if k == 1:
            time.sleep(0.8)  # the primary stalls; the hedge must win
        return payload + 1

    dep = DagDeployment(
        registry=_registry(), retry=RetryPolicy(hedge_after_s=0.05)
    )
    dep.deploy("s1", straggler, list(PLATFORMS))
    with dep:
        t0 = time.perf_counter()
        r = dep.run(spec, 1)
        took = time.perf_counter() - t0
        assert r.outputs == 2 and took < 0.6
        assert dep.stats["hedges"] == 1 and dep.stats["hedge_wins"] == 1


def test_timeout_returns_structured_record_and_cleans_buffers():
    spec = DagSpec(
        steps=(DagStep("s1", "pA"), DagStep("s2", "pB")), edges=(("s1", "s2"),)
    )
    release = threading.Event()

    def slow(payload, data):
        release.wait(5.0)
        return payload

    dep = DagDeployment(registry=_registry(sync=False))
    dep.deploy("s1", slow, list(PLATFORMS))
    dep.deploy("s2", slow, list(PLATFORMS))
    with dep:
        r = dep.run(spec, 1, timeout_s=0.2)
        assert r.status == "timeout" and "TimeoutError" in r.error
        assert r.outputs is None
        assert dep.stats["timeouts"] == 1
        release.set()
        time.sleep(0.3)  # let the cancelled cascade unwind
        assert dep.store.keys("__payload__/") == []
        # the deployment still serves fresh requests afterwards
        r2 = dep.run(spec, 1, timeout_s=10.0)
        assert r2.status == "ok" and r2.outputs == 1


def test_timed_out_job_dead_letters():
    spec = DagSpec(steps=(DagStep("s1", "pA"),), edges=())
    release = threading.Event()

    def slow(payload, data):
        release.wait(5.0)
        return payload

    dep = DagDeployment(registry=_registry())
    dep.deploy("s1", slow, list(PLATFORMS))
    with dep:
        jm = JobManager(dep, timeout_s=0.2)
        j = jm.submit(1, spec=spec)
        release.set()
        assert j.status == "dead_lettered" and "Timeout" in j.error
        assert jm.dead_letters[0].request_id is not None


# ---------------------------------------------------------------------------
# chaos property test
# ---------------------------------------------------------------------------
def _random_schedule(rng: random.Random) -> FaultSchedule:
    events = []
    for _ in range(rng.randint(1, 3)):
        events.append(
            FaultEvent(
                rng.choice(PLATFORMS),
                p_error=rng.uniform(0.05, 0.5),
                from_request=rng.randint(0, 4),
                to_request=rng.randint(8, 24),
            )
        )
    if rng.random() < 0.7:
        start = rng.randint(2, 10)
        events.append(
            OutageEvent(
                start, start + rng.randint(2, 6), platform=rng.choice(PLATFORMS)
            )
        )
    return FaultSchedule(events, seed=rng.randint(0, 2**31))


@pytest.mark.parametrize("seed", (0, 1, 2))
@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_chaos_jobs_complete_correctly_or_dead_letter(graph, seed):
    rng = random.Random(1000 * seed + hash(graph) % 997)
    spec = _spec(graph, rng)
    schedule = _random_schedule(rng)
    retry = RetryPolicy(
        max_attempts=rng.randint(1, 4), backoff_base_s=0.001, seed=seed
    )
    with _deploy(spec, faults=schedule, retry=retry) as dep:
        jm = JobManager(dep, timeout_s=20.0)
        jobs = [jm.submit(k, spec=spec) for k in range(12)]
        for k, job in enumerate(jobs):
            assert job.status in ("completed", "dead_lettered")
            assert job.done.is_set()  # bounded join: every submit resolved
            if job.status == "completed":
                assert job.result.outputs == _expected(spec, k)
            else:
                assert job.error is not None
        s = jm.stats
        assert s["kept"] + s["dead_lettered"] == s["submitted"] == 12
        assert len(jm.dead_letters) == sum(
            1 for j in jobs if j.status == "dead_lettered"
        )


def test_chaos_ledger_exact_under_multithreaded_clients():
    """8 client threads hammer overlapping payloads through a faulty
    deployment: the ledger must balance exactly and every job must reach a
    final state — no hangs, no double counts."""
    rng = random.Random(42)
    spec = _spec("diamond", rng)
    schedule = FaultSchedule(
        [
            FaultEvent("pA", p_error=0.3, to_request=200),
            OutageEvent(10, 18, platform="pB"),
        ],
        seed=9,
    )
    with _deploy(
        spec,
        faults=schedule,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.001),
    ) as dep:
        jm = JobManager(dep, timeout_s=20.0)
        results: list = []

        def client(tid):
            got = []
            for k in range(12):
                got.append(jm.submit(k % 6, spec=spec))
            results.append(got)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        s = jm.stats
        assert s["submitted"] == 8 * 12
        assert s["kept"] + s["dead_lettered"] == s["submitted"]
        for got in results:
            for job in got:
                assert job.done.is_set()
                assert job.status in ("completed", "dead_lettered")
        # completed jobs returned the correct value for their payload
        for job in {j.job_id: j for g in results for j in g}.values():
            if job.status == "completed":
                out = job.result.outputs
                assert out in {_expected(spec, k) for k in range(6)}


def test_job_dataclass_shapes():
    j = Job(job_id="abc")
    assert j.status == "running" and not j.done.is_set()
    d = DeadLetter("abc", "boom", at=0.0)
    assert d.request_id is None
