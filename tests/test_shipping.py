"""Function-shipping placement: DP optimality + the paper's §4.3 decision."""
import itertools

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.shipping import PlacementCosts, chain_cost, place_chain
from repro.core.workflow import DataRef, StepSpec, WorkflowSpec


def costs_from_tables(fetch, compute, transfer):
    return PlacementCosts(
        fetch_s=lambda name, p, deps: fetch.get((name, p), 0.0),
        compute_s=lambda name, p: compute.get((name, p), 0.1),
        transfer_s=lambda a, b, size: transfer.get((a, b), 0.0),
        payload_size=1.0)


def test_ships_ocr_to_data_region():
    """Reproduces the paper's §4.3 decision: the optimizer moves OCR to
    us-east-1, where its data lives."""
    spec = WorkflowSpec((
        StepSpec("check", "edge"), StepSpec("virus", "edge"),
        StepSpec("ocr", "eu-central-1",
                 data_deps=(DataRef("scans", "us-east-1", int(30e6)),)),
        StepSpec("e_mail", "us-east-1")))
    fetch = {("ocr", "eu-central-1"): 3.6, ("ocr", "us-east-1"): 0.9}
    compute = {("ocr", p): 5.85 for p in ("eu-central-1", "us-east-1")}
    transfer = {(a, b): (0.1 if a == b else 0.8)
                for a in ("edge", "eu-central-1", "us-east-1")
                for b in ("edge", "eu-central-1", "us-east-1")}
    placed = place_chain(spec, {"ocr": ["eu-central-1", "us-east-1"]},
                         costs_from_tables(fetch, compute, transfer))
    assert placed.steps[2].platform == "us-east-1"


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_chain_dp_matches_bruteforce(seed):
    import random
    rnd = random.Random(seed)
    plats = ["p0", "p1", "p2"]
    n = rnd.randint(2, 4)
    spec = WorkflowSpec(tuple(StepSpec(f"s{i}", "p0") for i in range(n)))
    fetch = {(f"s{i}", p): rnd.uniform(0, 2) for i in range(n)
             for p in plats}
    compute = {(f"s{i}", p): rnd.uniform(0.1, 2) for i in range(n)
               for p in plats}
    transfer = {(a, b): 0.0 if a == b else rnd.uniform(0.05, 1.0)
                for a in plats for b in plats}
    costs = costs_from_tables(fetch, compute, transfer)
    cand = {f"s{i}": plats for i in range(n)}
    placed = place_chain(spec, cand, costs)
    best_dp = chain_cost(placed, costs)
    best_brute = min(
        chain_cost(WorkflowSpec(tuple(
            StepSpec(f"s{i}", route[i]) for i in range(n))), costs)
        for route in itertools.product(plats, repeat=n))
    assert best_dp == pytest.approx(best_brute, rel=1e-9)
