"""repro.obs level 2: windowed histograms (epoch-ring rotation/eviction),
SLO burn-rate alerting, tail-based trace sampling (thread-exact counters),
the transfer_table calibration hook on all three simulator backends, and
the what-if causal profiler — plus the controller's ``slo`` trigger."""

import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.adapt import RecompositionController, TelemetryHub
from repro.core import simulator as sm
from repro.core.shipping import PlacementCosts
from repro.dag import DagSpec, DagStep
from repro.obs import (
    CalibratedWorkflow,
    LogHistogram,
    MetricsRegistry,
    SloSpec,
    SloTracker,
    TailSampler,
    Tracer,
    WhatIfProfiler,
    WindowedHistogram,
    calibrate,
)

DOC_EDGES = (
    ("check", "virus"),
    ("check", "ocr"),
    ("virus", "e_mail"),
    ("ocr", "e_mail"),
)


def _doc_spec(n=8, seeds=None, tracer=None):
    return sm.ExperimentSpec(
        sm.document_workflow_fig4(),
        edges=DOC_EDGES,
        n_requests=n,
        seeds=seeds,
        tracer=tracer,
    )


# ---------------------------------------------------------------------------
# LogHistogram.merge
# ---------------------------------------------------------------------------
def test_histogram_merge_matches_combined_stream():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(-2.0, 0.8, 1000)
    ys = rng.lognormal(-1.0, 0.5, 1000)
    a, b, both = LogHistogram(), LogHistogram(), LogHistogram()
    for x in xs:
        a.observe(float(x))
        both.observe(float(x))
    for y in ys:
        b.observe(float(y))
        both.observe(float(y))
    a.merge(b)
    assert a.counts == both.counts
    assert a.count == both.count == 2000
    assert a.sum == pytest.approx(both.sum)
    assert a.max == both.max
    for q in (0.5, 0.95, 0.99):
        assert a.quantile(q) == both.quantile(q)


def test_histogram_merge_rejects_mismatched_geometry():
    with pytest.raises(ValueError):
        LogHistogram().merge(LogHistogram(n_buckets=80))


# ---------------------------------------------------------------------------
# WindowedHistogram: the property the whole level-2 plane rests on
# ---------------------------------------------------------------------------
@settings(max_examples=60)
@given(
    values=st.lists(st.floats(1e-3, 10.0), min_size=1, max_size=40),
    gaps=st.lists(st.floats(0.0, 3.0), min_size=40, max_size=40),
    epochs=st.integers(1, 8),
)
def test_windowed_quantiles_track_exact_order_statistic(values, gaps, epochs):
    """Under arbitrary rotation/eviction, the windowed quantile must match
    the exact order statistic of the still-live observations to within one
    bucket width (~15% relative), and the live COUNT and MAX exactly."""
    wh = WindowedHistogram(window_s=float(epochs), epochs=epochs)  # 1 s/epoch
    now, times = 0.0, []
    for v, g in zip(values, gaps):
        now += g
        times.append(now)
        wh.observe(v, now=now)
    e_last = int(np.floor(now / wh.epoch_s))
    live = sorted(
        v
        for v, t in zip(values, times)
        if int(np.floor(t / wh.epoch_s)) > e_last - epochs
    )
    w = wh.window()
    assert w.count == len(live)
    assert w.max == max(live)
    for q in (0.5, 0.95, 0.99):
        exact = live[int(np.floor(q * (len(live) - 1)))]
        assert abs(w.quantile(q) - exact) / exact < 0.16, (q, w.quantile(q))
    assert wh.total.count == len(values)  # since-birth never evicts


def test_windowed_eviction_drops_stale_max():
    """Regression for the lifetime-max clamp: a 100 s outlier that aged out
    of the window must not cap (or inflate) the windowed p99."""
    wh = WindowedHistogram(window_s=10.0, epochs=5)
    wh.observe(100.0, now=0.0)
    for k in range(50):
        wh.observe(0.01, now=20.0 + k * 0.1)
    w = wh.window()
    assert w.max < 1.0
    assert w.quantile(0.99) < 1.0
    snap = wh.snapshot()
    assert snap["max_s"] == 100.0  # since-birth keeps the outlier
    assert snap["w_max_s"] < 1.0
    assert snap["w_count"] == 50


def test_window_probe_is_read_only_and_ages_out():
    wh = WindowedHistogram(window_s=4.0, epochs=4)
    for k in range(8):
        wh.observe(1.0, now=float(k))
    assert wh.window(now=7.0).count == 4
    assert wh.window(now=100.0).count == 0  # probing the future: all aged out
    assert wh.window(now=7.0).count == 4  # ...and the probe mutated nothing
    assert wh.total.count == 8


def test_rotation_survives_large_clock_jump():
    wh = WindowedHistogram(window_s=4.0, epochs=4)
    wh.observe(1.0, now=0.0)
    wh.observe(2.0, now=1e9)  # recycle work is bounded by the ring size
    w = wh.window()
    assert w.count == 1 and w.max == 2.0


# ---------------------------------------------------------------------------
# MetricsRegistry: windowed surfaces + snapshot under contention
# ---------------------------------------------------------------------------
def test_registry_window_quantiles_and_top():
    reg = MetricsRegistry(window_s=10.0, epochs=5)
    for k in range(20):
        reg.observe("fast/x", 0.01, now=float(k))
        reg.observe("slow/y", 1.0, now=float(k))
    # while everything is live, windowed and since-birth p95 agree
    assert reg.window_quantiles("slow/y", now=19.0)[1] == pytest.approx(
        reg.quantiles("slow/y")[1]
    )
    assert reg.top(1, key="w_p99_s", now=19.0)[0][0] == "slow/y"
    # far future: the window empties, since-birth stays
    assert reg.window_quantiles("slow/y", now=1e6) == (0.0, 0.0, 0.0)
    assert reg.quantiles("slow/y")[0] > 0
    assert reg.snapshot(now=19.0)["fast/x"]["w_count"] == 10


def test_registry_snapshot_concurrent_with_observes():
    """snapshot copies counts under the lock and does quantile math outside
    it — under a writer hammering observe, every snapshot must still be a
    coherent (monotone-count) copy, and nothing may raise."""
    reg = MetricsRegistry()
    reg.observe("s/a", 0.01, now=0.0)
    stop = threading.Event()

    def hammer():
        k = 1
        while not stop.is_set():
            reg.observe("s/a", 0.01, now=float(k % 7))
            k += 1

    t = threading.Thread(target=hammer)
    t.start()
    try:
        counts = [reg.snapshot()["s/a"]["count"] for _ in range(100)]
    finally:
        stop.set()
        t.join()
    assert counts == sorted(counts)


# ---------------------------------------------------------------------------
# SloSpec / SloTracker
# ---------------------------------------------------------------------------
def test_slo_spec_validates():
    with pytest.raises(ValueError):
        SloSpec("s", objective_s=0.0)
    with pytest.raises(ValueError):
        SloSpec("s", objective_s=1.0, target=1.0)
    with pytest.raises(ValueError):
        SloSpec("s", objective_s=1.0, fast_window_s=10.0, slow_window_s=5.0)
    assert SloSpec("s", objective_s=1.0, target=0.9).error_budget == pytest.approx(0.1)


def test_slo_burn_alert_is_edge_triggered_and_recovers():
    spec = SloSpec(
        "p95",
        objective_s=1.0,
        target=0.9,
        fast_window_s=8.0,
        slow_window_s=24.0,
        burn_threshold=4.0,
        min_count=4,
    )
    tracer = Tracer()
    slo = SloTracker(spec, tracer=tracer)
    now = 0.0
    for _ in range(20):  # healthy: never burns
        assert not slo.record(0.5, now=now)
        now += 1.0
    assert slo.alerts == 0
    burn_at = None
    for k in range(20):  # sustained violation
        if slo.record(5.0, now=now) and burn_at is None:
            burn_at = k
        now += 1.0
    assert burn_at is not None and burn_at + 1 >= spec.min_count
    assert slo.burning and slo.alerts == 1  # one alert per episode
    burns = [e for e in tracer.events if e[1] == "slo.burn"]
    assert len(burns) == 1
    attrs = burns[0][2]
    assert attrs["slo"] == "p95"
    assert attrs["fast_burn"] >= spec.burn_threshold
    for _ in range(30):  # recovery clears the alert without a new episode
        slo.record(0.5, now=now)
        now += 1.0
    assert not slo.burning and slo.alerts == 1
    assert slo.stats["recoveries"] == 1
    assert any(e[1] == "slo.ok" for e in tracer.events)
    snap = slo.snapshot(now=now)
    assert snap["burning"] is False and snap["alerts"] == 1
    assert snap["violations"] == 20 and snap["observed"] == 70


def test_slo_min_count_suppresses_thin_window_alerts():
    slo = SloTracker(
        SloSpec(
            "s",
            objective_s=0.1,
            target=0.9,
            fast_window_s=10.0,
            slow_window_s=10.0,
            burn_threshold=1.0,
            min_count=4,
        )
    )
    for k in range(3):  # burn rate 10x, but the window is too thin to page
        assert not slo.record(5.0, now=float(k))
    assert slo.alerts == 0
    assert slo.record(5.0, now=3.0)
    assert slo.alerts == 1


# ---------------------------------------------------------------------------
# TailSampler
# ---------------------------------------------------------------------------
def test_sampler_reasons_and_threshold_arming():
    s = TailSampler(
        window_s=100.0,
        epochs=10,
        head_every=4,
        slo=SloSpec("s", objective_s=1.0, target=0.9),
        min_count=8,
    )
    assert s.threshold() == 0.0  # cold window: slow test not armed
    assert s.decide(0.01, now=0.0) == (True, "head")  # 1-in-N baseline
    assert s.decide(2.0, now=1.0) == (True, "slo")  # violation while cold
    for k in range(8):
        s.decide(0.01, now=2.0 + k)  # arm the slow test
    assert s.threshold(now=9.0) > 0.0
    assert s.decide(5.0, now=10.0) == (True, "slow")  # slow outranks slo
    assert s.decide(0.001, now=11.0) == (False, None)
    assert s.stats["kept"] + s.stats["evicted"] == s.stats["seen"]


def test_sampler_counters_exact_under_threads():
    """Thread isolation: four writers race decide(); the counters must come
    out exact (kept + evicted == seen) and exactly the slow 2% retained —
    no lost updates, no fast request misjudged against a torn threshold."""
    s = TailSampler(
        window_s=1e9,
        epochs=4,
        quantile=0.95,
        margin=2.0,
        head_every=0,
        min_count=16,
    )
    rng = np.random.default_rng(5)
    for k, v in enumerate(rng.uniform(0.01, 0.02, 64)):  # arm single-threaded
        assert s.decide(float(v), now=float(k)) == (False, None)
    per_thread, slow_every = 200, 50  # 2% slow: far below the p95 bar
    results = [[] for _ in range(4)]

    def worker(i):
        r = np.random.default_rng(100 + i)
        for k in range(per_thread):
            v = 5.0 if k % slow_every == 0 else float(r.uniform(0.01, 0.02))
            results[i].append(s.decide(v, now=float(64 + k)))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    n_slow = 4 * (per_thread // slow_every)
    total = 64 + 4 * per_thread
    assert s.stats["seen"] == total
    assert s.stats["kept"] + s.stats["evicted"] == total
    assert s.stats["kept"] == s.stats["kept_slow"] == n_slow
    flat = [d for rs in results for d in rs]
    assert sum(1 for keep, _ in flat if keep) == n_slow
    assert all(reason == "slow" for keep, reason in flat if keep)


def test_tracer_tail_sampling_keeps_slow_folds_all():
    sampler = TailSampler(window_s=1e6, epochs=4, margin=2.0, head_every=0, min_count=8)
    tr = Tracer(metrics=MetricsRegistry(), sampler=sampler)
    rng = np.random.default_rng(2)
    for k in range(20):
        healthy = float(rng.uniform(0.01, 0.02))
        tr.finish(tr.begin(name=f"r{k}", t0=0.0), t_end=healthy)
    assert tr.traces() == []  # all healthy: no span tree retained
    t = tr.begin(name="slow", t0=0.0)
    tr.finish(t, t_end=5.0)
    assert tr.last() is t
    assert t.root.attrs["sampled"] == "slow"
    # aggregates stay unbiased: every request folded, kept or not
    assert tr.metrics.snapshot(now=5.0)["request_s/all"]["count"] == 21
    assert sampler.stats["seen"] == 21
    assert sampler.stats["kept"] == sampler.stats["kept_slow"] == 1


# ---------------------------------------------------------------------------
# simulator: transfer_table hook + draw neutrality of the full stack
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["scalar", "numpy", "jax"])
def test_transfer_table_overrides_edges_on_every_backend(backend):
    seeds = (0,) if backend == "jax" else None

    def run(table):
        simulator = sm.WorkflowSimulator(
            sm.paper_platforms(), seed=7, transfer_table=table
        )
        return np.asarray(
            simulator.simulate(_doc_spec(n=8, seeds=seeds), backend=backend)
        )

    base = run(None)
    assert np.array_equal(base, run({}))  # empty table: bit-for-bit neutral
    slow = run({("check", "ocr"): 50.0})  # pinned edge lands on the path
    assert np.all(slow >= base + 40.0)
    fast = run({e: 0.0 for e in DOC_EDGES})  # free edges only ever help
    assert np.all(fast <= base + 1e-9)


@pytest.mark.parametrize("backend", ["scalar", "numpy", "jax"])
def test_level2_stack_is_draw_neutral(backend):
    """Windowed metrics + tail sampler attached must not consume, reorder,
    or perturb a single rng draw on any backend."""
    seeds = (0, 1) if backend == "jax" else None
    off = sm.WorkflowSimulator(sm.paper_platforms(), seed=7).simulate(
        _doc_spec(n=16, seeds=seeds), backend=backend
    )
    tracer = Tracer(
        metrics=MetricsRegistry(window_s=60.0),
        sampler=TailSampler(window_s=60.0, head_every=2, min_count=4),
    )
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=7)
    on = simulator.simulate(
        _doc_spec(n=16, seeds=seeds, tracer=tracer), backend=backend
    )
    assert np.array_equal(off, on), "sampling/windowing perturbed the draws"
    assert tracer.metrics.snapshot()  # the stack actually saw the run


# ---------------------------------------------------------------------------
# calibration + what-if profiler
# ---------------------------------------------------------------------------
def test_calibrate_replays_the_observed_trace():
    tracer = Tracer()
    simulator = sm.WorkflowSimulator(sm.paper_platforms(), seed=3)
    simulator.simulate(_doc_spec(n=1, tracer=tracer), backend="scalar")
    trace = tracer.last()
    world = calibrate(trace)
    replay = Tracer()
    world.simulator(seed=0).simulate(
        world.spec(n_requests=1, tracer=replay), backend="scalar"
    )
    assert replay.last().total_s == pytest.approx(trace.total_s, rel=0.05)


def test_profiler_fetch_speedup_beats_compute_on_fetch_dominated_flow():
    """The causal-profiling regression: on a fetch-dominated workflow a
    virtual 2x fetch speedup must predict a strictly larger p95 win than
    the same speedup applied to compute."""
    world = CalibratedWorkflow(
        platforms=(sm.SimPlatform("p", "r", cold_start=sm.Dist(0.0, 0.0)),),
        steps=(
            sm.SimStep("a", "p", compute=sm.Dist(0.3, 0.0)),
            sm.SimStep(
                "b",
                "p",
                compute=sm.Dist(0.3, 0.0),
                fetch=sm.Dist(2.0, 0.0),
                prefetch=False,
            ),
        ),
        edges=(("a", "b"),),
        transfer_table={("a", "b"): 0.05},
        msg_latency_s=0.0,
        prefetch=False,
    )
    ranked = WhatIfProfiler(world, n_requests=40).rank(speedup=2.0)
    by = {(iv.kind, iv.target): iv for iv in ranked}
    fetch, compute = by[("fetch", "b")], by[("compute", "b")]
    assert fetch.delta_s == pytest.approx(-1.0, rel=0.01)  # 2 s serial fetch
    assert compute.delta_s == pytest.approx(-0.15, rel=0.01)
    assert fetch.delta_s < compute.delta_s < 0
    assert ranked[0] is fetch  # the fetch fix tops the ranking
    assert "fetch b" in fetch.label and fetch.delta_pct < 0


# ---------------------------------------------------------------------------
# controller: the slo trigger
# ---------------------------------------------------------------------------
def _costs(compute=None):
    compute = compute or {}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.25 * len(deps),
        compute_s=lambda name, p: compute.get((name, p), 0.1),
        transfer_s=lambda a, b, size: 0.0 if a == b else 0.5,
        payload_size=1.5e6,
    )


def _chain(work="pA"):
    return DagSpec(
        (
            DagStep("ingest", "edge"),
            DagStep("work", work),
            DagStep("deliver", "edge"),
        ),
        (("ingest", "work"), ("work", "deliver")),
        "t",
    )


def test_controller_slo_trigger_fires_once_per_episode():
    hub = TelemetryHub(alpha=1.0)
    tracer = Tracer()
    slo = SloTracker(
        SloSpec(
            "p95",
            objective_s=0.1,
            target=0.9,
            fast_window_s=10.0,
            slow_window_s=10.0,
            burn_threshold=1.0,
            min_count=4,
        ),
        tracer=tracer,
    )
    ctrl = RecompositionController(
        hub,
        _costs(compute={("work", "pA"): 0.1, ("work", "pB"): 0.2}),
        {"work": ["pA", "pB"]},
        every_n=10**9,  # cost triggers off: only the SLO can force a recompute
        drift_ratio=10**9,
        min_samples=1,
        tracer=tracer,
        slo=slo,
    )
    spec = _chain("pA")
    for k in range(6):  # healthy: never recomputes
        slo.record(0.05, now=float(k))
        assert ctrl.tick(spec) is None
    assert ctrl.stats["recomputes"] == 0
    # pA degrades: the SLO burns, and observed costs make pB the winner
    hub.record_compute("work", "pA", 5.0)
    for k in range(6, 12):
        slo.record(5.0, now=float(k))
    assert slo.alerts == 1
    placement = ctrl.tick(spec)
    assert placement is not None and placement["work"] == "pB"
    assert ctrl.stats["slo_triggers"] == 1 and ctrl.last_trigger == "slo"
    decision = [e for e in tracer.events if e[1] == "recompose.decision"][-1]
    assert decision[2]["trigger"] == "slo" and decision[2]["slo"] == "p95"
    # latched: still burning, but the episode was handled — no re-recompute
    spec = spec.apply_placement(placement)
    slo.record(5.0, now=12.0)
    assert ctrl.tick(spec) is None
    assert ctrl.stats["recomputes"] == 1
