"""Checkpointing: roundtrip, atomicity, async overlap, GC."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


@pytest.fixture
def tmp_ckpt(tmp_path):
    return CheckpointManager(str(tmp_path / "ckpt"), keep=2)


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": jnp.ones((8, 8)), "count": jnp.int32(7)}}


def test_roundtrip(tmp_ckpt):
    t = tree()
    tmp_ckpt.save(10, t, blocking=True)
    restored = tmp_ckpt.restore(10, jax.tree_util.tree_map(jnp.zeros_like, t))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b)), t, restored)


def test_async_save_then_wait(tmp_ckpt):
    t = tree(1)
    tmp_ckpt.save(5, t, blocking=False)
    tmp_ckpt.wait()
    assert tmp_ckpt.latest_step() == 5


def test_atomicity_incomplete_save_ignored(tmp_ckpt):
    t = tree(2)
    tmp_ckpt.save(1, t, blocking=True)
    # simulate a crash mid-save: a step dir without a manifest
    broken = os.path.join(tmp_ckpt.dir, "step_2")
    os.makedirs(broken)
    np.save(os.path.join(broken, "junk.npy"), np.zeros(3))
    assert tmp_ckpt.latest_step() == 1     # step_2 has no manifest


def test_gc_keeps_last_k(tmp_ckpt):
    t = tree(3)
    for s in (1, 2, 3, 4):
        tmp_ckpt.save(s, t, blocking=True)
    assert tmp_ckpt.all_steps() == [3, 4]


def test_restore_rejects_shape_mismatch(tmp_ckpt):
    t = tree(4)
    tmp_ckpt.save(9, t, blocking=True)
    bad = jax.tree_util.tree_map(jnp.zeros_like, t)
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(AssertionError):
        tmp_ckpt.restore(9, bad)
