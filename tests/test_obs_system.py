"""repro.obs on the REAL dataflow engine: end-to-end request traces,
critical-path attribution against wall-clock, concurrent-request trace
isolation through an AdaptiveDeployment, and recomposition decisions
landing in the tracer's event ring."""

import threading
import time

import numpy as np
import pytest

from repro.adapt import AdaptiveDeployment, RecompositionController, TelemetryHub
from repro.core import DataRef, Platform, PlatformRegistry
from repro.core.shipping import PlacementCosts
from repro.dag import DagDeployment, DagSpec, DagStep
from repro.obs import MetricsRegistry, Tracer, extract_critical_path, instrument


def make_registry():
    reg = PlatformRegistry()
    reg.register(Platform("edge", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("pA", "us", kind="cloud"))
    reg.register(Platform("pB", "us", kind="cloud"))
    return reg


def diamond_spec(prefetch=True):
    return DagSpec(
        (
            DagStep("src", "edge", prefetch=prefetch),
            DagStep(
                "left", "pA", data_deps=(DataRef("d/left", "us"),), prefetch=prefetch
            ),
            DagStep("right", "pB", prefetch=prefetch),
            DagStep("sink", "pA", prefetch=prefetch),
        ),
        (("src", "left"), ("src", "right"), ("left", "sink"), ("right", "sink")),
        "diamond",
    )


def sleepy(dt):
    def handler(payload, data):
        time.sleep(dt)
        return payload

    return handler


def join_handler(payload, data):
    time.sleep(0.01)
    return sum(payload.values())


@pytest.fixture()
def traced_dag():
    tracer = Tracer(metrics=MetricsRegistry())
    dep = DagDeployment(make_registry(), tracer=tracer)
    dep.store.enforce_latency = True
    dep.store.network.set_link("eu", "us", 0.005, 100e6)
    dep.store.put("d/left", b"x" * 1000, region="us")
    dep.deploy("src", sleepy(0.01), ["edge"])
    dep.deploy(
        "left",
        sleepy(0.03),
        ["pA"],
        abstract_args=((4,),),
        compile_fn=lambda *a: time.sleep(0.002),
    )
    dep.deploy("right", sleepy(0.02), ["pB"])
    dep.deploy("sink", join_handler, ["pA", "pB"])
    yield dep, tracer
    dep.shutdown()


def test_engine_trace_attribution_matches_wall_clock(traced_dag):
    dep, tracer = traced_dag
    dep.run(diamond_spec(), 1)  # warm
    tracer.clear()
    r = dep.run(diamond_spec(), 1)
    trace = tracer.last()
    assert trace is not None and trace.trace_id == trace.root.trace_id
    nodes = trace.node_spans()
    assert set(nodes) == {"src", "left", "right", "sink"}
    cp = extract_critical_path(trace)
    att = cp.attribution
    # acceptance bar: path + attribution explain end-to-end latency
    assert sum(att.values()) == pytest.approx(cp.total_s, rel=1e-9)
    assert cp.total_s == pytest.approx(r.total_s, rel=0.05)
    assert cp.nodes[0] == "src" and cp.nodes[-1] == "sink"
    assert att["compute"] > 0.03  # at least src+branch+sink sleeps


def test_engine_component_events_attach_to_spans(traced_dag):
    dep, tracer = traced_dag
    dep.run(diamond_spec(), 1)
    names = {
        name
        for trace in tracer.traces()
        for span in trace.spans
        for _t, name, _a in span.events
    }
    # prefetch fired off the poke, payloads buffered through the store
    assert any(n.startswith("prefetch.") or n.startswith("fetch.") for n in names)
    assert "store.put" in names and "store.get" in names
    assert any(n.startswith("compile.") for n in names)


def test_engine_metrics_merged_into_report(traced_dag):
    dep, tracer = traced_dag
    dep.run(diamond_spec(), 1)
    metrics = dep.report()["metrics"]
    assert any(k.startswith("node_s/") for k in metrics)
    assert any(k.startswith("compute_s/") for k in metrics)
    # requests aggregate under ONE series, not one per request id
    assert metrics["request_s/all"]["count"] == 1
    assert not any(tracer.last().trace_id in k for k in metrics)


def test_timeline_payload_wait_and_transfer(traced_dag):
    dep, _ = traced_dag
    r = dep.run(diamond_spec(), 1)
    sink = r.timeline["sink"]
    assert set(sink["payload_wait_s"]) == {"left", "right"}
    assert all(v >= 0 for v in sink["payload_wait_s"].values())
    assert set(sink["transfer_s"]) <= {"left", "right"}
    assert all(v >= 0 for v in sink["transfer_s"].values())


# ---------------------------------------------------------------------------
# concurrent-request trace isolation
# ---------------------------------------------------------------------------
def fallback_costs():
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.02 * len(deps),
        compute_s=lambda name, p: 0.02,
        transfer_s=lambda a, b, size: 0.0 if a == b else 0.01,
        payload_size=1000,
    )


def test_concurrent_requests_trace_isolation(traced_dag):
    dep, tracer = traced_dag
    adapt = AdaptiveDeployment(
        dep,
        diamond_spec(),
        {"sink": ["pA", "pB"]},
        fallback_costs(),
        every_n=4,
        tracer=tracer,
    )
    adapt.run(1)  # warm
    tracer.clear()
    n_threads, errs = 6, []

    def one():
        try:
            adapt.run(2)
        except BaseException as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=one) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    traces = tracer.traces()
    assert len(traces) == n_threads
    assert len({t.trace_id for t in traces}) == n_threads
    for trace in traces:
        ids = {s.span_id for s in trace.spans} | {trace.root.span_id}
        for span in trace.spans:
            # purity: every span belongs to exactly this request ...
            assert span.trace_id == trace.trace_id
            # ... and parentage stays inside the trace (acyclic by ids)
            if span is not trace.root:
                assert span.parent_id in ids and span.parent_id != span.span_id
        assert set(trace.node_spans()) == {"src", "left", "right", "sink"}
        cp = extract_critical_path(trace)
        assert sum(cp.attribution.values()) == pytest.approx(cp.total_s, rel=1e-9)
        # under thread contention the walk must still explain most of the
        # request: generous bound, this is an isolation test not a timer
        assert cp.total_s == pytest.approx(trace.total_s, rel=0.35)


# ---------------------------------------------------------------------------
# recomposition decisions in the tracer event ring
# ---------------------------------------------------------------------------
def chain_spec(work_platform="pA"):
    return DagSpec(
        (
            DagStep("ingest", "edge"),
            DagStep("work", work_platform),
            DagStep("deliver", "edge"),
        ),
        (("ingest", "work"), ("work", "deliver")),
        "t",
    )


def test_controller_logs_decisions_to_tracer():
    hub = TelemetryHub(alpha=1.0)
    tracer = Tracer()
    fb = PlacementCosts(
        fetch_s=lambda name, p, deps: 0.0,
        compute_s=lambda name, p: {("work", "pA"): 0.1, ("work", "pB"): 0.2}.get(
            (name, p), 0.1
        ),
        transfer_s=lambda a, b, size: 0.0,
        payload_size=1000,
    )
    ctrl = RecompositionController(
        hub, fb, {"work": ["pA", "pB"]}, every_n=1, min_samples=1, tracer=tracer
    )
    assert ctrl.tick(chain_spec("pA")) is None  # optimal: no_change
    hub.record_compute("work", "pA", 5.0)  # degrade pA -> swap
    placement = ctrl.tick(chain_spec("pA"))
    assert placement["work"] == "pB"
    decisions = [a for _t, n, a in tracer.events if n == "recompose.decision"]
    assert [d["outcome"] for d in decisions] == ["no_change", "swap"]
    swap = decisions[-1]
    assert swap["trigger"] in ("boundary", "drift")
    assert swap["new_placement"]["work"] == "pB"
    assert swap["predicted_cost_s"] < swap["current_cost_s"]


def test_adaptive_deployment_records_cutover_events(traced_dag):
    dep, tracer = traced_dag
    # bias costs so the DP moves sink to pB on the first boundary
    fb = PlacementCosts(
        fetch_s=lambda name, p, deps: 0.0,
        compute_s=lambda name, p: 0.5 if (name, p) == ("sink", "pA") else 0.01,
        transfer_s=lambda a, b, size: 0.0,
        payload_size=1000,
    )
    adapt = AdaptiveDeployment(
        dep, diamond_spec(), {"sink": ["pA", "pB"]}, fb, every_n=2, tracer=tracer
    )
    for _ in range(4):
        adapt.run(1)
    assert adapt.routes.version >= 1
    names = [n for _t, n, _a in tracer.events]
    assert "recompose.decision" in names and "recompose.cutover" in names
    cut = [a for _t, n, a in tracer.events if n == "recompose.cutover"][0]
    assert cut["moved"]["sink"] == ("pA", "pB")
    # request traces kept flowing through the instrumented deployment
    assert len(tracer.traces()) >= 4


def test_instrument_wires_components():
    dep = DagDeployment(make_registry())
    tracer = instrument(dep)
    assert dep.tracer is tracer
    assert dep.cache.tracer is tracer
    assert dep.prefetcher.tracer is tracer
    assert dep.store.tracer is tracer
    dep.shutdown()
