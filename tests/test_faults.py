"""Fault injection across the sim/real split: draw-neutrality pins,
cross-backend pricing agreement, retry/backoff closed forms, telemetry
error accounting, outage-aware costs, and the controller's
fail-over/fail-back state machine."""

import math

import numpy as np
import pytest

from repro.adapt import RecompositionController, TelemetryHub, observed_costs
from repro.core import simulator as S
from repro.core.faults import (
    FaultEvent,
    FaultSchedule,
    OutageEvent,
    RetryPolicy,
    availability,
    hash_u01,
)
from repro.core.shipping import PlacementCosts
from repro.obs import Tracer

BACKENDS = ("scalar", "numpy", "jax")


def _fallback_costs(compute=None):
    compute = compute or {}
    return PlacementCosts(
        fetch_s=lambda name, p, deps: 0.25 * len(deps),
        compute_s=lambda name, p: compute.get((name, p), 0.1),
        transfer_s=lambda a, b, size: 0.0 if a == b else 0.5,
        payload_size=1.5e6,
    )


def _schedule():
    return FaultSchedule(
        [
            FaultEvent("gcf", p_error=0.3, from_request=5, to_request=30),
            OutageEvent(from_request=10, to_request=20, platform="lambda-us-east-1"),
        ],
        seed=7,
    )


# ---------------------------------------------------------------------------
# draw-neutrality: disabled faults are bit-for-bit the old behavior
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_schedule_is_draw_neutral(backend):
    steps = S.document_workflow_fig4()
    base = S.WorkflowSimulator(S.paper_platforms(), seed=3).simulate(
        S.ExperimentSpec(steps, n_requests=48), backend=backend
    )
    neutral = S.WorkflowSimulator(S.paper_platforms(), seed=3).simulate(
        S.ExperimentSpec(
            steps, n_requests=48, faults=FaultSchedule(()), retry=None
        ),
        backend=backend,
    )
    assert np.array_equal(np.asarray(base), np.asarray(neutral))


@pytest.mark.parametrize("backend", BACKENDS)
def test_active_schedule_leaves_finite_pricing_untouched(backend):
    """Failed requests are priced as-if-completed and masked to inf AFTER
    the recurrence — so every finite total is bit-identical to the
    fault-free run (the fault plane with retry=None adds zero seconds)."""
    steps = S.document_workflow_fig4()
    base = np.asarray(
        S.WorkflowSimulator(S.paper_platforms(), seed=3).simulate(
            S.ExperimentSpec(steps, n_requests=48), backend=backend
        )
    )
    faulted = np.asarray(
        S.WorkflowSimulator(S.paper_platforms(), seed=3).simulate(
            S.ExperimentSpec(steps, n_requests=48, faults=_schedule(), retry=None),
            backend=backend,
        )
    )
    fin = np.isfinite(faulted)
    assert not fin.all()  # the outage window really failed someone
    assert np.array_equal(faulted[fin], base[fin])


def test_fault_masks_agree_across_backends():
    """Which requests die is a pure hash decision — every backend must
    agree exactly, and the hard-outage window must kill its whole span."""
    steps = S.document_workflow_fig4()
    rp = RetryPolicy(max_attempts=3, backoff_base_s=0.05)
    outs = {
        b: np.asarray(
            S.WorkflowSimulator(S.paper_platforms(), seed=3).simulate(
                S.ExperimentSpec(steps, n_requests=48, faults=_schedule(), retry=rp),
                backend=b,
            )
        )
        for b in BACKENDS
    }
    ref = np.isinf(outs["scalar"])
    for b in ("numpy", "jax"):
        assert np.array_equal(ref, np.isinf(outs[b])), b
    assert ref[10:20].all()  # outage window: retries cannot save these
    assert not ref[:5].any()  # before any event fires


def test_fault_pricing_agrees_across_backends_when_deterministic():
    """With every spread zeroed the backends run identical arithmetic, so
    fault-extended latencies (retry backoff included) must agree to float
    tolerance — the shared host-side plane is the single pricing source."""
    steps = [
        S.SimStep(
            s.name,
            s.platform,
            compute=S.Dist(s.compute.median, 0.0),
            fetch=S.Dist(s.fetch.median, 0.0),
            prefetch=s.prefetch,
        )
        for s in S.document_workflow_fig4()
    ]
    plats = [
        S.SimPlatform(
            p.name,
            p.region,
            p.native_prefetch,
            p.allows_sync,
            S.Dist(p.cold_start.median, 0.0),
            p.keep_warm_s,
        )
        for p in S.paper_platforms()
    ]
    rp = RetryPolicy(max_attempts=3, backoff_base_s=0.05)
    outs = {
        b: np.asarray(
            S.WorkflowSimulator(plats, seed=3).simulate(
                S.ExperimentSpec(steps, n_requests=48, faults=_schedule(), retry=rp),
                backend=b,
            )
        )
        for b in BACKENDS
    }
    ref = np.isinf(outs["scalar"])
    fin = ~ref
    assert fin.any() and ref.any()
    for b in ("numpy", "jax"):
        assert np.array_equal(ref, np.isinf(outs[b])), b
        np.testing.assert_allclose(outs[b][fin], outs["scalar"][fin], rtol=1e-9)


def test_retry_extends_latency_by_the_seeded_backoff():
    """A request inside the outage window fails attempt after attempt;
    each non-final failure adds exactly RetryPolicy.backoff_s to the
    node's end time. Closed-form check against the plane."""
    fs = FaultSchedule([OutageEvent(0, 10, platform="p")], seed=3)
    rp = RetryPolicy(max_attempts=4, backoff_base_s=0.1, backoff_multiplier=2.0)
    plane = fs.plane("f", "p", np.arange(12), retry=rp)
    want = sum(rp.backoff_s(a, "f", "p", 4) for a in range(3))
    assert plane.extra_s[4] == pytest.approx(want)
    assert plane.n_failures[4] == 4 and bool(plane.failed[4])
    # outside the window: clean
    assert plane.extra_s[11] == 0.0 and not plane.failed[11]


def test_transient_retry_can_succeed_mid_streak():
    """With p<1 and a budget, some requests fail attempt 0 but succeed on
    a retry: n_failures>0, failed=False, extra_s>0."""
    fs = FaultSchedule([FaultEvent("p", p_error=0.5)], seed=11)
    rp = RetryPolicy(max_attempts=4, backoff_base_s=0.01)
    plane = fs.plane("f", "p", np.arange(400), retry=rp)
    saved = (plane.n_failures > 0) & ~plane.failed
    assert saved.any()
    assert (plane.extra_s[saved] > 0).all()
    # and the budget still loses sometimes at p=0.5^4
    assert plane.failed.mean() == pytest.approx(0.5**4, abs=0.05)


def test_outage_region_scoped_and_open_ended():
    fs = FaultSchedule([OutageEvent(3, None, region="eu")], seed=0)
    ks = np.arange(8)
    assert not fs.outage_arrays(ks, "p", region="us").any()
    eu = fs.outage_arrays(ks, "p", region="eu")
    assert not eu[:3].any() and eu[3:].all()


def test_hash_is_stable_and_attempt_outcome_matches_plane():
    """The engine's single-request check and the simulator's vector plane
    evaluate the same hash: a request the plane says failed attempt 0 must
    make attempt_outcome return non-None, and vice versa."""
    fs = FaultSchedule([FaultEvent("p", p_error=0.4)], seed=5)
    ks = np.arange(64)
    plane = fs.plane("f", "p", ks, retry=None)
    for k in range(64):
        kind = fs.attempt_outcome("f", "p", k, 0)
        assert (kind is not None) == bool(plane.n_failures[k]), k
    # determinism pin for the counter hash itself
    u = hash_u01(5, 123, 0, 0x51AB, np.arange(4))
    assert np.array_equal(u, hash_u01(5, 123, 0, 0x51AB, np.arange(4)))
    assert ((0.0 <= u) & (u < 1.0)).all()


def test_availability_helper():
    assert availability(np.array([1.0, math.inf, 2.0, math.inf])) == 0.5
    assert availability(np.array([])) == 1.0


# ---------------------------------------------------------------------------
# telemetry error accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ("scalar", "numpy"))
def test_simulated_faults_feed_error_telemetry(backend):
    hub = TelemetryHub()
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=3, telemetry=hub)
    sim.simulate(
        S.ExperimentSpec(
            S.document_workflow_fig4(),
            n_requests=48,
            faults=_schedule(),
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.01),
        ),
        backend=backend,
    )
    snap = hub.snapshot()
    # the outage window (requests 10..20, 3 attempts each) left counts on
    # the lambda cells; the rate EWMA has decayed through the healthy tail
    # but must still be present and positive
    dead = [c for c in snap["errors"] if "lambda-us-east-1" in c]
    assert dead and all(snap["errors"][c] >= 10 for c in dead)
    assert all(snap["error_rate"][c] > 0 for c in dead)


def test_telemetry_is_unchanged_when_faults_off():
    def run(faults, retry):
        hub = TelemetryHub()
        sim = S.WorkflowSimulator(S.paper_platforms(), seed=3, telemetry=hub)
        sim.simulate(
            S.ExperimentSpec(
                S.document_workflow_fig4(), n_requests=24, faults=faults, retry=retry
            ),
            backend="numpy",
        )
        return hub.snapshot()

    a = run(None, None)
    b = run(FaultSchedule(()), None)
    assert a == b


def test_hub_error_rate_and_penalty_shape():
    hub = TelemetryHub(alpha=0.5)
    assert hub.error_rate("f", "p") is None
    assert hub.error_penalty_s("f", "p") is None  # no attempts at all
    hub.record_compute("f", "p", 2.0)  # one success
    assert hub.error_penalty_s("f", "p") == 0.0  # attempts seen, no errors
    hub.record_error("f", "p")
    r = hub.error_rate("f", "p")
    assert 0.0 < r < 1.0
    # expected extra attempts r/(1-r), each paying the compute EWMA
    assert hub.error_penalty_s("f", "p") == pytest.approx(r / (1 - r) * 2.0)
    assert hub.error_count("f", "p") == 1
    assert hub.error_counts() == {("f", "p"): 1}
    hub.reset_errors("f", "p")
    assert hub.error_rate("f", "p") is None  # history forgotten
    assert hub.error_count("f", "p") == 1  # audit count kept


def test_observed_costs_outage_is_infinite_and_flaky_is_penalized():
    hub = TelemetryHub(alpha=1.0)
    for _ in range(3):
        hub.record_compute("f", "p", 1.0)
    hub.record_error("f", "p", 1)
    costs = observed_costs(hub, _fallback_costs(), outages={("f", "q")})
    assert costs.compute_s("f", "q") == math.inf
    # flaky-but-alive: base EWMA (1.0) + error penalty > clean cell
    assert costs.compute_s("f", "p") > 1.0
    clean = observed_costs(hub, _fallback_costs(), errors=False)
    assert clean.compute_s("f", "p") == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# controller: outage trigger, fail-over, fail-back
# ---------------------------------------------------------------------------
def _controller(hub, tracer=None, **kw):
    from repro.dag import DagSpec, DagStep  # local: spec-only, no engine

    spec = DagSpec(
        steps=(DagStep("f", "p"), DagStep("g", "r")), edges=(("f", "g"),)
    )
    ctl = RecompositionController(
        hub,
        # home platform p is strictly cheaper than the failover q — the
        # asymmetry that makes fail-back observable (p was placed for a
        # reason; a tie would leave the DP parked on q)
        _fallback_costs({("f", "q"): 0.2}),
        {"f": ["p", "q"]},
        every_n=10**9,  # boundary never fires: outage logic only
        tracer=tracer,
        **kw,
    )
    return ctl, spec


def test_controller_outage_failover_and_failback():
    hub = TelemetryHub(alpha=1.0)
    tracer = Tracer()
    ctl, spec = _controller(hub, tracer, outage_threshold=0.5, outage_ttl=3)
    # healthy ticks: nothing happens
    hub.record_compute("f", "p", 0.1)
    assert ctl.tick(spec) is None
    # platform p dies: errors flood in
    for _ in range(4):
        hub.record_error("f", "p")
    placement = ctl.tick(spec)
    assert placement is not None and placement["f"] == "q"
    assert ctl.stats["outage_triggers"] == 1
    assert ctl.last_trigger == "outage"
    assert ("f", "p") in ctl.outages()
    names = [e[1] for e in tracer.events]
    assert "outage.detected" in names
    decisions = [e for e in tracer.events if e[1] == "recompose.decision"]
    assert decisions and decisions[-1][2]["trigger"] == "outage"
    # swap applied: the active spec moved to q
    spec2 = spec.apply_placement(placement)
    # ttl ticks with no fresh errors -> mark expires, fail-back probe
    got = None
    for _ in range(5):
        got = ctl.tick(spec2)
        if got is not None:
            break
    assert got is not None and got["f"] == "p"  # failed back (p is cheap)
    assert ("f", "p") not in ctl.outages()
    assert hub.error_rate("f", "p") is None  # optimistic reset
    assert "outage.cleared" in [e[1] for e in tracer.events]


def test_controller_still_dead_platform_remarks_after_probe():
    hub = TelemetryHub(alpha=1.0)
    ctl, spec = _controller(hub, outage_threshold=0.5, outage_ttl=2)
    for _ in range(4):
        hub.record_error("f", "p")
    placement = ctl.tick(spec)
    assert placement["f"] == "q"
    spec2 = spec.apply_placement(placement)
    for _ in range(4):  # expire the mark (fail-back probe fires)
        if ctl.tick(spec2) is not None:
            break
    # the probe routed back onto p, which is still dead: fresh errors
    for _ in range(4):
        hub.record_error("f", "p")
    placement = ctl.tick(spec)
    assert placement is not None and placement["f"] == "q"
    assert ctl.stats["outage_triggers"] >= 2


def test_trigger_precedence_slo_beats_outage():
    class FakeSlo:
        alerts = 1

        class spec:
            name = "p99"

    hub = TelemetryHub(alpha=1.0)
    tracer = Tracer()
    ctl, spec = _controller(hub, tracer, outage_threshold=0.5, outage_ttl=3)
    ctl.slo = FakeSlo()
    for _ in range(4):
        hub.record_error("f", "p")
    ctl.tick(spec)
    decisions = [e for e in tracer.events if e[1] == "recompose.decision"]
    assert decisions[-1][2]["trigger"] == "slo"  # slo > outage
    assert ctl.stats["slo_triggers"] == 1 and ctl.stats["outage_triggers"] == 0
