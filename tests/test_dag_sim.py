"""DAG discrete-event recurrence: chain degeneration, fan-out overlap,
protocol properties, and the chain-vs-DAG acceptance medians."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import simulator as S
from repro.dag.sim import DagWorkflowSimulator, document_dag_fig4, serialize_chain


def chain_edges(steps):
    return [(steps[i].name, steps[i + 1].name) for i in range(len(steps) - 1)]


def flat_platform():
    return S.SimPlatform(
        "p", "r", native_prefetch=True, allows_sync=True, cold_start=S.Dist(0.0)
    )


def test_degenerate_chain_matches_linear_recurrence():
    """A from_chain-shaped DAG reproduces the chain simulator draw for
    draw (same rng stream, same recurrence)."""
    steps = S.document_workflow_fig4()
    for prefetch in (True, False):
        dag_sim = DagWorkflowSimulator(S.paper_platforms(), seed=11)
        lin_sim = S.WorkflowSimulator(S.paper_platforms(), seed=11)
        tr_dag = dag_sim.run_dag_request(steps, chain_edges(steps), 0.0, prefetch)
        tr_lin = lin_sim.run_request(steps, 0.0, prefetch)
        assert tr_dag.total_s == pytest.approx(tr_lin.total_s, abs=1e-12)
        for i, s in enumerate(steps):
            assert tr_dag.end[s.name] == pytest.approx(tr_lin.end[i])
        assert tr_dag.double_billed_s == pytest.approx(tr_lin.double_billed_s)


def test_fan_out_branches_overlap():
    """Deterministic diamond: total = head + max(branches) + join (plus
    transfers), NOT the chain's sum of branches."""

    def mk(name, c):
        return S.SimStep(name, "p", compute=S.Dist(c, 0.0))

    steps = [mk("head", 0.1), mk("left", 1.0), mk("right", 2.0), mk("join", 0.1)]
    edges = [("head", "left"), ("head", "right"), ("left", "join"), ("right", "join")]
    sim = DagWorkflowSimulator([flat_platform()], msg_latency_s=0.0, seed=0)
    tr = sim.run_dag_request(steps, edges, 0.0, prefetch=True)
    assert tr.total_s == pytest.approx(0.1 + 2.0 + 0.1, abs=1e-6)
    # the join waited for the SLOWER branch
    assert tr.payload["join"] == pytest.approx(tr.end["right"], abs=1e-9)


def test_join_payload_is_max_over_predecessors():
    steps, edges = document_dag_fig4()
    sim = DagWorkflowSimulator(S.paper_platforms(), seed=5)
    tr = sim.run_dag_request(steps, edges, 0.0, prefetch=True)
    pl = sim.platforms
    by = {s.name: s for s in steps}
    expected = max(
        tr.end[u] + sim._transfer_s(pl[by[u].platform], pl[by["e_mail"].platform])
        for u in ("virus", "ocr")
    )
    assert tr.payload["e_mail"] == pytest.approx(expected)


def test_acceptance_dag_prefetch_beats_chain_serialization():
    """Acceptance: calibrated diamond, prefetch-on DAG median below the
    chain serialization of the same steps (and below DAG baseline)."""
    steps, edges = document_dag_fig4()
    chain = serialize_chain(steps, edges)
    assert [s.name for s in chain] == ["check", "virus", "ocr", "e_mail"]

    def fresh():
        return DagWorkflowSimulator(S.paper_platforms(), seed=42)

    dag_pf = S.median(fresh().run_dag_experiment(steps, edges, 400, prefetch=True))
    dag_base = S.median(fresh().run_dag_experiment(steps, edges, 400, prefetch=False))
    chain_pf = S.median(fresh().run_experiment(chain, 400, prefetch=True))
    chain_base = S.median(fresh().run_experiment(chain, 400, prefetch=False))
    assert dag_pf < chain_pf, (dag_pf, chain_pf)
    assert dag_base < chain_base, (dag_base, chain_base)
    assert dag_pf < dag_base, (dag_pf, dag_base)


compute_st = st.floats(0.05, 3.0)
fetch_st = st.floats(0.0, 3.0)


def fan_out_fan_in(steps_raw):
    """s0 fans out to every middle step; all middle steps join at the last."""
    plats = S.paper_platforms()
    steps = [
        S.SimStep(
            f"s{i}",
            plats[i % len(plats)].name,
            compute=S.Dist(c, 0.0),
            fetch=S.Dist(f, 0.0),
        )
        for i, (c, f) in enumerate(steps_raw)
    ]
    last = steps[-1].name
    edges = [("s0", s.name) for s in steps[1:-1]]
    edges += [(s.name, last) for s in steps[1:-1]]
    return plats, steps, edges


@given(
    st.lists(st.tuples(compute_st, fetch_st), min_size=3, max_size=6),
    st.integers(0, 3),
)
@settings(max_examples=30, deadline=None)
def test_dag_prefetch_never_slower(steps_raw, seed):
    """Protocol property, DAG edition: with identical sampled durations the
    dataflow schedule with pre-fetching is never slower than without."""
    plats, steps, edges = fan_out_fan_in(steps_raw)
    sim = DagWorkflowSimulator(plats, seed=seed)
    base = sim.run_dag_request(steps, edges, 1e6, prefetch=False).total_s
    sim = DagWorkflowSimulator(plats, seed=seed)
    geo = sim.run_dag_request(steps, edges, 1e6, prefetch=True).total_s
    assert geo <= base + 1e-9


@given(
    st.lists(st.tuples(compute_st, fetch_st), min_size=3, max_size=6),
    st.integers(0, 3),
)
@settings(max_examples=30, deadline=None)
def test_dag_never_slower_than_chain_serialization(steps_raw, seed):
    """With identical sampled durations, the dataflow schedule is never
    slower than the serialized chain of the same steps."""
    plats, steps, edges = fan_out_fan_in(steps_raw)
    for prefetch in (True, False):
        dag_sim = DagWorkflowSimulator(plats, seed=seed)
        dag = dag_sim.run_dag_request(steps, edges, 1e6, prefetch).total_s
        lin_sim = S.WorkflowSimulator(plats, seed=seed)
        lin = lin_sim.run_request(serialize_chain(steps, edges), 1e6, prefetch).total_s
        assert dag <= lin + 1e-9


def test_cycle_rejected():
    steps = [
        S.SimStep("a", "tinyfaas-edge", compute=S.Dist(0.1)),
        S.SimStep("b", "tinyfaas-edge", compute=S.Dist(0.1)),
    ]
    sim = DagWorkflowSimulator(S.paper_platforms(), seed=0)
    with pytest.raises(ValueError, match="cycle"):
        sim.run_dag_request(steps, [("a", "b"), ("b", "a")], 0.0, True)


def test_unpoked_node_pays_cold_path():
    """prefetch=False on a node: its branch pays cold+fetch serially even
    when the rest of the DAG is poked."""
    steps = [
        S.SimStep("a", "p", compute=S.Dist(1.0, 0.0)),
        S.SimStep(
            "b", "p", compute=S.Dist(0.1, 0.0), fetch=S.Dist(0.5, 0.0), prefetch=False
        ),
    ]
    sim = DagWorkflowSimulator([flat_platform()], msg_latency_s=0.0, seed=0)
    tr = sim.run_dag_request(steps, [("a", "b")], 0.0, prefetch=True)
    # b's 0.5 fetch was NOT hidden behind a's 1.0 compute
    assert tr.total_s == pytest.approx(1.0 + 0.5 + 0.1, abs=1e-6)
