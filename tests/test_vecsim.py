"""The batched simulation fast path: draw-order contract (frozen
reference), statistical equivalence with the scalar loop, exact agreement
wherever randomness cancels (sigma-0 distributions, n=1), drift-mask
boundaries, the cold-start scan, and the seed-sweep helper."""

import math

import numpy as np
import pytest

from repro.core import simulator as S
from repro.dag import document_dag_fig4

SEEDS = (0, 1, 2)


def _deterministic(steps):
    """The same workflow with every spread zeroed: identical arithmetic on
    both paths, so traces must agree bit-for-bit, not statistically."""
    return [
        S.SimStep(
            s.name,
            s.platform,
            compute=S.Dist(s.compute.median, 0.0),
            fetch=S.Dist(s.fetch.median, 0.0),
            prefetch=s.prefetch,
        )
        for s in steps
    ]


def _deterministic_platforms():
    return [
        S.SimPlatform(
            p.name,
            p.region,
            p.native_prefetch,
            p.allows_sync,
            S.Dist(p.cold_start.median, 0.0),
            p.keep_warm_s,
        )
        for p in S.paper_platforms()
    ]


# ---------------------------------------------------------------------------
# frozen reference: the vectorized draw-order contract
# ---------------------------------------------------------------------------
# Per node in topo order: n cold draws, then n fetch draws, then n compute
# draws. Regenerating these numbers requires an intentional, documented
# change to that contract (or to the recurrence itself).
FROZEN_CHAIN_PREFETCH = [
    3.971754709658,
    2.446005330083,
    2.131840393647,
    2.144428912572,
    2.398269350945,
    2.458603852856,
]
FROZEN_CHAIN_BASELINE = [
    8.708875333184,
    4.716278510589,
    4.553191096882,
    4.346689202346,
    4.830691891129,
    4.860633883353,
]
FROZEN_DAG_PREFETCH = [
    4.126205311078,
    2.155648526707,
    2.156533624912,
    2.114771100992,
    2.451390063664,
]


def test_frozen_reference_chain():
    for prefetch, want in [
        (True, FROZEN_CHAIN_PREFETCH),
        (False, FROZEN_CHAIN_BASELINE),
    ]:
        sim = S.WorkflowSimulator(S.paper_platforms(), seed=3)
        out = sim.run_experiment(
            S.document_workflow_fig4(), 6, prefetch=prefetch, backend="numpy"
        )
        assert out.tolist() == pytest.approx(want, abs=1e-9)


def test_frozen_reference_dag():
    steps, edges = document_dag_fig4()
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=7)
    out = sim.run_dag_experiment(steps, edges, 5, prefetch=True, backend="numpy")
    assert out.tolist() == pytest.approx(FROZEN_DAG_PREFETCH, abs=1e-9)


def test_frozen_reference_unchanged_by_single_chunk_stream():
    """StreamConfig(chunks=1) is whole-object semantics: attaching it must
    reproduce the frozen draws bit-for-bit (same rng stream, same floats),
    on both the chain and the DAG."""
    sim = S.WorkflowSimulator(
        S.paper_platforms(), seed=3, stream=S.StreamConfig(chunks=1)
    )
    out = sim.run_experiment(
        S.document_workflow_fig4(), 6, prefetch=True, backend="numpy"
    )
    base = S.WorkflowSimulator(S.paper_platforms(), seed=3).run_experiment(
        S.document_workflow_fig4(), 6, prefetch=True, backend="numpy"
    )
    assert np.array_equal(out, base)
    assert out.tolist() == pytest.approx(FROZEN_CHAIN_PREFETCH, abs=1e-9)

    steps, edges = document_dag_fig4()
    sim = S.WorkflowSimulator(
        S.paper_platforms(), seed=7, stream=S.StreamConfig(chunks=1)
    )
    out = sim.run_dag_experiment(steps, edges, 5, prefetch=True, backend="numpy")
    assert out.tolist() == pytest.approx(FROZEN_DAG_PREFETCH, abs=1e-9)


# ---------------------------------------------------------------------------
# statistical equivalence with the scalar path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "name,make_steps,edges",
    [
        ("fig4", S.document_workflow_fig4, None),
        ("fig6_far", lambda: S.shipping_workflow_fig6("lambda-eu-central-1"), None),
        ("fig6_close", lambda: S.shipping_workflow_fig6("lambda-us-east-1"), None),
        ("fig8", S.native_prefetch_workflow_fig8, None),
        ("diamond", lambda: document_dag_fig4()[0], document_dag_fig4()[1]),
    ],
)
def test_median_and_p99_agree_within_1pct(name, make_steps, edges):
    """Different draw order, same distributions: pooled (3 fixed seeds x
    1800 requests) medians and p99s within 1%. Seeds are pinned, so this
    is a deterministic regression bound, not a flaky statistical one."""

    def pooled(backend):
        chunks = []
        for seed in SEEDS:
            sim = S.WorkflowSimulator(S.paper_platforms(), seed=seed)
            if edges is None:
                chunks.append(
                    sim.run_experiment(
                        make_steps(), 1800, prefetch=True, backend=backend
                    )
                )
            else:
                chunks.append(
                    sim.run_dag_experiment(
                        make_steps(), edges, 1800, prefetch=True, backend=backend
                    )
                )
        return np.concatenate(chunks)

    sc, ve = pooled("scalar"), pooled("numpy")
    assert np.median(ve) == pytest.approx(np.median(sc), rel=0.01)
    assert np.percentile(ve, 99) == pytest.approx(np.percentile(sc, 99), rel=0.01)


def test_single_request_is_bitwise_scalar():
    """With n=1 the two draw orders coincide (per node: one cold, one
    fetch, one compute draw), so the paths must agree exactly. Holds
    because request 0 is cold on every node here (finite keep_warm_s) —
    a never-cold platform consumes no cold draw on the scalar path."""
    a = S.WorkflowSimulator(S.paper_platforms(), seed=5).run_experiment(
        S.document_workflow_fig4(), 1, backend="numpy"
    )
    b = S.WorkflowSimulator(S.paper_platforms(), seed=5).run_experiment(
        S.document_workflow_fig4(), 1
    )
    assert np.array_equal(a, b)


def test_zero_requests():
    out = S.WorkflowSimulator(S.paper_platforms(), seed=0).run_experiment(
        S.document_workflow_fig4(), 0, backend="numpy"
    )
    assert out.shape == (0,)


# ---------------------------------------------------------------------------
# exact agreement when randomness cancels (sigma-0 distributions)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefetch", [True, False])
def test_sigma0_chain_matches_scalar_exactly(prefetch):
    plats = _deterministic_platforms()
    steps = _deterministic(S.document_workflow_fig4())
    sc = S.WorkflowSimulator(plats, seed=0).run_experiment(steps, 40, prefetch=prefetch)
    ve = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 40, prefetch=prefetch, backend="numpy"
    )
    assert np.allclose(sc, ve, atol=1e-12)


@pytest.mark.parametrize("prefetch", [True, False])
def test_sigma0_diamond_matches_scalar_exactly(prefetch):
    raw, edges = document_dag_fig4()
    steps = _deterministic(raw)
    plats = _deterministic_platforms()
    sc = S.WorkflowSimulator(plats, seed=0).run_dag_experiment(
        steps, edges, 30, prefetch=prefetch
    )
    ve = S.WorkflowSimulator(plats, seed=0).run_dag_experiment(
        steps, edges, 30, prefetch=prefetch, backend="numpy"
    )
    assert np.allclose(sc, ve, atol=1e-12)


# ---------------------------------------------------------------------------
# drift masks at event boundaries
# ---------------------------------------------------------------------------
def _drift_setup():
    plats = [
        S.SimPlatform("p", "r1", cold_start=S.Dist(0.5, 0.0)),
        S.SimPlatform("q", "r2", cold_start=S.Dist(0.7, 0.0)),
    ]
    steps = [
        S.SimStep("a", "p", compute=S.Dist(0.3, 0.0), fetch=S.Dist(0.1, 0.0)),
        S.SimStep("b", "q", compute=S.Dist(0.4, 0.0), fetch=S.Dist(0.2, 0.0)),
    ]
    return plats, steps


def test_drift_boundary_request_k_minus_1_vs_k():
    """The event at request k scales requests k.. and leaves ..k-1 alone —
    checked against the scalar path exactly (sigma 0) and against the
    undrifted stream at the boundary."""
    plats, steps = _drift_setup()

    def mk():  # a fresh schedule per simulator (each memoizes segments)
        return S.DriftSchedule(
            [S.DriftEvent(3, "q", compute_scale=2.0, transfer_scale=1.5)]
        )

    sc = S.WorkflowSimulator(plats, seed=0, drift=mk()).run_experiment(
        steps, 8, prefetch=True
    )
    ve = S.WorkflowSimulator(plats, seed=0, drift=mk()).run_experiment(
        steps, 8, prefetch=True, backend="numpy"
    )
    plain = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 8, prefetch=True, backend="numpy"
    )
    assert np.allclose(sc, ve, atol=1e-12)
    assert ve[2] == pytest.approx(plain[2], abs=1e-12)  # k-1: untouched
    assert ve[3] > plain[3]  # k: scaled


def test_drift_scale_arrays_match_scalar_scales():
    drift = S.DriftSchedule(
        [
            S.DriftEvent(2, "p", compute_scale=3.0),
            S.DriftEvent(5, "p", compute_scale=2.0, fetch_scale=4.0),
            S.DriftEvent(4, "q", transfer_scale=7.0),
        ]
    )
    ks = np.arange(8)
    for platform in ("p", "q", "unknown"):
        c, t, f = drift.scale_arrays(ks, platform)
        for k in ks:
            assert (c[k], t[k], f[k]) == drift.scales(int(k), platform)


def test_drift_scales_memoization_is_transparent():
    """The segment cache must never change what ``scales`` returns."""
    drift = S.DriftSchedule([S.DriftEvent(5, "p", compute_scale=2.0)])
    assert drift.scales(4, "p") == (1.0, 1.0, 1.0)
    assert drift.scales(5, "p") == (2.0, 1.0, 1.0)
    assert drift.scales(9, "p") == (2.0, 1.0, 1.0)  # cached segment
    assert drift.scales(4, "p") == (1.0, 1.0, 1.0)  # earlier segment again
    assert drift.scales(5, "other") == (1.0, 1.0, 1.0)


# ---------------------------------------------------------------------------
# the cold-start scan
# ---------------------------------------------------------------------------
def test_cold_scan_alternating_cold_warm_regime():
    """interarrival > keep_warm only when the previous request was warm:
    the cold mask must alternate, exactly as the scalar recurrence does
    (this is the case where request k's coldness depends on request k-1's
    coldness — the genuinely sequential recurrence)."""
    plats = [
        S.SimPlatform(
            "p",
            "r",
            native_prefetch=True,
            cold_start=S.Dist(0.5, 0.0),
            keep_warm_s=4.0,
        )
    ]
    steps = [S.SimStep("a", "p", compute=S.Dist(0.8, 0.0))]
    sc = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 20, interarrival_s=5.0, prefetch=True
    )
    ve = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 20, interarrival_s=5.0, prefetch=True, backend="numpy"
    )
    assert np.allclose(sc, ve, atol=1e-12)
    assert len(set(np.round(ve, 9))) == 2  # two levels: cold and warm


def test_cold_scan_every_request_cold():
    plats = [
        S.SimPlatform(
            "p",
            "r",
            native_prefetch=True,
            cold_start=S.Dist(0.5, 0.0),
            keep_warm_s=1.0,
        )
    ]
    steps = [S.SimStep("a", "p", compute=S.Dist(0.2, 0.0))]
    sc = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 10, interarrival_s=10.0, prefetch=True
    )
    ve = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 10, interarrival_s=10.0, prefetch=True, backend="numpy"
    )
    assert np.allclose(sc, ve, atol=1e-12)
    assert np.allclose(ve[1:], ve[1], atol=1e-12)  # steady cold level


def test_cold_scan_infinite_keep_warm_never_cold():
    plats = [
        S.SimPlatform(
            "p",
            "r",
            native_prefetch=True,
            cold_start=S.Dist(0.5, 0.0),
            keep_warm_s=math.inf,
        )
    ]
    steps = [S.SimStep("a", "p", compute=S.Dist(0.2, 0.0))]
    ve = S.WorkflowSimulator(plats, seed=0).run_experiment(
        steps, 4, prefetch=True, backend="numpy"
    )
    sc = S.WorkflowSimulator(plats, seed=0).run_experiment(steps, 4, prefetch=True)
    assert np.allclose(sc, ve, atol=1e-12)


# ---------------------------------------------------------------------------
# guard rails + the sweep helper
# ---------------------------------------------------------------------------
def test_vectorized_rejects_timing_controller():
    from repro.core.timing import PokeTimingController

    sim = S.WorkflowSimulator(
        S.paper_platforms(), seed=0, timing=PokeTimingController()
    )
    with pytest.raises(ValueError, match="timing"):
        sim.run_experiment(S.document_workflow_fig4(), 4, backend="numpy")


def test_vectorized_rejects_duplicate_name_platform_nodes():
    steps = [
        S.SimStep("f", "gcf", compute=S.Dist(0.1)),
        S.SimStep("f", "gcf", compute=S.Dist(0.1)),
    ]
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    with pytest.raises(ValueError, match="unique"):
        sim.run_experiment(steps, 4, backend="numpy")
    sim.run_experiment(steps, 4)  # the scalar path still serves these


def test_run_experiment_many_shapes_and_rng_isolation():
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0)
    before = sim.rng.bit_generator.state
    m = sim.run_experiment_many(
        S.document_workflow_fig4(), seeds=[0, 1, 2], n_requests=64
    )
    assert m.shape == (3, 64)
    assert sim.rng.bit_generator.state == before  # own rng untouched
    # per-seed rows are reproducible one-off experiments
    solo = S.WorkflowSimulator(S.paper_platforms(), seed=1).run_experiment(
        S.document_workflow_fig4(), 64, backend="numpy"
    )
    assert np.array_equal(m[1], solo)
    # DAG sweep
    steps, edges = document_dag_fig4()
    md = sim.run_experiment_many(steps, seeds=[3, 4], n_requests=16, edges=edges)
    assert md.shape == (2, 16)


def test_vectorized_telemetry_reports_aggregates():
    from repro.adapt import TelemetryHub

    hub = TelemetryHub()
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0, telemetry=hub)
    totals = sim.run_experiment(
        S.document_workflow_fig4(), 200, prefetch=True, backend="numpy"
    )
    snap = hub.snapshot()
    assert snap["cold_starts"]["ocr@lambda-us-east-1"] == 1  # request 0 only
    assert snap["warm_hits"]["ocr@lambda-us-east-1"] == 199
    assert snap["cold_s"]["ocr@lambda-us-east-1"] > 0
    assert snap["compute_s"]["ocr@lambda-us-east-1"] == pytest.approx(0.45, rel=0.25)
    assert "ocr@us-east-1" in snap["fetch_s"]
    assert "europe-west10->us-east-1" in snap["transfer_s"]
    # and the tap is draw-neutral: same totals without the hub
    plain = S.WorkflowSimulator(S.paper_platforms(), seed=0).run_experiment(
        S.document_workflow_fig4(), 200, prefetch=True, backend="numpy"
    )
    assert np.array_equal(totals, plain)


def test_vectorized_with_drift_and_telemetry_sees_drifted_compute():
    from repro.adapt import TelemetryHub

    hub = TelemetryHub(alpha=1.0)
    drift = S.DriftSchedule([S.DriftEvent(0, "gcf", compute_scale=10.0)])
    sim = S.WorkflowSimulator(S.paper_platforms(), seed=0, telemetry=hub, drift=drift)
    sim.run_experiment(S.document_workflow_fig4(), 100, backend="numpy")
    assert hub.compute_s("virus", "gcf") == pytest.approx(3.0, rel=0.2)  # 10 x 0.30
