"""The dataflow DAG engine: fan-out parallelism, fan-in joins, poke
cascades along edges, payload-buffer hygiene, chain interop."""

import time

import numpy as np
import pytest

from repro.core import (
    DataRef,
    Deployment,
    Platform,
    PlatformRegistry,
    StepSpec,
    WorkflowSpec,
)
from repro.dag import DagDeployment, DagSpec, DagStep


def make_registry():
    reg = PlatformRegistry()
    reg.register(Platform("edge-eu", "eu", kind="edge", native_prefetch=True))
    reg.register(Platform("cloud-us", "us", kind="cloud"))
    return reg


def make_dep(enforce=True):
    dep = DagDeployment(make_registry())
    dep.store.enforce_latency = enforce
    dep.store.network.set_link("eu", "us", 0.04, 8e6)
    return dep


def sleep_handler(duration, factor=1):
    def h(payload, data):
        time.sleep(duration)
        return payload * factor

    return h


def deploy_diamond(dep, branch_s=0.15):
    dep.deploy("head", sleep_handler(0.02), ["edge-eu"])
    dep.deploy("left", sleep_handler(branch_s, 2), ["cloud-us"])
    dep.deploy("right", sleep_handler(branch_s, 3), ["cloud-us"])
    dep.deploy("join", lambda p, d: (p["left"], p["right"]), ["cloud-us"])
    return DagSpec(
        (
            DagStep("head", "edge-eu"),
            DagStep("left", "cloud-us"),
            DagStep("right", "cloud-us"),
            DagStep("join", "cloud-us"),
        ),
        (
            ("head", "left"),
            ("head", "right"),
            ("left", "join"),
            ("right", "join"),
        ),
        "diamond",
    )


def test_diamond_executes_with_fan_in_join():
    dep = make_dep(enforce=False)
    spec = deploy_diamond(dep)
    r = dep.run(spec, 1)
    assert r.outputs == (2, 3)
    assert set(r.timeline) == {"head", "left", "right", "join"}
    assert dep.stats["joins"] == 1
    dep.shutdown()


def test_pokes_cascade_along_both_branches():
    """One run pokes left, right AND the join — each exactly once (the
    diamond's join is reachable via two paths but deduplicated)."""
    dep = make_dep(enforce=False)
    spec = deploy_diamond(dep)
    dep.run(spec, 1)
    assert dep.stats["pokes"] == {"left": 1, "right": 1, "join": 1}
    dep.shutdown()


def test_branches_run_in_parallel():
    """Two 0.15 s branches finish in ~max, not ~sum: the DAG end-to-end
    stays well under the chain serialization of the same handlers."""
    dep = make_dep(enforce=False)
    spec = deploy_diamond(dep, branch_s=0.15)
    dep.run(spec, 1)  # warm pools
    t_dag = min(dep.run(spec, 1).total_s for _ in range(3))
    dep.shutdown()
    assert t_dag < 0.15 * 2, t_dag  # sum would be >= 0.3


def test_dag_beats_chain_serialization_real_engine():
    """Acceptance: prefetch-on DAG median < chain serialization median of
    the SAME steps on the real middlewares (enforced latencies)."""
    deps = (DataRef("ref", "eu"),)

    def seed(dep):
        dep.store.put("ref", np.ones(int(4e5 // 8)), region="eu")
        return dep

    dag = seed(make_dep())
    spec = deploy_diamond(dag, branch_s=0.12)
    spec = DagSpec(
        tuple(
            DagStep(s.name, s.platform, deps if s.name in ("left", "right") else ())
            for s in spec.steps
        ),
        spec.edges,
        spec.workflow_id,
    )
    dag.run(spec, 1)
    t_dag = float(np.median([dag.run(spec, 1).total_s for _ in range(3)]))
    dag.shutdown()

    chain = seed(Deployment(make_registry()))
    chain.store.enforce_latency = True
    chain.store.network.set_link("eu", "us", 0.04, 8e6)
    chain.deploy("head", sleep_handler(0.02), ["edge-eu"])
    chain.deploy("left", sleep_handler(0.12, 2), ["cloud-us"])
    chain.deploy("right", sleep_handler(0.12, 3), ["cloud-us"])
    chain.deploy("join", lambda p, d: p, ["cloud-us"])
    cspec = WorkflowSpec(
        (
            StepSpec("head", "edge-eu"),
            StepSpec("left", "cloud-us", data_deps=deps),
            StepSpec("right", "cloud-us", data_deps=deps),
            StepSpec("join", "cloud-us"),
        ),
        "diamond-chain",
    )
    chain.run(cspec, 1)
    t_chain = float(np.median([chain.run(cspec, 1).total_s for _ in range(3)]))
    chain.shutdown()
    assert t_dag < t_chain, (t_dag, t_chain)


def test_fan_in_payload_buffers_do_not_leak():
    """Satellite: every __payload__ store key is deleted after its GET —
    in the DAG engine AND the chain middleware."""
    dep = make_dep(enforce=False)
    spec = deploy_diamond(dep)
    for _ in range(3):
        dep.run(spec, 1)
    assert dep.stats["buffered_edges"] > 0  # the store path was taken
    assert dep.store.keys("__payload__") == []
    dep.shutdown()

    chain = Deployment(make_registry())
    chain.deploy("a", lambda p, d: p, ["edge-eu"])
    chain.deploy("b", lambda p, d: p, ["cloud-us"])
    wf = WorkflowSpec((StepSpec("a", "edge-eu"), StepSpec("b", "cloud-us")))
    for _ in range(3):
        chain.run(wf, 1)
    assert chain.store.stats["puts"] >= 3  # buffering did happen
    assert chain.store.keys("__payload__") == []
    chain.shutdown()


def test_results_identical_with_and_without_prefetch():
    dep = make_dep(enforce=False)
    rng = np.random.default_rng(0)
    dep.store.put("w", rng.normal(size=64), region="eu")

    def scale(p, d):
        return float(np.sum(d["w"])) * p

    dep.deploy("head", lambda p, d: p + 1, ["edge-eu"])
    dep.deploy("left", scale, ["cloud-us"])
    dep.deploy("right", lambda p, d: p * 10, ["cloud-us"])
    dep.deploy("join", lambda p, d: p["left"] + p["right"], ["cloud-us"])

    def spec(prefetch):
        return DagSpec(
            (
                DagStep("head", "edge-eu", prefetch=prefetch),
                DagStep(
                    "left",
                    "cloud-us",
                    data_deps=(DataRef("w", "eu"),),
                    prefetch=prefetch,
                ),
                DagStep("right", "cloud-us", prefetch=prefetch),
                DagStep("join", "cloud-us", prefetch=prefetch),
            ),
            (
                ("head", "left"),
                ("head", "right"),
                ("left", "join"),
                ("right", "join"),
            ),
        )

    r1 = dep.run(spec(True), 2.0).outputs
    r2 = dep.run(spec(False), 2.0).outputs
    assert r1 == pytest.approx(r2)
    dep.shutdown()


def test_multi_source_multi_sink():
    dep = make_dep(enforce=False)
    dep.deploy("src_a", lambda p, d: p + 1, ["edge-eu"])
    dep.deploy("src_b", lambda p, d: p + 2, ["edge-eu"])
    dep.deploy("mid", lambda p, d: p["src_a"] * p["src_b"], ["cloud-us"])
    dep.deploy("sink_x", lambda p, d: ("x", p), ["cloud-us"])
    dep.deploy("sink_y", lambda p, d: ("y", p), ["edge-eu"])
    spec = DagSpec(
        (
            DagStep("src_a", "edge-eu"),
            DagStep("src_b", "edge-eu"),
            DagStep("mid", "cloud-us"),
            DagStep("sink_x", "cloud-us"),
            DagStep("sink_y", "edge-eu"),
        ),
        (
            ("src_a", "mid"),
            ("src_b", "mid"),
            ("mid", "sink_x"),
            ("mid", "sink_y"),
        ),
    )
    r = dep.run(spec, 10)  # both sources get the client input
    assert r.outputs == {"sink_x": ("x", 132), "sink_y": ("y", 132)}
    dep.shutdown()


def test_chain_lifted_to_dag_matches_chain_engine():
    """from_chain specs run on the DAG engine with identical results."""
    wf = WorkflowSpec((StepSpec("a", "edge-eu"), StepSpec("b", "cloud-us")))

    chain = Deployment(make_registry())
    chain.deploy("a", lambda p, d: p + 1, ["edge-eu"])
    chain.deploy("b", lambda p, d: p * 10, ["cloud-us"])
    expected = chain.run(wf, 1).outputs
    chain.shutdown()

    dag = make_dep(enforce=False)
    dag.deploy("a", lambda p, d: p + 1, ["edge-eu"])
    dag.deploy("b", lambda p, d: p * 10, ["cloud-us"])
    assert dag.run(DagSpec.from_chain(wf), 1).outputs == expected
    dag.shutdown()


def test_handler_error_propagates():
    dep = make_dep(enforce=False)
    dep.deploy("a", lambda p, d: p, ["edge-eu"])
    dep.deploy("boom", lambda p, d: 1 / 0, ["cloud-us"])
    spec = DagSpec(
        (DagStep("a", "edge-eu"), DagStep("boom", "cloud-us")), (("a", "boom"),)
    )
    with pytest.raises(ZeroDivisionError):
        dep.run(spec, 1)
    dep.shutdown()


def test_missing_deployment_raises():
    dep = make_dep(enforce=False)
    dep.deploy("a", lambda p, d: p, ["edge-eu"])
    spec = DagSpec((DagStep("a", "cloud-us"),), ())
    with pytest.raises(KeyError):
        dep.run(spec, 0)
    dep.shutdown()


def test_prewarm_hides_compile_in_dag():
    """A poked branch node compiles in the background (never a cold miss)."""
    import jax
    import jax.numpy as jnp

    dep = make_dep(enforce=False)

    def stepfn(x):
        return jnp.tanh(x @ x.T).sum()

    abstract = (jax.ShapeDtypeStruct((32, 32), jnp.float32),)
    dep.deploy("head", sleep_handler(0.25), ["edge-eu"])
    dep.deploy(
        "b",
        lambda p, d: float(stepfn(jnp.asarray(p))),
        ["cloud-us"],
        abstract_args=abstract,
        compile_fn=stepfn,
    )
    spec = DagSpec(
        (DagStep("head", "edge-eu"), DagStep("b", "cloud-us")), (("head", "b"),)
    )
    x = np.random.default_rng(0).normal(size=(32, 32)).astype(np.float32)
    dep.run(spec, x)
    assert dep.cache.stats["prewarms"] >= 1
    assert dep.cache.stats["misses"] == 0
    dep.shutdown()
