"""AdamW + schedule + int8 error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.optim import compress as C


def test_adamw_converges_on_quadratic():
    opt = AdamW(AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0))
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for step in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, gnorm = opt.update(params, state, grads,
                                          jnp.int32(step))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping_bounds_update():
    opt = AdamW(AdamWConfig(peak_lr=1e-2, clip_norm=1.0, warmup_steps=0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    p2, state, gnorm = opt.update(params, state, huge, jnp.int32(0))
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1e-1   # clipped


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(jnp.float32(0), peak_lr=1.0,
                                warmup_steps=10, total_steps=100))
    lr_peak = float(cosine_schedule(jnp.float32(10), peak_lr=1.0,
                                    warmup_steps=10, total_steps=100))
    lr_end = float(cosine_schedule(jnp.float32(100), peak_lr=1.0,
                                   warmup_steps=10, total_steps=100))
    assert lr0 < 0.2 and lr_peak == pytest.approx(1.0, abs=0.05)
    assert lr_end == pytest.approx(0.1, abs=0.02)   # final_frac


# -- compression ---------------------------------------------------------------
@given(st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=64) * rng.uniform(0.1, 100))
    q, scale = C.quantize_int8(x)
    err = jnp.abs(C.dequantize_int8(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) / 2 + 1e-6


def test_error_feedback_telescopes():
    """sum(sent_t) == sum(grad_t) - residual_T: nothing is ever lost."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(32)
    total_sent = jnp.zeros(32)
    total_grad = jnp.zeros(32)
    for t in range(50):
        g = jnp.asarray(rng.normal(size=32))
        q, scale, residual = C.compress_with_feedback(g, residual)
        total_sent += C.dequantize_int8(q, scale)
        total_grad += g
    np.testing.assert_allclose(np.asarray(total_sent + residual),
                               np.asarray(total_grad), rtol=1e-4, atol=1e-4)


def test_compressed_sgd_converges():
    """Quadratic minimization with int8 error-feedback gradients."""
    rng = np.random.default_rng(1)
    target = jnp.asarray(rng.normal(size=16))
    w = jnp.zeros(16)
    residual = jnp.zeros(16)
    for t in range(400):
        g = 2 * (w - target)
        q, scale, residual = C.compress_with_feedback(g, residual)
        w = w - 0.05 * C.dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)


def test_wire_savings_reported():
    grads = {"a": jnp.zeros((128, 128)), "b": jnp.zeros(64)}
    stats = C.tree_compress_stats(grads)
    assert stats["ratio"] > 3.9
