"""The redesigned experiment API: ``ExperimentSpec`` + ``simulate(spec,
backend=...)`` as the one entry point, the legacy wrappers as thin shims
over it, and the ``vectorized=`` -> ``backend=`` deprecation mapping."""

import warnings

import numpy as np
import pytest

from repro.core import simulator as S
from repro.dag import document_dag_fig4


def _sim(seed=0, **kw):
    return S.WorkflowSimulator(S.paper_platforms(), seed=seed, **kw)


# ---------------------------------------------------------------------------
# the deprecation shim
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("flag,backend", [(True, "numpy"), (False, "scalar")])
def test_vectorized_kwarg_warns_and_maps(flag, backend):
    steps = S.document_workflow_fig4()
    with pytest.warns(DeprecationWarning, match="vectorized"):
        old = _sim(3).run_experiment(steps, 16, vectorized=flag)
    new = _sim(3).run_experiment(steps, 16, backend=backend)
    assert np.array_equal(old, new)


def test_vectorized_kwarg_warns_on_dag_and_many():
    steps, edges = document_dag_fig4()
    with pytest.warns(DeprecationWarning):
        old = _sim(7).run_dag_experiment(steps, edges, 8, vectorized=True)
    assert np.array_equal(
        old, _sim(7).run_dag_experiment(steps, edges, 8, backend="numpy")
    )
    with pytest.warns(DeprecationWarning):
        old = _sim().run_experiment_many(
            S.document_workflow_fig4(), [1, 2], n_requests=8, vectorized=True
        )
    assert np.array_equal(
        old,
        _sim().run_experiment_many(
            S.document_workflow_fig4(), [1, 2], n_requests=8
        ),
    )


def test_vectorized_and_backend_together_is_an_error():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(TypeError, match="not both"):
            _sim().run_experiment(
                S.document_workflow_fig4(), 4, vectorized=True, backend="numpy"
            )


def test_unknown_backend_is_an_error():
    with pytest.raises(ValueError, match="backend"):
        _sim().run_experiment(S.document_workflow_fig4(), 4, backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        _sim().simulate(
            S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4),
            backend="",
        )


# ---------------------------------------------------------------------------
# ExperimentSpec and simulate()
# ---------------------------------------------------------------------------
def test_spec_normalizes_sequences_to_tuples():
    steps, edges = document_dag_fig4()
    spec = S.ExperimentSpec(list(steps), edges=list(edges), seeds=[1, 2])
    assert isinstance(spec.steps, tuple)
    assert isinstance(spec.edges, tuple)
    assert spec.seeds == (1, 2)
    assert (spec.n_requests, spec.interarrival_s, spec.prefetch) == (1800, 1.0, True)


def test_simulate_matches_legacy_wrappers():
    steps = S.document_workflow_fig4()
    dag_steps, edges = document_dag_fig4()
    # chain, scalar (the run_experiment default)
    a = _sim(3).run_experiment(steps, 12)
    b = _sim(3).simulate(S.ExperimentSpec(steps, n_requests=12), backend="scalar")
    assert np.array_equal(a, b)
    # DAG, numpy
    a = _sim(7).run_dag_experiment(dag_steps, edges, 10, backend="numpy")
    b = _sim(7).simulate(
        S.ExperimentSpec(dag_steps, edges=edges, n_requests=10),
        backend="numpy",
    )
    assert np.array_equal(a, b)
    # seed sweep == stacked fresh single-seed runs
    m = _sim().simulate(
        S.ExperimentSpec(steps, n_requests=16, seeds=(4, 5)), backend="numpy"
    )
    assert m.shape == (2, 16)
    solo = _sim(5).run_experiment(steps, 16, backend="numpy")
    assert np.array_equal(m[1], solo)


def test_simulate_seed_sweep_restores_own_rng():
    sim = _sim()
    before = sim.rng.bit_generator.state
    sim.simulate(
        S.ExperimentSpec(S.document_workflow_fig4(), n_requests=8, seeds=(0, 1)),
        backend="numpy",
    )
    assert sim.rng.bit_generator.state == before


def test_spec_drift_overrides_simulator_for_one_experiment():
    steps = [S.SimStep("a", "gcf", compute=S.Dist(0.3, 0.0), fetch=S.Dist(0.1, 0.0))]
    drift = S.DriftSchedule([S.DriftEvent(0, "gcf", compute_scale=10.0)])
    sim = _sim()
    plain = sim.simulate(
        S.ExperimentSpec(steps, n_requests=6, seeds=(0,)), backend="numpy"
    )
    drifted = sim.simulate(
        S.ExperimentSpec(steps, n_requests=6, seeds=(0,), drift=drift),
        backend="numpy",
    )
    assert (drifted > plain).all()
    assert sim.drift is None  # restored after the run
    again = sim.simulate(
        S.ExperimentSpec(steps, n_requests=6, seeds=(0,)), backend="numpy"
    )
    assert np.array_equal(plain, again)


def test_spec_telemetry_overrides_and_restores():
    from repro.adapt import TelemetryHub

    hub = TelemetryHub()
    sim = _sim()
    sim.simulate(
        S.ExperimentSpec(S.document_workflow_fig4(), n_requests=32, telemetry=hub),
        backend="numpy",
    )
    assert hub.snapshot()["warm_hits"]  # the hub saw the run
    assert sim.telemetry is None  # and the simulator forgot it


def test_simulate_placements_requires_placements():
    sim = _sim()
    with pytest.raises(ValueError, match="non-empty"):
        sim.simulate_placements(
            S.ExperimentSpec(S.document_workflow_fig4(), n_requests=4), []
        )
