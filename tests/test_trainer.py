"""Fault-tolerant trainer: loss falls, restart determinism, stragglers,
elastic re-mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def tcfg(tmp_path, **kw):
    base = dict(seq_len=32, global_batch=4, total_steps=12,
                checkpoint_every=6, checkpoint_dir=str(tmp_path / "ckpt"),
                adamw=AdamWConfig(peak_lr=3e-3, warmup_steps=2,
                                  total_steps=100))
    base.update(kw)
    return TrainerConfig(**base)


@pytest.fixture
def cfg():
    return smoke_config("qwen3-1.7b")


def test_loss_decreases(cfg, tmp_path):
    tr = Trainer(cfg, tcfg(tmp_path, total_steps=16))
    log = tr.run()
    first = np.mean([m["loss"] for m in log[:4]])
    last = np.mean([m["loss"] for m in log[-4:]])
    assert last < first, (first, last)


def test_restart_resumes_identically(cfg, tmp_path):
    """12 straight steps == 6 steps + crash + restore + 6 steps, exactly."""
    t1 = Trainer(cfg, tcfg(tmp_path / "a"))
    log1 = t1.run(12)

    t2 = Trainer(cfg, tcfg(tmp_path / "b"))
    t2.run(6)
    # "crash": fresh trainer object, same checkpoint dir
    t3 = Trainer(cfg, tcfg(tmp_path / "b"))
    log3 = t3.run(6)
    assert t3.step == 12
    ref = [m["loss"] for m in log1[6:]]
    got = [m["loss"] for m in log3]
    np.testing.assert_allclose(got, ref, rtol=1e-5)


def test_straggler_detection(cfg, tmp_path):
    tr = Trainer(cfg, tcfg(tmp_path, total_steps=14))
    fired = []
    tr.on_straggler = lambda step, dt: fired.append(step)
    tr.run(14, inject_straggler_at=10)
    assert any(s == 10 for s, dt, ewma in tr.stragglers)
    assert fired == [10]


def test_elastic_remesh_continues(cfg, tmp_path):
    """Re-shard live state onto a different mesh and keep training."""
    from repro.launch.mesh import make_host_mesh
    from repro.dist import sharding as shd
    tr = Trainer(cfg, tcfg(tmp_path, total_steps=4))
    tr.run(4)
    leaf_before = np.asarray(
        jax.tree_util.tree_leaves(tr.params)[0]).copy()
    count_before = int(tr.opt_state["count"])
    mesh = make_host_mesh(model_parallel=1)     # 1-device "new topology"
    tr.remesh(mesh, shd.train_rules())
    # state preserved EXACTLY across the re-shard (no re-init)
    leaf_after = np.asarray(jax.tree_util.tree_leaves(tr.params)[0])
    np.testing.assert_array_equal(leaf_before, leaf_after)
    assert int(tr.opt_state["count"]) == count_before
    log = tr.run(4)
    assert len(log) == 8
    assert np.isfinite(log[-1]["loss"])
