import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single device; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # Offline fallback: the dev container cannot pip install; vendor/ holds a
    # minimal shim (see its docstring). CI installs the real hypothesis.
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "vendor"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
